"""Reproduce every Neural Cache figure/table from the paper in one run.

Prints the paper's number next to ours for:
  Fig 13 (per-layer latency), Fig 14 (latency breakdown), Fig 15 (total
  latency + speedups), Fig 16 (throughput vs batch), Table III (energy /
  power), Table IV (cache-capacity scaling).

Run:  PYTHONPATH=src python examples/paper_repro.py
"""
from benchmarks import (fig13_latency_by_layer, fig14_breakdown,
                        fig15_total_latency, fig16_throughput_batch,
                        tab3_energy, tab4_cache_scaling)

MODULES = [
    ("Fig 13 latency by layer", fig13_latency_by_layer),
    ("Fig 14 breakdown", fig14_breakdown),
    ("Fig 15 total latency", fig15_total_latency),
    ("Fig 16 throughput vs batch", fig16_throughput_batch),
    ("Table III energy/power", tab3_energy),
    ("Table IV capacity scaling", tab4_cache_scaling),
]

if __name__ == "__main__":
    for title, mod in MODULES:
        print(f"\n=== {title} ===")
        for line in mod.run():
            print(" ", line)
