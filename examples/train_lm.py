"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU.

Uses the full production path — config system, synthetic data pipeline,
sharded step builder, AdamW, async checkpointing, watchdog — just on a
1-device mesh with a 110M-parameter olmo-family config.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params is deliberate: big enough to be honest, small enough for CPU.)
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~110M params: 12 x d512 olmo-family (matches GPT-2-small scale)
    cfg = dataclasses.replace(
        get_config("olmo-1b"),
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=50304, head_dim=64, dtype="float32", remat="none",
        attn_chunk_q=128, attn_chunk_kv=128,
    )
    n = cfg.param_count()
    print(f"[example] training {n/1e6:.0f}M-param {cfg.family} LM "
          f"for {args.steps} steps")
    shape = ShapeSpec("example", args.seq, args.batch, "train")
    _, _, hist = train(cfg, shape, steps=args.steps,
                       ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20)
    losses = [h["loss"] for h in hist]
    import numpy as np
    k = max(1, len(losses) // 10)
    print(f"[example] loss: first-{k} avg {np.mean(losses[:k]):.3f} -> "
          f"last-{k} avg {np.mean(losses[-k:]):.3f}")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    print("[example] OK")


if __name__ == "__main__":
    main()
