"""Serve a quantized LM with continuous batching — the paper's inference
pipeline (8-bit weights, batched requests) through the serving engine.

Shows the three weight precisions the bit-serial architecture trades
between (8/4/2-bit), with per-batch throughput, plus greedy-decode
agreement between the fp and W8-dequant models.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.serve import Request, ServingEngine
from repro.models import transformer as T
from repro.quant import quantize_lm_params


def dequantize_tree(qparams):
    """Weight-only quantization: materialize fp weights from int8+scales
    (serving frameworks do this per-layer on the fly; here once)."""

    def leaf(x):
        if isinstance(x, dict) and "q" in x:
            scale = x["scale"]
            if scale.ndim == 1:
                scale = scale[None, :]
            return x["q"].astype(jnp.float32) * scale
        return x

    return jax.tree.map(leaf, qparams,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def main():
    cfg = reduced_config(get_config("qwen2-7b"), n_layers=4, d_model=128,
                         d_ff=256, vocab_size=512, head_dim=32)
    params = T.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(8)]

    def run(p, tag):
        eng = ServingEngine(cfg, p, max_batch=4, max_len=128)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_tokens=8))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        print(f"  {tag:16s} {toks:3d} tokens  {toks/dt:7.1f} tok/s  "
              f"{eng.steps} engine steps")
        return {r.rid: r.out for r in done}

    print("[serve] fp32 baseline vs weight-quantized serving:")
    ref = run(params, "fp32")
    for bits in (8, 4):
        qp = quantize_lm_params(params, bits=bits)
        outs = run(dequantize_tree(qp), f"w{bits} (dequant)")
        agree = np.mean([outs[i] == ref[i] for i in outs])
        print(f"    -> greedy agreement with fp32: {agree*100:.0f}%")
    print("[serve] OK")


if __name__ == "__main__":
    main()
