"""Serve a quantized LM with continuous batching — the paper's inference
pipeline (8-bit weights, batched requests) through the serving engine.

Shows the three weight precisions the bit-serial architecture trades
between (8/4/2-bit), with per-batch throughput, plus greedy-decode
agreement between the fp and W8-dequant models.

With ``--neural-cache`` the demo serves quantized Inception images through
the SLO-aware Neural Cache engine instead: admission batch sizes come from
the cycle model's predicted p99 latency (core/slo.py), calibrated on the
fly against measured batch wall times, and the run prints the admitted
batch histogram and SLO hit rate.

Run:  PYTHONPATH=src python examples/serve_quantized.py
      PYTHONPATH=src python examples/serve_quantized.py --neural-cache --slo-ms 5000
      PYTHONPATH=src python examples/serve_quantized.py --neural-cache \
          --fault-profile seed=7,filter=0.1,compute=0.05
      PYTHONPATH=src python examples/serve_quantized.py --neural-cache \
          --compressed --warmup-replan
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.serve import NCRequest, NCServingEngine, Request, ServingEngine
from repro.models import transformer as T
from repro.quant import quantize_lm_params


def dequantize_tree(qparams):
    """Weight-only quantization: materialize fp weights from int8+scales
    (serving frameworks do this per-layer on the fly; here once)."""

    def leaf(x):
        if isinstance(x, dict) and "q" in x:
            scale = x["scale"]
            if scale.ndim == 1:
                scale = scale[None, :]
            return x["q"].astype(jnp.float32) * scale
        return x

    return jax.tree.map(leaf, qparams,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def main_neural_cache(slo_ms: float, requests: int = 6,
                      fault_profile: str | None = None,
                      compressed: bool = False,
                      warmup_replan: bool = False) -> None:
    """SLO-aware Neural Cache serving (§VI-C batching under a deadline).

    Submits ``requests`` images to an :class:`NCServingEngine` armed with
    ``--slo-ms``: the first admission sizes its batch from the modeled
    cycles alone, the measured wall time calibrates the
    :class:`~repro.core.slo.LatencyModel`, and later admissions shrink or
    grow to keep the predicted p99 under the remaining deadline budget.
    Logits are asserted bit-identical to standalone ``nc_forward`` runs —
    the SLO knob changes batch sizes, never results.

    ``--compressed`` plans and executes from the PR 8 CSR bit-plane
    filter store (residency credit and any raised streaming ceiling show
    up in the printed stats); ``--warmup-replan`` re-plans the engine
    after the first batch from measured occupancy.  Both are
    accounting/plan knobs — the closing assertion still demands logits
    byte-identical to a plain dense standalone forward.

    ``--fault-profile`` (e.g. ``seed=7,filter=0.1,compute=0.05``) scopes
    seeded fault injection (core/faults.py) over the run with integrity
    checking armed: corruption is detected by the per-pass checksums and
    re-executed, so the bit-identity assertion still holds."""
    import contextlib

    from repro.core import faults
    from repro.models import inception

    profile = (faults.FaultProfile.parse(fault_profile)
               if fault_profile else None)
    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.key(0), config=cfg)
    eng = NCServingEngine(params, cfg, max_batch=4, slo_ms=slo_ms,
                          integrity=profile is not None,
                          compressed=compressed,
                          warmup_replan=warmup_replan)
    rng = np.random.default_rng(0)
    imgs = rng.random((requests, cfg.img, cfg.img, 3)).astype(np.float32)
    for r in range(requests):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    scope = (faults.inject(profile) if profile is not None
             else contextlib.nullcontext())
    t0 = time.perf_counter()
    with scope as fs:
        done = eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats()
    print(f"[serve-nc] {len(done)} images in {dt:.2f}s emulated, "
          f"{eng.steps} admitted batches {s['batch_histogram']} "
          f"(stream limit {s['stream_batch_limit']})")
    print(f"[serve-nc] SLO {slo_ms:.0f} ms: {s['slo_hits']} hit / "
          f"{s['slo_misses']} miss (rate "
          f"{s['slo_hit_rate']:.0%}); latency model calibrated x"
          f"{s['calibration_scale']:.0f} wall/modeled over "
          f"{s['calibration_samples']} batches")
    if compressed or warmup_replan:
        print(f"[serve-nc] compressed={s['compressed']} residency credit "
              f"{s['residency_credit_bytes']} B/batch, "
              f"{s['warmup_replans']} warmup re-plan(s)")
    if profile is not None:
        fstats = fs.stats()
        print(f"[serve-nc] faults (seed {fstats['seed']}): "
              f"{fstats['injected']} injected, {fstats['detected']} "
              f"detected / {fstats['corrupt_attempts']} corrupt passes, "
              f"{fstats['reexecuted']} re-executed; {s['retries']} batch "
              f"retries, {s['degraded_batches']} degraded, "
              f"{s['failed']} failed")
    r0 = next(r for r in done if r.rid == 0)
    ref, _ = inception.nc_forward(params, imgs[0], config=cfg)
    np.testing.assert_array_equal(r0.logits, np.asarray(ref))
    print("[serve-nc] logits bit-identical to standalone nc_forward — OK")


def main():
    cfg = reduced_config(get_config("qwen2-7b"), n_layers=4, d_model=128,
                         d_ff=256, vocab_size=512, head_dim=32)
    params = T.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(8)]

    def run(p, tag):
        eng = ServingEngine(cfg, p, max_batch=4, max_len=128)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_tokens=8))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        print(f"  {tag:16s} {toks:3d} tokens  {toks/dt:7.1f} tok/s  "
              f"{eng.steps} engine steps")
        return {r.rid: r.out for r in done}

    print("[serve] fp32 baseline vs weight-quantized serving:")
    ref = run(params, "fp32")
    for bits in (8, 4):
        qp = quantize_lm_params(params, bits=bits)
        outs = run(dequantize_tree(qp), f"w{bits} (dequant)")
        agree = np.mean([outs[i] == ref[i] for i in outs])
        print(f"    -> greedy agreement with fp32: {agree*100:.0f}%")
    print("[serve] OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--neural-cache", action="store_true",
                    help="serve Inception images through the SLO-aware "
                         "Neural Cache engine instead of the LM")
    ap.add_argument("--slo-ms", type=float, default=5000.0,
                    help="per-request latency SLO for --neural-cache "
                         "(emulation wall-clock; the model calibrates "
                         "wall vs modeled cycles on the fly)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--fault-profile", type=str, default=None,
                    help="seeded fault injection for --neural-cache "
                         "(core/faults.py spec, e.g. 'seed=7,filter=0.1'); "
                         "implies integrity checking")
    ap.add_argument("--compressed", action="store_true",
                    help="plan + execute --neural-cache from the CSR "
                         "bit-plane filter store (PR 8); logits stay "
                         "byte-identical")
    ap.add_argument("--warmup-replan", action="store_true",
                    help="re-plan --neural-cache after the first batch "
                         "from measured occupancy (warmup batch excluded "
                         "from calibration)")
    args = ap.parse_args()
    if args.neural_cache:
        main_neural_cache(args.slo_ms, args.requests, args.fault_profile,
                          compressed=args.compressed,
                          warmup_replan=args.warmup_replan)
    else:
        main()
