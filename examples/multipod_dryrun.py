"""Example: one multi-pod dry-run cell with full roofline printout.

Lowers and compiles qwen2-7b train_4k on the 2x16x16 production mesh (512
placeholder devices), then prints the memory analysis, loop-corrected cost
analysis, collective schedule and the three roofline terms.

Run:  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import json
import sys

_ARGS = sys.argv[1:]
sys.argv = sys.argv[:1]  # keep dryrun's own parser quiet

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)


def main():
    arch = _ARGS[0] if _ARGS else "qwen2-7b"
    shape = _ARGS[1] if len(_ARGS) > 1 else "train_4k"
    rec = dryrun.run_cell(arch, shape, multi_pod=True)
    print(json.dumps(rec, indent=1))
    rl = rec["roofline"]
    print(f"\n[{arch} x {shape} @ {rec['mesh']}]")
    print(f"  peak {rec['peak_bytes_per_device']/1e9:.2f} GB/device, "
          f"fits 16GB HBM: {rec['fits_hbm']}")
    print(f"  compute {rl['t_compute']*1e3:.2f} ms | memory "
          f"{rl['t_memory']*1e3:.2f} ms | collective "
          f"{rl['t_collective']*1e3:.2f} ms -> {rl['dominant']}-bound")


if __name__ == "__main__":
    main()
