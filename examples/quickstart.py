"""Quickstart: the paper's pipeline end-to-end on a small CNN layer.

1. bit-exact in-SRAM arithmetic emulation (add / multiply / reduce) with the
   paper's cycle counts,
2. the cycle-accurate Neural Cache simulator reproducing the paper's
   headline numbers for Inception v3 on a 35 MB Xeon LLC,
3. the TPU translation: a quantized conv-as-GEMM through the fused W8A8
   kernel and the bit-serial (plane-decomposed) kernel.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitserial as B
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.simulator import simulate_network
from repro.models.inception import inception_v3_specs
from repro.core.quantize import choose_qparams_symmetric, quantize_per_channel, quantize
from repro.kernels import ops as K


def demo_bitserial():
    print("=== 1. bit-serial in-SRAM arithmetic (paper §III) ===")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 200, 8), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 55, 8), jnp.uint32)
    ap, bp = B.bitplane_pack(a, 8), B.bitplane_pack(b, 8)
    s, cyc_add = B.bitserial_add(ap, bp)
    p, cyc_mul = B.bitserial_multiply(ap, bp)
    print(f"  a+b bit-exact: {np.array_equal(B.bitplane_unpack(s), np.asarray(a)+np.asarray(b))}"
          f"  ({cyc_add} cycles = n+1)")
    print(f"  a*b bit-exact: {np.array_equal(B.bitplane_unpack(p), np.asarray(a)*np.asarray(b))}"
          f"  ({cyc_mul} cycles = n^2+5n-2)")
    r, cyc_red = B.bitserial_reduce(p)
    print(f"  reduce(8 lanes): {int(B.bitplane_unpack(r)[0])} == "
          f"{int((np.asarray(a)*np.asarray(b)).sum())}  ({cyc_red} cycles)")


def demo_simulator():
    print("\n=== 2. Neural Cache simulator: Inception v3 on 35MB LLC ===")
    res = simulate_network(inception_v3_specs(), XEON_E5_35MB)
    ms = res.latency_s * 1e3
    print(f"  total latency : {ms:8.2f} ms   (paper: 4.72 ms)")
    print(f"  vs CPU 86.4 ms: {86.4/ms:8.1f} x    (paper: 18.3x)")
    print(f"  vs GPU 36.3 ms: {36.3/ms:8.1f} x    (paper: 7.7x)")


def demo_tpu_kernels():
    print("\n=== 3. TPU translation: quantized GEMM kernels ===")
    rng = jax.random.key(7)
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (128, 256), jnp.float32)
    w = jax.random.normal(k2, (256, 128), jnp.float32) * 0.2
    qp = choose_qparams_symmetric(jnp.max(jnp.abs(x)))
    xq = quantize(x, qp)
    wq, wscale = quantize_per_channel(w)
    y8 = K.quant_matmul(xq, wq, qp.scale, wscale.reshape(-1))
    err = jnp.abs(y8 - x @ w).mean() / jnp.abs(x @ w).mean()
    print(f"  W8A8 fused kernel rel.err: {float(err):.4f}")
    for bits in (8, 4, 2):
        wqb, wsb = quantize_per_channel(w, bits=bits)
        planes = K.pack_weights(wqb.astype(jnp.int32), bits)  # byte-packed
        yb = K.bitserial_matmul(xq, planes, qp.scale, wsb.reshape(-1),
                                n_bits=bits)
        err = jnp.abs(yb - x @ w).mean() / jnp.abs(x @ w).mean()
        print(f"  bit-serial {bits}-bit ({bits} planes/byte-packed, cost ∝ planes)"
              f" rel.err: {float(err):.4f}")


if __name__ == "__main__":
    demo_bitserial()
    demo_simulator()
    demo_tpu_kernels()
