"""Transformer building blocks — pure-functional JAX (params are pytrees).

Attention is flash-style in pure JAX (scan over KV tiles with running
softmax) so 32k+ prefill never materializes a [Tq, Tk] score tensor.
Sliding-window layers use a *banded* variant: a fixed-width KV strip is
dynamically sliced per Q tile, so HLO FLOPs scale with window, not context.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) / math.sqrt(d_in)).astype(dtype)


def embed_init(key, v: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (v, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, w=None, b=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        out = out * w.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(cfg: ModelConfig, key) -> dict:
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((cfg.d_model,), cfg.jdtype)}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), cfg.jdtype),
                "b": jnp.zeros((cfg.d_model,), cfg.jdtype)}
    if cfg.norm == "layernorm_np":  # OLMo: non-parametric
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ModelConfig, p: dict, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"])
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return layer_norm(x)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, H, T, D]; positions: [B, T] or [T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, D/2]
    cos = jnp.cos(angles)[:, None, :, :]
    sin = jnp.sin(angles)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_init(cfg: ModelConfig, key) -> dict:
    dt = cfg.jdtype
    ks = jax.random.split(key, 4)
    d, hd, H, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": dense_init(ks[0], d, H * hd, dt),
        "wk": dense_init(ks[1], d, Hkv * hd, dt),
        "wv": dense_init(ks[2], d, Hkv * hd, dt),
        "wo": dense_init(ks[3], H * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    return p


def _qkv(cfg: ModelConfig, p: dict, x, positions):
    B, T, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, Hkv, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _tile_attn(q, k, v, qpos, kpos, window: int):
    """One (Q-tile, KV-strip) flash step.  q:[B,Hkv,G,qc,D] k/v:[B,Hkv,kc,D].
    Returns (scores-max m, exp-sum l, weighted acc)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k, preferred_element_type=jnp.float32) * scale
    mask = kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # guard fully-masked rows (padding tiles)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m_safe, l, acc


def flash_attention(
    q: jax.Array,  # [B, H, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_valid: jax.Array | int | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Tiled flash attention (GQA) in pure JAX.

    window > 0 uses the *banded* path: per Q tile only a fixed
    (window + q_chunk)-wide KV strip is sliced, so cost is O(T * window).
    """
    B, H, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    G = H // Hkv
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)

    pad_q = (-Tq) % q_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    Tqp = q.shape[2]
    qg = q.reshape(B, Hkv, G, Tqp, D)
    nq = Tqp // q_chunk

    kv_valid = Tk if kv_valid is None else kv_valid

    if window > 0:
        # banded: strip width rounded up to kv_chunk multiple
        strip = int(math.ceil((window + q_chunk) / kv_chunk)) * kv_chunk
        strip = min(strip, Tk)

        def q_tile(i):
            qi = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=3)
            qpos = i * q_chunk + jnp.arange(q_chunk) + q_offset
            start = jnp.clip(i * q_chunk + q_offset - (strip - q_chunk), 0, Tk - strip)
            ks = jax.lax.dynamic_slice_in_dim(k, start, strip, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, strip, axis=2)
            kpos = start + jnp.arange(strip)
            kpos = jnp.where(kpos < kv_valid, kpos, jnp.iinfo(jnp.int32).max)
            m, l, acc = _tile_attn(qi, ks, vs, qpos, kpos, window)
            return acc / jnp.maximum(l, 1e-30)[..., None]

        # checkpoint: recompute the tile's scores in the backward pass
        # instead of stacking O(T * strip) residuals across the map.
        q_tile = jax.checkpoint(q_tile)
        out = jax.lax.map(q_tile, jnp.arange(nq))  # [nq,B,Hkv,G,qc,D]
        out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Tqp, D)
    else:
        pad_k = (-Tk) % kv_chunk
        if pad_k:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        nk = k.shape[2] // kv_chunk
        kc = k.reshape(B, Hkv, nk, kv_chunk, D)
        vc = v.reshape(B, Hkv, nk, kv_chunk, D)

        def q_tile(i):
            qi = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=3)
            qpos = i * q_chunk + jnp.arange(q_chunk) + q_offset
            if not causal:
                qpos = jnp.full_like(qpos, jnp.iinfo(jnp.int32).max // 2)

            def kv_step(carry, j):
                m, l, acc = carry
                kj, vj = kc[:, :, j], vc[:, :, j]
                kpos = j * kv_chunk + jnp.arange(kv_chunk)
                kpos = jnp.where(kpos < kv_valid, kpos, jnp.iinfo(jnp.int32).max)
                mj, lj, accj = _tile_attn(qi, kj, vj, qpos, kpos, 0)
                m_new = jnp.maximum(m, mj)
                c1 = jnp.exp(m - m_new)
                c2 = jnp.exp(mj - m_new)
                return (m_new, l * c1 + lj * c2,
                        acc * c1[..., None] + accj * c2[..., None]), None

            m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
            a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
            # checkpoint the KV step: the scan's AD then saves only the
            # (m, l, acc) carries per step and recomputes the (qc, kc)
            # score tile in the backward — flash-backward memory behavior.
            # Without this, autodiff stacks every f32 score tile: the full
            # O(T^2) matrix the flash structure exists to avoid.
            (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step),
                                          (m0, l0, a0), jnp.arange(nk))
            return acc / jnp.maximum(l, 1e-30)[..., None]

        out = jax.lax.map(jax.checkpoint(q_tile), jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Tqp, D)

    out = out.reshape(B, H, Tqp, D)[:, :, :Tq]
    return out.astype(v.dtype)


def _decode_mask(pos, S: int, window: int = 0):
    """Causal key mask for single-token decode: ``[1,1,1,1,S]`` for a
    scalar position shared by the batch, ``[B,1,1,1,S]`` for an int32
    ``[B]`` vector of per-row positions (continuous batching decodes
    each slot at its OWN position)."""
    pos = jnp.asarray(pos)
    kpos = jnp.arange(S)
    if pos.ndim > 0:
        mask = kpos[None, :] <= pos[:, None]
        if window > 0:
            mask &= kpos[None, :] > pos[:, None] - window
        return mask[:, None, None, None, :]
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    return mask[None, None, None, None]


def _ring_mask(ring_slot, ring_len, S: int):
    """Slot-age mask for SWA ring caches, scalar or per-row vector."""
    ring_slot = jnp.asarray(ring_slot)
    ring_len = jnp.asarray(ring_len)
    kpos = jnp.arange(S)
    if ring_slot.ndim > 0:
        age = (ring_slot[:, None] - kpos[None, :]) % S
        return (age < ring_len[:, None])[:, None, None, None, :]
    age = (ring_slot - kpos) % S  # 0 = newest
    return (age < ring_len)[None, None, None, None]


def _cache_row_update(cache_arr, new_vals, slot):
    """Write each batch row's single-position update at its OWN cache
    slot: ``cache_arr`` [B,Hkv,W,*], ``new_vals`` [B,Hkv,1,*], ``slot``
    int32 [B] — the vector counterpart of ``dynamic_update_slice_in_dim``
    on axis 2."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=1)
    )(cache_arr, new_vals, slot)


def decode_attention(q, k_cache, v_cache, pos, window: int = 0):
    """Single-token attention over a [B,Hkv,S,D] cache; pos = current
    index (scalar, or int32 [B] per-row positions)."""
    B, H, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, 1, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(_decode_mask(pos, S, window), s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, 1, D).astype(v_cache.dtype)


def attention_apply(cfg, p, x, positions, *, window=0, cache=None, cache_pos=None):
    """Returns (out [B,T,d], new_cache or None).

    cache: dict(k=[B,Hkv,W,D], v=...) — decode appends at ``cache_pos % W``
    (ring for SWA layers); prefill with cache returns the populated cache.
    """
    B, T, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    new_cache = None
    kv8 = cfg.kv_dtype == "int8"
    if cache is not None and T == 1 and kv8:
        W = cache["k"].shape[2]
        per_row = jnp.ndim(cache_pos) > 0  # int32 [B] per-slot positions
        if per_row:
            cache_pos = jnp.asarray(cache_pos, jnp.int32).reshape(-1)
        slot = cache_pos % W if window > 0 else cache_pos
        kq, ks1 = kv_quantize(k)
        vq, vs1 = kv_quantize(v)
        if per_row:
            new_cache = {"k": _cache_row_update(cache["k"], kq, slot),
                         "v": _cache_row_update(cache["v"], vq, slot),
                         "ks": _cache_row_update(cache["ks"], ks1, slot),
                         "vs": _cache_row_update(cache["vs"], vs1, slot)}
        else:
            dus = jax.lax.dynamic_update_slice_in_dim
            new_cache = {"k": dus(cache["k"], kq, slot, axis=2),
                         "v": dus(cache["v"], vq, slot, axis=2),
                         "ks": dus(cache["ks"], ks1, slot, axis=2),
                         "vs": dus(cache["vs"], vs1, slot, axis=2)}
        if window > 0:
            ring_len = jnp.minimum(cache_pos + 1,
                                   W if window >= W else window)
            out = decode_attention_q8(
                q, new_cache["k"], new_cache["ks"], new_cache["v"],
                new_cache["vs"], cache_pos, ring_slot=slot,
                ring_len=ring_len)
        else:
            out = decode_attention_q8(
                q, new_cache["k"], new_cache["ks"], new_cache["v"],
                new_cache["vs"], cache_pos)
        out = out.astype(x.dtype)
    elif cache is not None and T == 1:
        W = cache["k"].shape[2]
        per_row = jnp.ndim(cache_pos) > 0  # int32 [B] per-slot positions
        if per_row:
            cache_pos = jnp.asarray(cache_pos, jnp.int32).reshape(-1)
        slot = cache_pos % W if window > 0 else cache_pos
        if per_row:
            kc = _cache_row_update(cache["k"], k, slot)
            vc = _cache_row_update(cache["v"], v, slot)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=2)
        new_cache = {"k": kc, "v": vc}
        if window > 0:
            # ring buffer: positions are implicit; rebuild kpos mask by slot age
            ring_len = jnp.minimum(cache_pos + 1, W if window >= W else window)
            mask = _ring_mask(slot, ring_len, W)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                q.reshape(B, cfg.n_kv_heads, cfg.q_groups, 1, cfg.hd), kc,
                preferred_element_type=jnp.float32,
            ) / math.sqrt(cfg.hd)
            s = jnp.where(mask, s, -jnp.inf)
            pr = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bhgqk,bhkd->bhgqd", pr.astype(vc.dtype), vc,
                             preferred_element_type=jnp.float32)
            out = out.reshape(B, cfg.n_heads, 1, cfg.hd).astype(x.dtype)
        else:
            out = decode_attention(q, kc, vc, cache_pos, window=0)
    else:
        out = flash_attention(
            q, k, v, causal=True, window=window,
            q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv,
        )
        if cache is not None:  # prefill into cache
            W = cache["k"].shape[2]
            if window > 0 and W < k.shape[2]:
                # ring layout: absolute position p lives at slot p % W
                T_total = k.shape[2]
                k, v = k[:, :, -W:], v[:, :, -W:]
                k = jnp.roll(k, T_total % W, axis=2)
                v = jnp.roll(v, T_total % W, axis=2)
            dus = jax.lax.dynamic_update_slice_in_dim
            if kv8:
                kq, ks1 = kv_quantize(k)
                vq, vs1 = kv_quantize(v)
                new_cache = {"k": dus(cache["k"], kq, 0, axis=2),
                             "v": dus(cache["v"], vq, 0, axis=2),
                             "ks": dus(cache["ks"], ks1, 0, axis=2),
                             "vs": dus(cache["vs"], vs1, 0, axis=2)}
            else:
                new_cache = {"k": dus(cache["k"], k, 0, axis=2),
                             "v": dus(cache["v"], v, 0, axis=2)}
    B_, H, Tq, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(B, Tq, H * hd)
    return out @ p["wo"], new_cache


def attention_cache_init(cfg: ModelConfig, batch: int, seq_len: int, window: int) -> dict:
    W = min(window, seq_len) if window > 0 else seq_len
    shape = (batch, cfg.n_kv_heads, W, cfg.hd)
    if cfg.kv_dtype == "int8":
        # the paper's in-cache 8-bit layout for the KV cache: int8 payload
        # + per-(position, head) f32 scales (~1.5% overhead at hd=128)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "vs": jnp.zeros(shape[:3] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, cfg.jdtype), "v": jnp.zeros(shape, cfg.jdtype)}


# ---------------------------------------------------------------------------
# int8 KV cache helpers (kv_dtype="int8")
# ---------------------------------------------------------------------------
def kv_quantize(x: jax.Array):
    """[B,Hkv,T,D] -> (int8 values, f32 [B,Hkv,T,1] per-(pos,head) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention_q8(q, kq, ks, vq, vs, pos, window: int = 0,
                        ring_slot=None, ring_len=None):
    """Single-token attention on an int8 cache, int8 matmuls throughout.

    QK^T runs int8 x int8 -> int32 (MXU native), scaled by per-position key
    scales; softmax probs absorb the per-position *value* scales and are
    requantized to int8 for the PV matmul — the same
    quantize -> integer-MAC -> rescale pipeline the paper runs on bit lines.
    """
    B, H, _, D = q.shape
    Hkv, S = kq.shape[1], kq.shape[2]
    G = H // Hkv
    # quantize the query per (batch, head)
    qg = q.reshape(B, Hkv, G, 1, D)
    qs = jnp.max(jnp.abs(qg.astype(jnp.float32)), axis=-1, keepdims=True)
    qs = jnp.maximum(qs, 1e-12) / 127.0
    qq = jnp.clip(jnp.round(qg.astype(jnp.float32) / qs), -127, 127
                  ).astype(jnp.int8)
    s_int = jnp.einsum("bhgqd,bhkd->bhgqk", qq, kq,
                       preferred_element_type=jnp.int32)
    # scales: qs [B,Hkv,G,1,1] x ks [B,Hkv,S,1] -> [B,Hkv,1,1,S]
    s = (s_int.astype(jnp.float32) * qs
         * ks[..., 0][:, :, None, None, :]) / math.sqrt(D)
    if ring_slot is not None:  # SWA ring buffer: mask by slot age
        mask = _ring_mask(ring_slot, ring_len, S)
    else:
        mask = _decode_mask(pos, S, window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)  # [B,Hkv,G,1,S]
    # fold per-position value scales into p, requantize rows to int8
    pv = p * vs[..., 0][:, :, None, None, :]
    p_scale = jnp.maximum(jnp.max(pv, axis=-1, keepdims=True), 1e-12) / 127.0
    pq = jnp.clip(jnp.round(pv / p_scale), 0, 127).astype(jnp.int8)
    out_int = jnp.einsum("bhgqk,bhkd->bhgqd", pq, vq,
                         preferred_element_type=jnp.int32)
    out = out_int.astype(jnp.float32) * p_scale
    return out.reshape(B, H, 1, D)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    dt = cfg.jdtype
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wi": dense_init(ks[0], cfg.d_model, ff, dt),
            "wg": dense_init(ks[1], cfg.d_model, ff, dt),
            "wo": dense_init(ks[2], ff, cfg.d_model, dt),
        }
    return {
        "wi": dense_init(ks[0], cfg.d_model, ff, dt),
        "wo": dense_init(ks[2], ff, cfg.d_model, dt),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x):
    if cfg.act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
