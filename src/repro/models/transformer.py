"""Decoder LM assembly for every assigned architecture family.

Layers are grouped into *stages*: maximal runs of layers with the same
attention-window class (for hymba: SWA runs split by the three global
layers; for everything else: one stage).  Each stage's params/caches are
stacked on a leading layer axis and executed with ``jax.lax.scan`` —
constant-size HLO regardless of depth (qwen110b's 80 layers compile as one
scanned body), remat policy applied at the scan boundary.  The stage
structure doubles as the pipeline-parallel cut points
(distributed/pipeline.py).

Entry points (all pure):
    init_lm(cfg, key)                          -> params
    lm_apply(cfg, params, tokens/embeds, ...)  -> hidden or (logits, caches)
    lm_loss(cfg, params, batch)                -> scalar (chunked vocab CE)
    prefill(cfg, params, tokens)               -> (last_logits, caches)
    decode_step(cfg, params, tokens, caches, pos) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current_abstract_mesh
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE

__all__ = ["plan_stages", "init_lm", "lm_apply", "lm_loss", "prefill",
           "decode_step", "init_caches", "Stage"]


@dataclasses.dataclass(frozen=True)
class Stage:
    start: int
    length: int
    window: int  # 0 = full attention


def plan_stages(cfg: ModelConfig) -> list[Stage]:
    if not cfg.global_layers or cfg.attn_window == 0:
        return [Stage(0, cfg.n_layers, cfg.attn_window)]
    stages: list[Stage] = []
    i = 0
    globals_ = set(cfg.global_layers)
    while i < cfg.n_layers:
        if i in globals_:
            stages.append(Stage(i, 1, 0))
            i += 1
        else:
            j = i
            while j < cfg.n_layers and j not in globals_:
                j += 1
            stages.append(Stage(i, j - i, cfg.attn_window))
            i = j
    return stages


# ---------------------------------------------------------------------------
# activation sharding constraints (GSPMD anchor points)
# ---------------------------------------------------------------------------
def _constrain(cfg: ModelConfig, x, kind: str = "act"):
    """Re-anchor activation sharding at layer boundaries.

    Without these, one unshardable op (e.g. the embedding gather) lets GSPMD
    run the whole residual stream replicated — measured as a 188 GiB/device
    temp arena on olmo-1b before this constraint existed (EXPERIMENTS.md
    §Perf).  ``cfg.act_spec`` is set by the launcher; None (tests, single
    device) is a no-op.
    """
    if cfg.act_spec is None:
        return x
    mesh = current_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    b, s, v = cfg.act_spec
    if kind == "act":  # [B, T, d]
        spec = jax.sharding.PartitionSpec(b, s, None)
    elif kind in ("loss_h", "logits"):
        # Loss region: trade sequence parallelism for vocab TP.  With the
        # seq dim on `model`, every loss chunk's dW_head is a full [d, V]
        # partial reduced over `model` — 5 GB x chunks x microbatches of
        # all-reduce on a 110B model.  Re-sharding h to (batch, -, -) and
        # the logits to (batch, -, model) keeps dW_head shard-local; the
        # price is one 64 MB h all-gather per chunk (§Perf cell B).
        if not cfg.loss_vocab_tp:  # baseline: loss follows the act sharding
            spec = jax.sharding.PartitionSpec(b, s, None if kind == "loss_h"
                                              else v)
            return jax.lax.with_sharding_constraint(x, spec)
        v_eff = v
        if v is None and s == "model":
            n = dict(mesh.shape).get("model", 1)
            if n > 1 and cfg.vocab_size % n == 0:
                v_eff = "model"
        if kind == "loss_h":
            spec = jax.sharding.PartitionSpec(b, None, None)
        else:
            spec = jax.sharding.PartitionSpec(b, None, v_eff)
    else:  # [B, T]
        spec = jax.sharding.PartitionSpec(b, s)
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# per-layer params
# ---------------------------------------------------------------------------
def _layer_init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": L.norm_init(cfg, ks[0])}
    if cfg.has_attention:
        p["attn"] = L.attention_init(cfg, ks[1])
    if cfg.has_ssm:
        p["ssm"] = M.mamba_init(cfg, ks[2])
    if cfg.family == "hybrid":
        p["beta_attn"] = jnp.ones((), jnp.float32)
        p["beta_ssm"] = jnp.ones((), jnp.float32)
    if cfg.is_moe:
        p["norm2"] = L.norm_init(cfg, ks[3])
        p["moe"] = MoE.moe_init(cfg, ks[4])
        if cfg.moe_dense_residual:
            p["dense_mlp"] = L.mlp_init(cfg, ks[5], d_ff=cfg.dense_ff or 2 * cfg.d_model)
    elif cfg.d_ff > 0:
        p["norm2"] = L.norm_init(cfg, ks[3])
        p["mlp"] = L.mlp_init(cfg, ks[4])
    return p


def init_lm(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 3)
    stages = plan_stages(cfg)
    stage_params = []
    for st in stages:
        per_layer = [_layer_init(cfg, ks[st.start + i]) for i in range(st.length)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        stage_params.append(stacked)
    params = {
        "embed": L.embed_init(ks[-1], cfg.vocab_size, cfg.d_model, cfg.jdtype),
        "stages": stage_params,
        "final_norm": L.norm_init(cfg, ks[-2]),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(ks[-3], cfg.d_model, cfg.vocab_size, cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> list[dict]:
    """Per-stage stacked caches sized by window class (SWA: ring buffers)."""
    caches = []
    for st in plan_stages(cfg):
        c: dict[str, Any] = {}
        if cfg.has_attention:
            one = L.attention_cache_init(cfg, batch, seq_len, st.window)
            c["attn"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (st.length,) + x.shape), one
            )
        if cfg.has_ssm:
            one = M.mamba_cache_init(cfg, batch)
            c["ssm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (st.length,) + x.shape), one
            )
        caches.append(c)
    return caches


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------
def _sp_enter(cfg, h):
    """Megatron-SP block entry: all-gather the seq-sharded residual so the
    block's GEMMs see full sequences and the weights STAY sharded (GSPMD
    otherwise replicates the ff weights per layer — §Perf cell B).  The
    residual stream stays seq-sharded between blocks (saved activations
    keep the 1/TP footprint); only the transient block input is gathered.
    """
    if cfg.act_spec is None or not cfg.megatron_sp:
        return h
    b, s, _ = cfg.act_spec
    if s is None:
        return h
    mesh = current_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return h
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.PartitionSpec(b, None, None))


def _layer_apply(cfg, lp, x, positions, window, attn_cache, ssm_cache, cache_pos):
    h = _sp_enter(cfg, L.apply_norm(cfg, lp["norm1"], x))
    new_ac, new_sc = attn_cache, ssm_cache
    if cfg.family == "hybrid":
        a, new_ac = L.attention_apply(cfg, lp["attn"], h, positions, window=window,
                                      cache=attn_cache, cache_pos=cache_pos)
        if x.shape[1] == 1 and ssm_cache is not None:
            s, new_sc = M.mamba_step(cfg, lp["ssm"], h, ssm_cache)
        else:
            s, new_sc = M.mamba_apply(cfg, lp["ssm"], h, cache=ssm_cache)
        ba = lp["beta_attn"].astype(x.dtype)
        bs = lp["beta_ssm"].astype(x.dtype)
        x = x + (ba * a + bs * s) / (ba + bs)
    elif cfg.family == "ssm":
        if x.shape[1] == 1 and ssm_cache is not None:
            s, new_sc = M.mamba_step(cfg, lp["ssm"], h, ssm_cache)
        else:
            s, new_sc = M.mamba_apply(cfg, lp["ssm"], h, cache=ssm_cache)
        x = x + s
    else:
        a, new_ac = L.attention_apply(cfg, lp["attn"], h, positions, window=window,
                                      cache=attn_cache, cache_pos=cache_pos)
        x = x + a
    if cfg.is_moe:
        h2 = _sp_enter(cfg, L.apply_norm(cfg, lp["norm2"], x))
        y = MoE.moe_apply(cfg, lp["moe"], h2)
        if cfg.moe_dense_residual:
            y = y + L.mlp_apply(cfg, lp["dense_mlp"], h2)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + L.mlp_apply(cfg, lp["mlp"],
                            _sp_enter(cfg, L.apply_norm(cfg, lp["norm2"], x)))
    return _constrain(cfg, x), new_ac, new_sc


def _remat_wrap(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        # NOT checkpoint_dots: that would save the (B,H,Tq,Tk) attention-score
        # dots — the exact O(T^2) tensor flash attention exists to avoid.
        # Batched dots (scores, attn@v, MoE dispatch) are recomputed; only
        # weight-matmul outputs (qkv/o/ff projections) are saved.
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _stage_apply(cfg, stacked, x, positions, window, cache, cache_pos):
    """Scan the stacked layers of one stage."""
    has_cache = cache is not None and len(cache) > 0

    if has_cache:
        def body(carry, per_layer):
            lp, pc = per_layer
            xo, nac, nsc = _layer_apply(cfg, lp, carry, positions, window,
                                        pc.get("attn"), pc.get("ssm"), cache_pos)
            out = {}
            if nac is not None:
                out["attn"] = nac
            if nsc is not None:
                out["ssm"] = nsc
            return xo, out

        body = _remat_wrap(cfg, body)
        x, new_cache = jax.lax.scan(body, x, (stacked, cache))
        return x, new_cache

    def body_nc(carry, lp):
        xo, _, _ = _layer_apply(cfg, lp, carry, positions, window, None, None,
                                cache_pos)
        return xo, None

    body_nc = _remat_wrap(cfg, body_nc)
    x, _ = jax.lax.scan(body_nc, x, stacked)
    return x, None


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def _embed(cfg, params, tokens=None, embeds=None):
    if embeds is not None:
        return _constrain(cfg, embeds.astype(cfg.jdtype))
    return _constrain(cfg, params["embed"][tokens])


def _head(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return h @ w


def lm_apply(cfg, params, tokens=None, *, embeds=None, positions=None,
             caches=None, cache_pos=None):
    """Backbone forward.  Returns (hidden [B,T,d], new_caches or None)."""
    x = _embed(cfg, params, tokens, embeds)
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)
    stages = plan_stages(cfg)
    new_caches = []
    for si, st in enumerate(stages):
        cache = caches[si] if caches is not None else None
        x, nc = _stage_apply(cfg, params["stages"][si], x, positions, st.window,
                             cache, cache_pos)
        new_caches.append(nc)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, (new_caches if caches is not None else None)


def lm_logits(cfg, params, hidden):
    out = _head(cfg, params, hidden)
    if cfg.act_spec is not None and out.ndim == 2:
        mesh = current_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            b, _, v = cfg.act_spec
            out = jax.lax.with_sharding_constraint(
                out, jax.sharding.PartitionSpec(b, v))
    return out


def lm_loss(cfg, params, tokens, labels, *, embeds=None, loss_chunk: int = 512):
    """Next-token CE, chunked over sequence so [B,S,V] never materializes."""
    hidden, _ = lm_apply(cfg, params, tokens, embeds=embeds)
    B, T, D = hidden.shape
    C = min(loss_chunk, T)
    pad = (-T) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = hidden.shape[1] // C
    hc = hidden.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        h, lab = inp
        h = _constrain(cfg, h, "loss_h")
        logits = _constrain(cfg,
                            _head(cfg, params, h).astype(cfg.loss_dtype),
                            "logits")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        valid = lab >= 0
        ce = jnp.where(valid, logz - gold, 0.0)
        # dtype-explicit: global x64 mode must not change the carry signature
        return (carry[0] + ce.sum(dtype=jnp.float32),
                carry[1] + valid.sum(dtype=jnp.int32)), None

    # checkpoint: recompute each [B, C, V] logits chunk in the backward
    # instead of stacking all n chunks of f32 logits as scan residuals.
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(chunk_loss),
                                 (jnp.float32(0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def prefill(cfg, params, tokens=None, *, embeds=None, max_len: int | None = None):
    """Run the prompt, return (last-position logits [B,V], caches).

    ``max_len`` sets the KV-cache capacity (prompt + decode headroom)."""
    if tokens is not None:
        batch, seq_len = tokens.shape
    else:
        batch, seq_len = embeds.shape[0], embeds.shape[1]
    caches = init_caches(cfg, batch, max_len or seq_len)
    hidden, caches = lm_apply(cfg, params, tokens, embeds=embeds, caches=caches)
    return lm_logits(cfg, params, hidden[:, -1]), caches


def decode_step(cfg, params, tokens, caches, pos):
    """One token for the whole batch.  tokens [B,1]; pos: scalar position
    shared by every row, or an int32 [B] vector of per-slot positions —
    continuous batching admits prompts of different lengths, so each slot
    must decode (RoPE) and write KV at its OWN position, not the batch
    max (PR 9 bugfix)."""
    if jnp.ndim(pos) > 0:
        pos = jnp.asarray(pos, jnp.int32).reshape(-1)
        positions = pos[:, None]
    else:
        positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    hidden, caches = lm_apply(cfg, params, tokens, positions=positions,
                              caches=caches, cache_pos=pos)
    return lm_logits(cfg, params, hidden[:, 0]), caches
