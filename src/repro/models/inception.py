"""Inception v3 — the paper's evaluation workload (Table I).

One structure definition drives BOTH:
  * ``inception_v3_specs()`` — the per-branch LayerSpec list consumed by the
    Neural Cache mapper/simulator (reproduces Table I's Conv / Filter-MB
    columns exactly; see tests/test_inception.py), and
  * ``init_params`` / ``apply`` — a runnable JAX forward pass (float and
    dynamically-quantized uint8, the paper's §IV-D pipeline).

BN is inference-folded into a per-channel scale/bias on every conv.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapper import LayerSpec
from repro.core import quantize as q

# ---------------------------------------------------------------------------
# Structure: op = ("conv", R, S, M, stride, pad) | ("maxpool"|"avgpool", R, stride, pad)
# A block is either a single op or a list of branches (each a list of ops).
# ---------------------------------------------------------------------------
STEM = [
    ("Conv2d_1a_3x3", ("conv", 3, 3, 32, 2, "VALID")),
    ("Conv2d_2a_3x3", ("conv", 3, 3, 32, 1, "VALID")),
    ("Conv2d_2b_3x3", ("conv", 3, 3, 64, 1, "SAME")),
    ("MaxPool_3a_3x3", ("maxpool", 3, 2, "VALID")),
    ("Conv2d_3b_1x1", ("conv", 1, 1, 80, 1, "VALID")),
    ("Conv2d_4a_3x3", ("conv", 3, 3, 192, 1, "VALID")),
    ("MaxPool_5a_3x3", ("maxpool", 3, 2, "VALID")),
]


def _inception_a(pool_proj: int):  # Mixed_5x (35x35)
    return [
        [("conv", 1, 1, 64, 1, "SAME")],
        [("conv", 1, 1, 48, 1, "SAME"), ("conv", 5, 5, 64, 1, "SAME")],
        [
            ("conv", 1, 1, 64, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, pool_proj, 1, "SAME")],
    ]


def _reduction_a():  # Mixed_6a (35 -> 17)
    return [
        [("conv", 3, 3, 384, 2, "VALID")],
        [
            ("conv", 1, 1, 64, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
            ("conv", 3, 3, 96, 2, "VALID"),
        ],
        [("maxpool", 3, 2, "VALID")],
    ]


def _inception_b(c7: int):  # Mixed_6b..6e (17x17)
    return [
        [("conv", 1, 1, 192, 1, "SAME")],
        [
            ("conv", 1, 1, c7, 1, "SAME"),
            ("conv", 1, 7, c7, 1, "SAME"),
            ("conv", 7, 1, 192, 1, "SAME"),
        ],
        [
            ("conv", 1, 1, c7, 1, "SAME"),
            ("conv", 7, 1, c7, 1, "SAME"),
            ("conv", 1, 7, c7, 1, "SAME"),
            ("conv", 7, 1, c7, 1, "SAME"),
            ("conv", 1, 7, 192, 1, "SAME"),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, 192, 1, "SAME")],
    ]


def _reduction_b():  # Mixed_7a (17 -> 8)
    return [
        [("conv", 1, 1, 192, 1, "SAME"), ("conv", 3, 3, 320, 2, "VALID")],
        [
            ("conv", 1, 1, 192, 1, "SAME"),
            ("conv", 1, 7, 192, 1, "SAME"),
            ("conv", 7, 1, 192, 1, "SAME"),
            ("conv", 3, 3, 192, 2, "VALID"),
        ],
        [("maxpool", 3, 2, "VALID")],
    ]


def _inception_c():  # Mixed_7b/7c (8x8); nested split branches flattened
    return [
        [("conv", 1, 1, 320, 1, "SAME")],
        [("conv", 1, 1, 384, 1, "SAME"), ("split", [("conv", 1, 3, 384, 1, "SAME")], [("conv", 3, 1, 384, 1, "SAME")])],
        [
            ("conv", 1, 1, 448, 1, "SAME"),
            ("conv", 3, 3, 384, 1, "SAME"),
            ("split", [("conv", 1, 3, 384, 1, "SAME")], [("conv", 3, 1, 384, 1, "SAME")]),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, 192, 1, "SAME")],
    ]


MIXED = [
    ("Mixed_5b", _inception_a(32)),
    ("Mixed_5c", _inception_a(64)),
    ("Mixed_5d", _inception_a(64)),
    ("Mixed_6a", _reduction_a()),
    ("Mixed_6b", _inception_b(128)),
    ("Mixed_6c", _inception_b(160)),
    ("Mixed_6d", _inception_b(160)),
    ("Mixed_6e", _inception_b(192)),
    ("Mixed_7a", _reduction_b()),
    ("Mixed_7b", _inception_c()),
    ("Mixed_7c", _inception_c()),
]

IMG = 299


def _out_size(h: int, r: int, stride: int, pad: str) -> int:
    if pad == "SAME":
        return math.ceil(h / stride)
    return (h - r) // stride + 1


# ---------------------------------------------------------------------------
# Spec generation for the mapper/simulator
# ---------------------------------------------------------------------------
def _op_specs(name, block, op, h, c, specs):
    """Append LayerSpecs for one op; return (out_h, out_c)."""
    if op[0] == "conv":
        _, r, s, m, stride, pad = op
        e = _out_size(h, max(r, s), stride, pad)
        specs.append(
            LayerSpec(name=name, kind="conv", H=h, R=r, S=s, C=c, M=m, E=e,
                      stride=stride, block=block)
        )
        return e, m
    if op[0] in ("maxpool", "avgpool"):
        _, r, stride, pad = op
        e = _out_size(h, r, stride, pad)
        specs.append(
            LayerSpec(name=name, kind=op[0], H=h, R=r, S=r, C=0, M=c, E=e,
                      stride=stride, block=block)
        )
        return e, c
    if op[0] == "split":
        out_c = 0
        e = h
        for i, sub in enumerate(op[1:]):
            hh, cc = h, c
            for j, sop in enumerate(sub):
                hh, cc = _op_specs(f"{name}_s{i}_{j}", block, sop, hh, cc, specs)
            out_c += cc
            e = hh
        return e, out_c
    raise ValueError(op)


def inception_v3_specs() -> list[LayerSpec]:
    specs: list[LayerSpec] = []
    h, c = IMG, 3
    for name, op in STEM:
        h, c = _op_specs(name, name, op, h, c, specs)
    for bname, branches in MIXED:
        out_c = 0
        out_h = h
        for bi, branch in enumerate(branches):
            hh, cc = h, c
            for oi, op in enumerate(branch):
                hh, cc = _op_specs(f"{bname}_b{bi}_{oi}", bname, op, hh, cc, specs)
            out_c += cc
            out_h = hh
        h, c = out_h, out_c
    # global average pool (8x8 window) + FC-as-1x1-conv (§IV-D)
    specs.append(LayerSpec("AvgPool", "avgpool", H=h, R=h, S=h, C=0, M=c, E=1,
                           stride=1, block="AvgPool"))
    specs.append(LayerSpec("FullyConnected", "fc", H=1, R=1, S=1, C=c, M=1001,
                           E=1, stride=1, block="FullyConnected"))
    return specs


# ---------------------------------------------------------------------------
# Runnable JAX model (NHWC).  BN folded: per-channel scale/bias after conv.
# ---------------------------------------------------------------------------
def _conv_init(key, r, s, c, m, dtype=jnp.float32):
    fan_in = r * s * c
    w = jax.random.normal(key, (r, s, c, m), dtype) * (2.0 / fan_in) ** 0.5
    return {"w": w, "scale": jnp.ones((m,), dtype), "bias": jnp.zeros((m,), dtype)}


def _iter_convs(img: int = IMG):
    """Yield (path, r, s, c, m) for every conv in definition order."""
    specs = inception_v3_specs()
    for sp in specs:
        if sp.kind in ("conv", "fc"):
            yield sp.name, sp.R, sp.S, sp.C, sp.M


def init_params(key: jax.Array, dtype=jnp.float32) -> dict:
    params = {}
    convs = list(_iter_convs())
    keys = jax.random.split(key, len(convs))
    for k, (name, r, s, c, m) in zip(keys, convs):
        params[name] = _conv_init(k, r, s, c, m, dtype)
    return params


def _conv(x, p, stride, pad):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y * p["scale"] + p["bias"]


def _pool(x, kind, r, stride, pad):
    if kind == "maxpool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, r, r, 1), (1, stride, stride, 1), pad
        )
    ones = jax.lax.reduce_window(
        jnp.ones_like(x), 0.0, jax.lax.add, (1, r, r, 1), (1, stride, stride, 1), pad
    )
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, r, r, 1), (1, stride, stride, 1), pad
    )
    return s / ones


def _apply_op(x, name, op, params, quant: bool):
    if op[0] == "conv":
        _, r, s, m, stride, pad = op
        p = params[name]
        if quant:
            x = q.fake_quant(x)  # dynamic uint8 activations (§IV-D)
            wq, wscale = q.quantize_per_channel(p["w"], axis=-1)
            p = dict(p, w=wq.astype(jnp.float32) * wscale)
        y = _conv(x, p, stride, pad)
        return jax.nn.relu(y)
    if op[0] in ("maxpool", "avgpool"):
        _, r, stride, pad = op
        return _pool(x, op[0], r, stride, pad)
    if op[0] == "split":
        outs = []
        for i, sub in enumerate(op[1:]):
            y = x
            for j, sop in enumerate(sub):
                y = _apply_op(y, f"{name}_s{i}_{j}", sop, params, quant)
            outs.append(y)
        return jnp.concatenate(outs, axis=-1)
    raise ValueError(op)


def apply(params: dict, x: jax.Array, quant: bool = False) -> jax.Array:
    """Forward pass.  x: [N, H, W, 3] float32 in [0,1].  Returns [N, 1001]."""
    for name, op in STEM:
        x = _apply_op(x, name, op, params, quant)
    for bname, branches in MIXED:
        outs = []
        for bi, branch in enumerate(branches):
            y = x
            for oi, op in enumerate(branch):
                y = _apply_op(y, f"{bname}_b{bi}_{oi}", op, params, quant)
            outs.append(y)
        x = jnp.concatenate(outs, axis=-1)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    if quant:
        x = q.fake_quant(x)
    p = params["FullyConnected"]
    logits = x @ p["w"][0, 0] * p["scale"] + p["bias"]
    return logits
