"""Inception v3 — the paper's evaluation workload (Table I).

One structure definition drives ALL OF:
  * ``inception_v3_specs()`` — the per-branch LayerSpec list consumed by the
    Neural Cache mapper/simulator (reproduces Table I's Conv / Filter-MB
    columns exactly; see tests/test_inception.py),
  * ``init_params`` / ``apply`` — a runnable JAX forward pass (float and
    dynamically-quantized uint8, the paper's §IV-D pipeline), and
  * ``nc_forward`` — the same network executed *through the bit-serial
    emulation* (core/nc_layers.py): every conv/pool/fc runs on the packed
    word engine and the per-layer report pairs the emulation's arithmetic
    cycles with the analytic model's pass cycles (core/simulator.py),
    paper-style.

An :class:`InceptionConfig` scales the workload: ``FULL`` is the paper's
299x299 network; ``reduced_config()`` shrinks image size / channel widths /
class count (and optionally drops mixed stages) so the full forward pass is
emulation-tractable while still exercising every block type (3x3 stems,
1x1 packing, 5x5 splits, 7x1/1x7 factorizations, nested splits, pools).

BN is inference-folded into a per-channel scale/bias on every conv.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import LayerSpec
from repro.core import nc_layers as nc
from repro.core import quantize as q
from repro.core import schedule as sched
from repro.core import simulator as sim
from repro.core import bitserial as bs
from repro.core import backends as _backends

# ---------------------------------------------------------------------------
# Structure: op = ("conv", R, S, M, stride, pad) | ("maxpool"|"avgpool", R, stride, pad)
# A block is either a single op or a list of branches (each a list of ops).
# ---------------------------------------------------------------------------
STEM = [
    ("Conv2d_1a_3x3", ("conv", 3, 3, 32, 2, "VALID")),
    ("Conv2d_2a_3x3", ("conv", 3, 3, 32, 1, "VALID")),
    ("Conv2d_2b_3x3", ("conv", 3, 3, 64, 1, "SAME")),
    ("MaxPool_3a_3x3", ("maxpool", 3, 2, "VALID")),
    ("Conv2d_3b_1x1", ("conv", 1, 1, 80, 1, "VALID")),
    ("Conv2d_4a_3x3", ("conv", 3, 3, 192, 1, "VALID")),
    ("MaxPool_5a_3x3", ("maxpool", 3, 2, "VALID")),
]


def _inception_a(pool_proj: int):  # Mixed_5x (35x35)
    return [
        [("conv", 1, 1, 64, 1, "SAME")],
        [("conv", 1, 1, 48, 1, "SAME"), ("conv", 5, 5, 64, 1, "SAME")],
        [
            ("conv", 1, 1, 64, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, pool_proj, 1, "SAME")],
    ]


def _reduction_a():  # Mixed_6a (35 -> 17)
    return [
        [("conv", 3, 3, 384, 2, "VALID")],
        [
            ("conv", 1, 1, 64, 1, "SAME"),
            ("conv", 3, 3, 96, 1, "SAME"),
            ("conv", 3, 3, 96, 2, "VALID"),
        ],
        [("maxpool", 3, 2, "VALID")],
    ]


def _inception_b(c7: int):  # Mixed_6b..6e (17x17)
    return [
        [("conv", 1, 1, 192, 1, "SAME")],
        [
            ("conv", 1, 1, c7, 1, "SAME"),
            ("conv", 1, 7, c7, 1, "SAME"),
            ("conv", 7, 1, 192, 1, "SAME"),
        ],
        [
            ("conv", 1, 1, c7, 1, "SAME"),
            ("conv", 7, 1, c7, 1, "SAME"),
            ("conv", 1, 7, c7, 1, "SAME"),
            ("conv", 7, 1, c7, 1, "SAME"),
            ("conv", 1, 7, 192, 1, "SAME"),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, 192, 1, "SAME")],
    ]


def _reduction_b():  # Mixed_7a (17 -> 8)
    return [
        [("conv", 1, 1, 192, 1, "SAME"), ("conv", 3, 3, 320, 2, "VALID")],
        [
            ("conv", 1, 1, 192, 1, "SAME"),
            ("conv", 1, 7, 192, 1, "SAME"),
            ("conv", 7, 1, 192, 1, "SAME"),
            ("conv", 3, 3, 192, 2, "VALID"),
        ],
        [("maxpool", 3, 2, "VALID")],
    ]


def _inception_c():  # Mixed_7b/7c (8x8); nested split branches flattened
    return [
        [("conv", 1, 1, 320, 1, "SAME")],
        [("conv", 1, 1, 384, 1, "SAME"), ("split", [("conv", 1, 3, 384, 1, "SAME")], [("conv", 3, 1, 384, 1, "SAME")])],
        [
            ("conv", 1, 1, 448, 1, "SAME"),
            ("conv", 3, 3, 384, 1, "SAME"),
            ("split", [("conv", 1, 3, 384, 1, "SAME")], [("conv", 3, 1, 384, 1, "SAME")]),
        ],
        [("avgpool", 3, 1, "SAME"), ("conv", 1, 1, 192, 1, "SAME")],
    ]


MIXED = [
    ("Mixed_5b", _inception_a(32)),
    ("Mixed_5c", _inception_a(64)),
    ("Mixed_5d", _inception_a(64)),
    ("Mixed_6a", _reduction_a()),
    ("Mixed_6b", _inception_b(128)),
    ("Mixed_6c", _inception_b(160)),
    ("Mixed_6d", _inception_b(160)),
    ("Mixed_6e", _inception_b(192)),
    ("Mixed_7a", _reduction_b()),
    ("Mixed_7b", _inception_c()),
    ("Mixed_7c", _inception_c()),
]

IMG = 299


# ---------------------------------------------------------------------------
# Workload configuration: the full paper network, or a reduced-but-complete
# miniature for emulation-scale end-to-end runs.
# ---------------------------------------------------------------------------
def _scale_op(op, div: int):
    if op[0] == "conv":
        _, r, s, m, stride, pad = op
        return ("conv", r, s, max(1, m // div), stride, pad)
    if op[0] == "split":
        return ("split",) + tuple(
            [_scale_op(o, div) for o in sub] for sub in op[1:])
    return op


def _scale_blocks(blocks, div: int):
    if div == 1:
        return blocks
    out = []
    for name, entry in blocks:
        if isinstance(entry, tuple):  # single op (stem)
            out.append((name, _scale_op(entry, div)))
        else:  # list of branches
            out.append((name, [[_scale_op(o, div) for o in br]
                               for br in entry]))
    return out


@dataclasses.dataclass(frozen=True)
class InceptionConfig:
    """Workload geometry: image size, channel-width divisor, classes, and
    the stem/mixed structure (pre-scaled by :func:`_scale_blocks`)."""

    img: int = IMG
    classes: int = 1001
    stem: tuple = tuple((n, op) for n, op in STEM)
    mixed: tuple = tuple((n, br) for n, br in MIXED)

    @property
    def name(self) -> str:
        return f"inception_v3_{self.img}px_{self.classes}cls"


FULL = InceptionConfig()

_STAGE_BLOCKS = {
    "a": ("Mixed_5b",),
    "ra": ("Mixed_6a",),
    "b": ("Mixed_6b",),
    "rb": ("Mixed_7a",),
    "c": ("Mixed_7b",),
}


def reduced_config(img: int = 79, width_div: int = 4, classes: int = 32,
                   stages: Sequence[str] = ("a", "ra", "b", "rb", "c"),
                   ) -> InceptionConfig:
    """A miniature Inception v3: same topology, ``width_div``-narrower
    channels, one mixed block per requested stage.

    The default (79px, /4 widths) keeps every block type and both spatial
    reductions (7x7 -> 3x3 -> 1x1 mixed grids) while staying tractable for
    the bit-serial emulation; ``stages=("a",)`` with a smaller image is the
    test-sized variant.  Note Mixed_6a/7a need a >=7px mixed grid."""
    keep = [b for s in stages for b in _STAGE_BLOCKS[s]]
    mixed = tuple((n, br) for n, br in MIXED if n in keep)
    return InceptionConfig(
        img=img, classes=classes,
        stem=tuple(_scale_blocks(STEM, width_div)),
        mixed=tuple(_scale_blocks(mixed, width_div)),
    )


REDUCED = reduced_config()


def _out_size(h: int, r: int, stride: int, pad: str) -> int:
    if pad == "SAME":
        return math.ceil(h / stride)
    return (h - r) // stride + 1


# ---------------------------------------------------------------------------
# Spec generation for the mapper/simulator
# ---------------------------------------------------------------------------
def _op_specs(name, block, op, h, c, specs):
    """Append LayerSpecs for one op; return (out_h, out_c)."""
    if op[0] == "conv":
        _, r, s, m, stride, pad = op
        e = _out_size(h, max(r, s), stride, pad)
        specs.append(
            LayerSpec(name=name, kind="conv", H=h, R=r, S=s, C=c, M=m, E=e,
                      stride=stride, block=block)
        )
        return e, m
    if op[0] in ("maxpool", "avgpool"):
        _, r, stride, pad = op
        e = _out_size(h, r, stride, pad)
        specs.append(
            LayerSpec(name=name, kind=op[0], H=h, R=r, S=r, C=0, M=c, E=e,
                      stride=stride, block=block)
        )
        return e, c
    if op[0] == "split":
        out_c = 0
        e = h
        for i, sub in enumerate(op[1:]):
            hh, cc = h, c
            for j, sop in enumerate(sub):
                hh, cc = _op_specs(f"{name}_s{i}_{j}", block, sop, hh, cc, specs)
            out_c += cc
            e = hh
        return e, out_c
    raise ValueError(op)


def inception_v3_specs(config: InceptionConfig = FULL) -> list[LayerSpec]:
    specs: list[LayerSpec] = []
    h, c = config.img, 3
    for name, op in config.stem:
        h, c = _op_specs(name, name, op, h, c, specs)
    for bname, branches in config.mixed:
        out_c = 0
        out_h = h
        for bi, branch in enumerate(branches):
            hh, cc = h, c
            for oi, op in enumerate(branch):
                hh, cc = _op_specs(f"{bname}_b{bi}_{oi}", bname, op, hh, cc, specs)
            out_c += cc
            out_h = hh
        h, c = out_h, out_c
    # global average pool (8x8 window) + FC-as-1x1-conv (§IV-D)
    specs.append(LayerSpec("AvgPool", "avgpool", H=h, R=h, S=h, C=0, M=c, E=1,
                           stride=1, block="AvgPool"))
    specs.append(LayerSpec("FullyConnected", "fc", H=1, R=1, S=1, C=c,
                           M=config.classes, E=1, stride=1,
                           block="FullyConnected"))
    return specs


# ---------------------------------------------------------------------------
# Runnable JAX model (NHWC).  BN folded: per-channel scale/bias after conv.
# ---------------------------------------------------------------------------
def _conv_init(key, r, s, c, m, dtype=jnp.float32):
    fan_in = r * s * c
    w = jax.random.normal(key, (r, s, c, m), dtype) * (2.0 / fan_in) ** 0.5
    return {"w": w, "scale": jnp.ones((m,), dtype), "bias": jnp.zeros((m,), dtype)}


def _iter_convs(config: InceptionConfig = FULL):
    """Yield (path, r, s, c, m) for every conv in definition order."""
    specs = inception_v3_specs(config)
    for sp in specs:
        if sp.kind in ("conv", "fc"):
            yield sp.name, sp.R, sp.S, sp.C, sp.M


def init_params(key: jax.Array, dtype=jnp.float32,
                config: InceptionConfig = FULL) -> dict:
    params = {}
    convs = list(_iter_convs(config))
    keys = jax.random.split(key, len(convs))
    for k, (name, r, s, c, m) in zip(keys, convs):
        params[name] = _conv_init(k, r, s, c, m, dtype)
    return params


def _conv(x, p, stride, pad):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y * p["scale"] + p["bias"]


def _pool(x, kind, r, stride, pad):
    if kind == "maxpool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, r, r, 1), (1, stride, stride, 1), pad
        )
    ones = jax.lax.reduce_window(
        jnp.ones_like(x), 0.0, jax.lax.add, (1, r, r, 1), (1, stride, stride, 1), pad
    )
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, r, r, 1), (1, stride, stride, 1), pad
    )
    return s / ones


def _apply_op(x, name, op, params, quant: bool):
    if op[0] == "conv":
        _, r, s, m, stride, pad = op
        p = params[name]
        if quant:
            x = q.fake_quant(x)  # dynamic uint8 activations (§IV-D)
            wq, wscale = q.quantize_per_channel(p["w"], axis=-1)
            p = dict(p, w=wq.astype(jnp.float32) * wscale)
        y = _conv(x, p, stride, pad)
        return jax.nn.relu(y)
    if op[0] in ("maxpool", "avgpool"):
        _, r, stride, pad = op
        return _pool(x, op[0], r, stride, pad)
    if op[0] == "split":
        outs = []
        for i, sub in enumerate(op[1:]):
            y = x
            for j, sop in enumerate(sub):
                y = _apply_op(y, f"{name}_s{i}_{j}", sop, params, quant)
            outs.append(y)
        return jnp.concatenate(outs, axis=-1)
    raise ValueError(op)


def apply(params: dict, x: jax.Array, quant: bool = False,
          config: InceptionConfig = FULL) -> jax.Array:
    """Forward pass.  x: [N, H, W, 3] float32 in [0,1].  Returns [N, classes]."""
    for name, op in config.stem:
        x = _apply_op(x, name, op, params, quant)
    for bname, branches in config.mixed:
        outs = []
        for bi, branch in enumerate(branches):
            y = x
            for oi, op in enumerate(branch):
                y = _apply_op(y, f"{bname}_b{bi}_{oi}", op, params, quant)
            outs.append(y)
        x = jnp.concatenate(outs, axis=-1)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    if quant:
        x = q.fake_quant(x)
    p = params["FullyConnected"]
    logits = x @ p["w"][0, 0] * p["scale"] + p["bias"]
    return logits


# ---------------------------------------------------------------------------
# End-to-end quantized forward pass THROUGH THE EMULATION (§IV-D pipeline):
# every conv/pool/fc runs on the packed bit-serial engine; activations stay
# *quantized uint8 residents* between layers.  The per-layer dynamic range is
# computed IN-CACHE by the nc_minmax log tree — only the two integer scalars
# per image leave the array, the CPU answers with a fixed-point multiplier +
# zero point, and the requantization runs back in-cache.  No CPU-side float
# min/max ever touches an activation tensor in the layer loop; the only
# offline float ranges are the static weights'.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NCLayerReport:
    """One emulated layer: arithmetic cycles charged by the engine next to
    the analytic model's serialized-pass cycles (paper-style)."""

    name: str
    kind: str
    out_shape: tuple
    emulated_cycles: int  # §III formulas per lane group (core/nc_layers.py)
    modeled_cycles: float  # calibrated per-pass model (core/simulator.py)
    serial_passes: int
    modeled_s: float  # modeled wall time incl. data movement
    lanes: int = 0
    zero_operand_lanes: int = 0  # EIE-style tag-skippable lanes (note only)
    batch: int = 1  # images folded into the packed lane axis
    minmax_cycles: int = 0  # §IV-D in-cache min/max tree (inside emulated)
    filter_loads: int = 0  # filter packs this batch (§VI-C residency: 1)
    skipped_passes: int = 0  # zero-filter passes the sparse plan dropped
    zero_filters: int = 0  # pruned filters the engine never ran
    overlap: bool = False  # §IV-E double buffering granted and executed
    integrity: bool = False  # ABFT checksum verification ran (PR 7)
    reexec_passes: int = 0  # fault-triggered pass re-executions
    faults_detected: int = 0  # verification mismatches caught
    quarantined_slices: tuple = ()  # slices retired by stuck-at recovery
    live_output_bytes: int = 0  # MEASURED max per-image non-zero-point
    # output bytes (conv only) — the warmup re-planner's observed occupancy


@dataclasses.dataclass(frozen=True)
class NCForwardReport:
    config_name: str
    layers: tuple[NCLayerReport, ...]
    batch: int = 1
    concat_requant_cycles: int = 0  # branch -> common-scale requant at concats

    @property
    def total_emulated_cycles(self) -> int:
        return sum(l.emulated_cycles for l in self.layers)

    @property
    def total_modeled_cycles(self) -> float:
        return sum(l.modeled_cycles for l in self.layers)

    @property
    def total_modeled_s(self) -> float:
        return sum(l.modeled_s for l in self.layers)

    @property
    def total_zero_operand_lanes(self) -> int:
        return sum(l.zero_operand_lanes for l in self.layers)

    @property
    def total_skipped_passes(self) -> int:
        return sum(l.skipped_passes for l in self.layers)

    def summary(self) -> str:
        """Paper-style per-layer cycle table (Figure 13 analogue)."""
        lines = [f"# {self.config_name}: per-layer cycles "
                 f"(emulated arithmetic | modeled passes)"]
        lines.append(f"{'layer':32s} {'kind':8s} {'emulated':>14s} "
                     f"{'modeled':>14s} {'passes':>7s} {'zero-lanes':>11s}")
        for l in self.layers:
            lines.append(
                f"{l.name:32s} {l.kind:8s} {l.emulated_cycles:14d} "
                f"{l.modeled_cycles:14.0f} {l.serial_passes:7d} "
                f"{l.zero_operand_lanes:11d}")
        lines.append(
            f"{'TOTAL':32s} {'':8s} {self.total_emulated_cycles:14d} "
            f"{self.total_modeled_cycles:14.0f} {'':7s} "
            f"{self.total_zero_operand_lanes:11d}")
        lines.append(f"# modeled latency {self.total_modeled_s * 1e3:.3f} ms")
        if self.total_skipped_passes:
            lines.append(f"# sparse schedule: {self.total_skipped_passes} "
                         f"zero-filter passes skipped per image")
        return "\n".join(lines)


_REQUANT_PASS_CYCLES = bs.mul_cycles(32) + bs.add_cycles(32)  # per lockstep pass


def prepare_conv_weights(params: dict, config: InceptionConfig) -> dict:
    """Offline weight quantization (the paper quantizes weights ahead of
    time — their float ranges are static and never enter the per-layer
    loop).  BN scale folds into the filter; bias is applied as an integer
    add in the requant epilogue.

    ``nc_forward`` calls this once per invocation by default; serving
    engines precompute it once and pass ``wpack=`` so resident filters are
    quantized exactly once per deployment, not once per batch."""
    packed = {}
    for name, _, _, _, _ in _iter_convs(config):
        p = params[name]
        wf = np.asarray(p["w"], np.float32) * np.asarray(p["scale"], np.float32)
        w_qp = q.choose_qparams(jnp.float32(wf.min()), jnp.float32(wf.max()))
        wq = nc._quantize_np(wf, w_qp).astype(np.uint8)
        packed[name] = (wq, w_qp, np.asarray(p["bias"], np.float32))
    return packed


# ---------------------------------------------------------------------------
# Value sparsity: occupancy metadata for the sparsity-aware scheduler.
# Filter occupancy is DETECTED from the quantized weights (deterministic —
# it earns exact skipped-pass credits); activation sparsity is an ESTIMATE
# threaded from the network structure (every conv output passes ReLU, so
# post-activation zeros are exact zeros in the uint8 resident format) and
# stays advisory: it sizes the EIE-style zero-operand word elision and the
# reports, never a cycle credit.
# ---------------------------------------------------------------------------
RELU_ZERO_FRACTION = 0.5  # prior for post-ReLU zeros (symmetric preactivation)


def _op_act_est(name, op, p_in, est):
    """Walk one op: record the conv's INPUT sparsity estimate, return the
    output estimate.  Pool zeros survive only when a whole window is zero
    (non-negative resident activations), so pools raise p to the window
    population; branch concats average their branches (an estimate — the
    channel weighting is not worth modeling)."""
    if op[0] == "conv":
        est[name] = p_in
        return RELU_ZERO_FRACTION
    if op[0] in ("maxpool", "avgpool"):
        _, r, stride, pad = op
        return float(p_in) ** (r * r)
    if op[0] == "split":
        outs = []
        for i, sub in enumerate(op[1:]):
            p = p_in
            for j, sop in enumerate(sub):
                p = _op_act_est(f"{name}_s{i}_{j}", sop, p, est)
            outs.append(p)
        return sum(outs) / len(outs)
    raise ValueError(op)


def activation_sparsity_estimates(config: InceptionConfig = REDUCED) -> dict:
    """ReLU-chain activation-sparsity estimates: for every conv/fc layer,
    the estimated fraction of exactly-zero INPUT activations (what the
    host engine's zero-operand word skipping can elide).  The input image
    is dense (0.0); the FC input comes through the global average pool, so
    it is effectively dense again."""
    est: dict[str, float] = {}
    p = 0.0  # raw image pixels
    for name, op in config.stem:
        p = _op_act_est(name, op, p, est)
    for bname, branches in config.mixed:
        outs = []
        for bi, branch in enumerate(branches):
            pb = p
            for oi, op in enumerate(branch):
                pb = _op_act_est(f"{bname}_b{bi}_{oi}", op, pb, est)
            outs.append(pb)
        p = sum(outs) / len(outs)
    est["FullyConnected"] = 0.0  # global avg of non-negative values
    return est


def network_occupancy(wpack: dict, config: InceptionConfig = REDUCED) -> dict:
    """Per-layer :class:`~repro.core.schedule.LayerOccupancy` from the
    quantized resident weights (:func:`prepare_conv_weights` output):
    zero-filter/dead-plane detection via the pack-time scan, with the
    ReLU-chain activation estimates threaded in.  Feed the result to
    ``plan_network(..., occupancy=...)`` to plan the pruned pass list."""
    est = activation_sparsity_estimates(config)
    occ = {}
    for name, r, s, c, m in _iter_convs(config):
        wq, w_qp, _ = wpack[name]
        rows = np.asarray(wq, np.int64).reshape(r * s * c, m).T
        occ[name] = sched.LayerOccupancy.from_filter_rows(
            rows, w_qp.bits, int(w_qp.zero_point),
            activation_sparsity=est.get(name, 0.0))
    return occ


def observed_occupancy(wpack: dict, config: InceptionConfig,
                       report: "NCForwardReport") -> dict:
    """Measured per-layer occupancy from a completed forward pass (PR 8
    warmup re-planning): the filter side re-runs the deterministic
    pack-time scan exactly like :func:`network_occupancy`, but the
    activation side is OBSERVED, not estimated — each conv's input
    sparsity comes from the engine's zero-operand lane counts and its
    ``live_outputs`` from the measured non-zero-point output bytes, so the
    §IV-D requant pass count shrinks to what the warmup batch actually
    produced.  The ReLU-chain estimate remains the prior for any layer the
    report did not cover."""
    est = activation_sparsity_estimates(config)
    by_name = {l.name: l for l in report.layers}
    occ = {}
    for name, r, s, c, m in _iter_convs(config):
        wq, w_qp, _ = wpack[name]
        rows = np.asarray(wq, np.int64).reshape(r * s * c, m).T
        rep = by_name.get(name)
        act = est.get(name, 0.0)
        live_out = None
        if rep is not None and rep.kind == "conv":
            if rep.lanes:
                act = rep.zero_operand_lanes / rep.lanes
            live_out = int(rep.live_output_bytes)
        base = sched.LayerOccupancy.from_filter_rows(
            rows, w_qp.bits, int(w_qp.zero_point), activation_sparsity=act)
        occ[name] = dataclasses.replace(base, live_outputs=live_out)
    return occ


def prune_wpack(wpack: dict, fraction: float = 0.5) -> dict:
    """Fixed filter pruning for the dense-vs-sparse gates: zero out (set to
    the quantized zero point) the LAST ``round(M * fraction)`` filters of
    every conv — the same last-k rule as ``schedule.prune_occupancy``, so
    a spec-driven plan matches what detection finds on these weights."""
    pruned = {}
    for name, (wq, w_qp, bias) in wpack.items():
        wq = np.array(wq, copy=True)
        k = int(round(wq.shape[-1] * fraction))
        if k:
            wq[..., wq.shape[-1] - k:] = int(w_qp.zero_point)
        pruned[name] = (wq, w_qp, bias)
    return pruned


def _requant_image(acc_b: np.ndarray, real_multiplier: float,
                   zero_point: int) -> np.ndarray:
    """In-cache fixed-point requantization of one image's int32 staging
    (§IV-D: integer multiply + round-shift, bit-exact with the shifter).
    Host int64 arithmetic — the jnp path truncates to int32 without
    ``jax_enable_x64`` and the 31-bit mantissa product needs 63 bits."""
    mult, shift = q.fixed_point_multiplier(jnp.float32(real_multiplier))
    mult, shift = int(mult), int(shift)
    rounded = (acc_b.astype(np.int64) * mult + (1 << (shift - 1))) >> shift
    return np.clip(rounded + zero_point, 0, 255).astype(np.uint8)


def _nc_run_conv(name, actq, act_qps, op, wpack, spec, plan, geom, const,
                 engine, records):
    _, r, s, m_, stride, pad = op
    wq, w_qp, bias = wpack[name]
    acc, cycles, stats = nc.nc_conv2d(
        actq, wq, act_qps, w_qp, stride, padding=pad, geom=geom,
        layer_spec=spec, plan=plan, engine=engine, return_stats=True)
    acc = np.asarray(acc, np.int64)  # [B, E, F, M] int32 staging
    B = acc.shape[0]
    # §IV-D epilogue, all in-cache: integer bias add (BN-folded), MSB-masked
    # ReLU, the min/max log tree, then fixed-point requant.  Only the two
    # integer scalars per image leave the array.
    sxw = np.array([np.float32(qp.scale) * np.float32(w_qp.scale)
                    for qp in act_qps], np.float64)
    bias_q = np.round(bias[None, :] / sxw[:, None]).astype(np.int64)  # (B, M)
    acc = np.maximum(acc + bias_q[:, None, None, :], 0)
    mn, mx, c_mm = nc.nc_minmax(acc.reshape(B, -1), bits=32, signed=True)
    cycles += int(c_mm)
    yq = np.empty(acc.shape, np.uint8)
    out_qps = []
    for b in range(B):
        # the CPU-side scalar step: two integers in, multiplier + zp out
        qp = q.choose_qparams(jnp.float32(mn[b] * sxw[b]),
                              jnp.float32(mx[b] * sxw[b]))
        yq[b] = _requant_image(acc[b], sxw[b] / float(qp.scale),
                               int(qp.zero_point))
        out_qps.append(qp)
    cycles += B * plan.quant_passes * _REQUANT_PASS_CYCLES
    # measured output occupancy for warmup re-planning: a lane holding the
    # image's zero point is an exact zero activation, so the max over the
    # batch of live (non-zero-point) output bytes is what the §IV-D
    # requant passes must actually cover
    live_out = max(int((yq[b] != int(out_qps[b].zero_point)).sum())
                   for b in range(B))
    # quarantine re-plans mid-layer: price the plan the engine actually
    # executed, plus the exact per-pass price of each fault re-execution
    eff_plan = stats.plan if stats.plan is not None else plan
    modeled = sim.modeled_layer_cycles(eff_plan, geom, const)
    records.append(NCLayerReport(
        name=name, kind="conv", out_shape=tuple(yq.shape),
        emulated_cycles=int(cycles),
        modeled_cycles=(modeled["total_cycles"]
                        + stats.reexec_passes * modeled["reexec_pass_cycles"]),
        serial_passes=modeled["serial_passes"], modeled_s=modeled["total_s"],
        lanes=stats.lanes, zero_operand_lanes=stats.zero_operand_lanes,
        batch=B, minmax_cycles=int(c_mm), filter_loads=stats.filter_loads,
        skipped_passes=modeled["skipped_passes"],
        zero_filters=stats.zero_filters, overlap=stats.overlap,
        integrity=stats.integrity, reexec_passes=stats.reexec_passes,
        faults_detected=stats.faults_detected,
        quarantined_slices=stats.quarantined_slices,
        live_output_bytes=live_out))
    return yq, out_qps


def _nc_run_pool(name, actq, act_qps, op, spec, geom, const, records):
    kind, r, stride, pad = op
    if kind == "maxpool":
        out_q, cycles = nc.nc_maxpool2d(actq, r, stride, padding=pad)
    else:
        out_q, cycles = nc.nc_avgpool2d(actq, r, stride, padding=pad)
    out_q = np.asarray(out_q, np.uint8)
    modeled = sim.modeled_layer_cycles(spec, geom, const)  # pools never skip
    records.append(NCLayerReport(
        name=name, kind=kind, out_shape=tuple(out_q.shape),
        emulated_cycles=int(cycles), modeled_cycles=modeled["total_cycles"],
        serial_passes=modeled["serial_passes"], modeled_s=modeled["total_s"],
        batch=out_q.shape[0]))
    # pooling is order/affine-transparent: quantization passes through
    return out_q, act_qps


def _nc_concat(outs, state):
    """Concatenate branch outputs along channels, requantizing every branch
    to a per-image common scale in-cache (branches carry their own dynamic
    ranges; the CPU sees only their qparams — scalars that already left)."""
    B = outs[0][0].shape[0]
    cat_qps = []
    pieces = [np.empty(yq.shape, np.uint8) for yq, _ in outs]
    for b in range(B):
        lo = min(float((qp.qmin - int(qp.zero_point)) * np.float32(qp.scale))
                 for _, qps in outs for qp in (qps[b],))
        hi = max(float((qp.qmax - int(qp.zero_point)) * np.float32(qp.scale))
                 for _, qps in outs for qp in (qps[b],))
        qp_c = q.choose_qparams(jnp.float32(lo), jnp.float32(hi))
        for i, (yq, qps) in enumerate(outs):
            qp_i = qps[b]
            accq = yq[b].astype(np.int64) - int(qp_i.zero_point)
            pieces[i][b] = _requant_image(
                accq, float(qp_i.scale) / float(qp_c.scale),
                int(qp_c.zero_point))
        cat_qps.append(qp_c)
    state["concat_requant_cycles"] += B * len(outs) * _REQUANT_PASS_CYCLES
    return np.concatenate(pieces, axis=-1), cat_qps


def _nc_apply_op(actq, act_qps, name, op, wpack, specs, plans, geom, const,
                 engine, records, state):
    if op[0] == "conv":
        return _nc_run_conv(name, actq, act_qps, op, wpack, specs[name],
                            plans[name], geom, const, engine, records)
    if op[0] in ("maxpool", "avgpool"):
        return _nc_run_pool(name, actq, act_qps, op, specs[name], geom,
                            const, records)
    if op[0] == "split":
        outs = []
        for i, sub in enumerate(op[1:]):
            yq, qps = actq, act_qps
            for j, sop in enumerate(sub):
                yq, qps = _nc_apply_op(yq, qps, f"{name}_s{i}_{j}", sop,
                                       wpack, specs, plans, geom, const,
                                       engine, records, state)
            outs.append((yq, qps))
        return _nc_concat(outs, state)
    raise ValueError(op)


def _nc_stage_gen(x4, config, wpack, specs, plans, geom, const, engine,
                  records, state):
    """Generator over the network's serial stages (§IV-E layer order): one
    yield per stem op, per mixed block, and for the final pool + FC.

    This is the hook for cross-layer streaming: ``nc_forward`` drains one
    generator straight through for a normal run, while ``stream_chunk``
    advances several chunk generators in a skewed wavefront (chunk i at
    stage t while chunk i+1 runs stage t-1 — layer L of one image set
    computes while the next set's layer L-1 loads).  ``state["logits"]``
    holds the float logits after exhaustion."""
    B = x4.shape[0]
    # §IV-D input quantization: images arrive as uint8 pixels — a static
    # [0, 1] range, no min/max ever computed on an activation tensor.
    actq = np.clip(np.round(x4 * np.float32(255.0)), 0, 255).astype(np.uint8)
    act_qps = [q.QuantParams(scale=np.float32(1.0 / 255.0), zero_point=0)] * B
    for name, op in config.stem:
        actq, act_qps = _nc_apply_op(actq, act_qps, name, op, wpack, specs,
                                     plans, geom, const, engine, records,
                                     state)
        yield name
    for bname, branches in config.mixed:
        outs = []
        for bi, branch in enumerate(branches):
            yq, qps = actq, act_qps
            for oi, op in enumerate(branch):
                yq, qps = _nc_apply_op(yq, qps, f"{bname}_b{bi}_{oi}", op,
                                       wpack, specs, plans, geom, const,
                                       engine, records, state)
            outs.append((yq, qps))
        actq, act_qps = _nc_concat(outs, state)
        yield bname
    # global average pool through the array, then FC as a 1x1 conv
    h = actq.shape[1]
    actq, act_qps = _nc_run_pool("AvgPool", actq, act_qps,
                                 ("avgpool", h, 1, "VALID"),
                                 specs["AvgPool"], geom, const, records)
    actq = actq.reshape(B, -1)
    wq, w_qp, fc_bias = wpack["FullyConnected"]
    spec = specs["FullyConnected"]
    acc, cycles, stats = nc.nc_fc(actq, wq[0, 0], act_qps, w_qp, geom=geom,
                                  layer_spec=spec,
                                  plan=plans["FullyConnected"],
                                  engine=engine, return_stats=True)
    sxw = np.array([np.float32(qp.scale) * np.float32(w_qp.scale)
                    for qp in act_qps], np.float32)
    logits = (np.asarray(acc, np.float32) * sxw[:, None]
              + fc_bias[None, :].astype(np.float32))
    eff_plan = (stats.plan if stats.plan is not None
                else plans["FullyConnected"])
    modeled = sim.modeled_layer_cycles(eff_plan, geom, const)
    records.append(NCLayerReport(
        name="FullyConnected", kind="fc", out_shape=tuple(logits.shape),
        emulated_cycles=int(cycles),
        modeled_cycles=(modeled["total_cycles"]
                        + stats.reexec_passes * modeled["reexec_pass_cycles"]),
        serial_passes=modeled["serial_passes"], modeled_s=modeled["total_s"],
        lanes=stats.lanes, zero_operand_lanes=stats.zero_operand_lanes,
        batch=x4.shape[0], filter_loads=stats.filter_loads,
        skipped_passes=modeled["skipped_passes"],
        zero_filters=stats.zero_filters, overlap=stats.overlap,
        integrity=stats.integrity, reexec_passes=stats.reexec_passes,
        faults_detected=stats.faults_detected,
        quarantined_slices=stats.quarantined_slices))
    state["logits"] = logits
    yield "FullyConnected"


def _merge_chunk_records(per_chunk: list[list[NCLayerReport]],
                         B: int) -> list[NCLayerReport]:
    """Merge per-chunk layer reports into whole-batch reports: emulated
    counters sum across chunks; modeled numbers are PER IMAGE and
    batch-independent, so the first chunk's stand for all.  Note
    ``filter_loads`` sums to the chunk count — cross-layer streaming packs
    each layer's filter grid once per CHUNK, trading §VI-C's once-per-batch
    residency for the wavefront (the reports keep that honest)."""
    merged = []
    for recs in zip(*per_chunk):
        r0 = recs[0]
        merged.append(dataclasses.replace(
            r0,
            out_shape=(B,) + tuple(r0.out_shape[1:]),
            emulated_cycles=sum(r.emulated_cycles for r in recs),
            lanes=sum(r.lanes for r in recs),
            zero_operand_lanes=sum(r.zero_operand_lanes for r in recs),
            batch=B,
            minmax_cycles=sum(r.minmax_cycles for r in recs),
            filter_loads=sum(r.filter_loads for r in recs),
            reexec_passes=sum(r.reexec_passes for r in recs),
            faults_detected=sum(r.faults_detected for r in recs),
            quarantined_slices=tuple(sorted(
                {s for r in recs for s in r.quarantined_slices})),
            live_output_bytes=max(r.live_output_bytes for r in recs),
        ))
    return merged


def nc_forward(params: dict, x: jax.Array,
               config: InceptionConfig = REDUCED,
               geom: CacheGeometry = XEON_E5_35MB,
               const: sim.SimConstants = sim.SimConstants(),
               engine: str | None = None,
               schedule: sched.NetworkSchedule | None = None,
               wpack: dict | None = None,
               sparse: bool = False,
               overlap: bool = False,
               integrity: bool = False,
               compressed: bool = False,
               stream_chunk: int | None = None):
    """Quantized Inception forward pass through the bit-serial emulation.

    x: [H, W, 3] or batched [B, H, W, 3] float32 in [0, 1].  Every conv,
    pool and the FC run on the packed word engine, tiled by the layer's
    :class:`~repro.core.schedule.SlicePlan` with the batch folded into the
    packed lane axis (one MAC+reduce serves a whole batch tile, filters
    packed once per layer per batch — §VI-C residency).

    Activations stay quantized uint8 between layers; each layer's dynamic
    range comes from the IN-CACHE ``nc_minmax`` log tree (§IV-D) — only
    two integer scalars per image leave the array, and the requantization
    runs back in-cache as a fixed-point multiply.  Quantization is
    per-image, so batched outputs are bit-identical to single-image runs.

    ``engine`` names a registered backend (``core/backends.py``).
    ``engine=None`` resolves by the standing precedence: the schedule's
    ``backend`` pin (``plan_network(..., backend=...)``) > the
    ``NC_BACKEND`` environment variable > the bucketed-jit engine once
    the compilation cache amortizes (batch >= 2), else the host engine.
    An explicit engine that contradicts a backend-carrying schedule
    raises (the schedule already decided).
    ``schedule`` accepts a precomputed :class:`NetworkSchedule` (the
    serving path plans once per batch size); by default one is planned
    here, and the SAME object prices the run via
    ``simulator.simulate_network(schedule)``.  ``wpack`` accepts the
    output of :func:`prepare_conv_weights` so resident filters quantize
    once per deployment instead of once per call.

    ``sparse=True`` plans against the weights' detected value sparsity
    (:func:`network_occupancy`): zero-filter passes are dropped from the
    executed pass list and credited in the modeled cycles, with outputs
    BYTE-IDENTICAL to the dense run on the same weights (the pruned
    filters' outputs are exact affine constants).  A ``schedule`` built
    with occupancy implies the same; ``sparse`` only controls the plan
    made here.

    ``overlap=True`` plans §IV-E double buffering: every layer the
    legality rule grants streams pass k+1's filter columns while pass k's
    MAC+reduce runs (core/nc_layers.py's depth-1 pipeline), with logits
    byte-identical to the serial run.  Like ``sparse``, it only controls
    the plan made here — a precomputed ``schedule`` already decided, and
    combining the two raises.

    ``integrity=True`` plans ABFT checksum verification (PR 7): every
    executed pass is verified against exact column/row checksums, detected
    corruption triggers bounded re-execution (and stuck-slice quarantine +
    re-plan under an active ``core.faults`` scope), and the modeled cycles
    pay the additive ``checksum_pass_cycles`` term.  Logits stay
    byte-identical to the unchecked run — verification never perturbs the
    data path.  Like the other plan flags it raises when combined with an
    explicit ``schedule`` (build that with ``plan_network(...,
    integrity=True)`` instead).

    ``compressed=True`` plans CSR bit-plane filter residency (PR 8):
    every conv/fc layer's resident footprint shrinks to the live bit
    planes plus a per-plane live-column bitmap
    (``mapper.compressed_filter_bytes``), the engine stores and streams
    filters through :class:`~repro.core.bitserial.CompressedPlanes`, and
    the modeled time earns the exact residency credit (dense minus
    compressed at filter bandwidth).  Logits stay BYTE-IDENTICAL to the
    dense store — decompression scatters live columns into zero words,
    the multiply identity.  Like the other plan flags it raises when
    combined with an explicit ``schedule``.

    ``stream_chunk=N`` additionally streams the batch through the network
    in chunks of ``N`` images advanced in a skewed wavefront — layer L of
    chunk i computes while chunk i+1 runs layer L-1 (cross-layer §VI-C
    streaming).  Logits stay byte-identical (quantization is per-image),
    but each chunk packs its own filter grids (``filter_loads`` in the
    report sums to the chunk count) and plans its own chunk-sized
    schedule, so it is an experiment flag, not the serving default.

    Returns ``(logits [B?, classes], NCForwardReport)`` — the report pairs
    each layer's emulated arithmetic cycles (min/max tree included) with
    the analytic model's serialized-pass cycles and modeled wall time.
    """
    xin = np.asarray(x, np.float32)
    batched = xin.ndim == 4
    x4 = xin if batched else xin[None]
    assert x4.ndim == 4, "nc_forward takes [H, W, 3] or [B, H, W, 3]"
    B = x4.shape[0]
    if (engine is not None and schedule is not None
            and schedule.backend not in (None, engine)):
        raise ValueError("pick the backend through the schedule "
                         "(plan_network(..., backend=...)); engine= "
                         "contradicting a backend-carrying schedule is "
                         "ambiguous")
    if engine is None:
        if schedule is not None and schedule.backend is not None:
            engine = schedule.backend
        else:
            engine = _backends.env_backend() or ("jit" if B >= 2 else "host")
    else:
        engine = _backends.get_backend(engine).name
    specs_list = inception_v3_specs(config)
    specs = {s.name: s for s in specs_list}
    if wpack is None:
        wpack = prepare_conv_weights(params, config)
    if schedule is not None and overlap:
        raise ValueError("request overlap through the schedule "
                         "(plan_network(..., overlap=True)); overlap= with "
                         "an explicit schedule is ambiguous")
    if schedule is not None and integrity:
        raise ValueError("request integrity through the schedule "
                         "(plan_network(..., integrity=True)); integrity= "
                         "with an explicit schedule is ambiguous")
    if schedule is not None and compressed:
        raise ValueError("request compression through the schedule "
                         "(plan_network(..., compressed=True)); compressed= "
                         "with an explicit schedule is ambiguous")
    if schedule is not None and stream_chunk is not None:
        raise ValueError("stream_chunk replans per chunk; it cannot honor "
                         "an explicit whole-batch schedule")
    occ = (network_occupancy(wpack, config)
           if sparse and schedule is None else None)

    if stream_chunk is not None and stream_chunk < B:
        # cross-layer streaming: chunk generators advanced in a skewed
        # wavefront — chunk i runs stage t while chunk i+1 runs stage t-1
        chunks = [x4[i:i + stream_chunk] for i in range(0, B, stream_chunk)]
        per_records: list[list[NCLayerReport]] = []
        per_states: list[dict] = []
        gens = []
        for xc in chunks:
            sc = sched.plan_network(specs_list, geom, batch=xc.shape[0],
                                    occupancy=occ, overlap=overlap,
                                    integrity=integrity,
                                    compressed=compressed)
            recs: list[NCLayerReport] = []
            st = {"concat_requant_cycles": 0}
            per_records.append(recs)
            per_states.append(st)
            gens.append(_nc_stage_gen(
                xc, config, wpack, specs,
                {p.spec.name: p for p in sc.layers}, geom, const, engine,
                recs, st))
        waiting = list(gens)
        active: list = []
        while waiting or active:
            if waiting:
                active.append(waiting.pop(0))  # next chunk enters, 1 behind
            for g in list(active):
                try:
                    next(g)
                except StopIteration:
                    active.remove(g)
        logits = np.concatenate([st["logits"] for st in per_states], axis=0)
        report = NCForwardReport(
            config.name, tuple(_merge_chunk_records(per_records, B)),
            batch=B,
            concat_requant_cycles=sum(st["concat_requant_cycles"]
                                      for st in per_states))
        return jnp.asarray(logits if batched else logits[0]), report

    if schedule is None:
        schedule = sched.plan_network(specs_list, geom, batch=B,
                                      occupancy=occ, overlap=overlap,
                                      integrity=integrity,
                                      compressed=compressed)
    plans = {p.spec.name: p for p in schedule.layers}
    records: list[NCLayerReport] = []
    state = {"concat_requant_cycles": 0}
    for _ in _nc_stage_gen(x4, config, wpack, specs, plans, geom, const,
                           engine, records, state):
        pass
    report = NCForwardReport(config.name, tuple(records), batch=B,
                             concat_requant_cycles=state["concat_requant_cycles"])
    return jnp.asarray(state["logits"] if batched
                       else state["logits"][0]), report
