"""Stub modality frontends (per assignment: [audio]/[vlm] backbones only).

The real EnCodec / InternViT towers are out of scope; ``input_specs()``
provides precomputed frame/patch embeddings for vlm and token ids for the
EnCodec-token (audio) decoder.  These stubs make the examples runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def stub_embeddings(cfg: ModelConfig, key, batch: int, seq_len: int) -> jax.Array:
    """Precomputed patch/frame embeddings stand-in: [B, S, d_model]."""
    return (jax.random.normal(key, (batch, seq_len, cfg.d_model), jnp.float32)
            * 0.02).astype(cfg.jdtype)


def stub_tokens(cfg: ModelConfig, key, batch: int, seq_len: int) -> jax.Array:
    """EnCodec-style token ids: [B, S] in [0, vocab)."""
    return jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size, jnp.int32)
