"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Three execution forms, equivalence-tested against each other:
  * ``ssd_chunked``  — the blocked quadratic-within-chunk / recurrent-across-
    chunk algorithm (training / prefill; O(T·Q) with chunk Q),
  * ``ssd_recurrent``— the pure step-by-step recurrence (oracle in tests),
  * ``step``         — single-token decode with (conv_state, ssm_state),
    O(1) in context length (this is why the SSM archs run long_500k).

State layout: h [B, n_heads, head_dim(P), state(N)]; B/C shared across heads
(ngroups=1).  SSD math runs in float32 regardless of the model dtype.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm

__all__ = ["mamba_init", "mamba_apply", "mamba_step", "mamba_cache_init",
           "ssd_chunked", "ssd_recurrent"]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def mamba_init(cfg: ModelConfig, key) -> dict:
    dt = cfg.jdtype
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus^-1-ish small dt
        "norm_w": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[3], di, d, dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: [B,T,C], w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def _segsum_decay(a):
    """a: [..., Q] log-decays -> L [..., Q, Q] with L[i,j]=exp(sum_{j<k<=i} a_k),
    zero above the diagonal."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    Q = a.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: upper-triangle diffs are large-positive, and
    # where(mask, exp(diff), 0) would propagate 0*inf = NaN in the backward.
    diff = jnp.where(mask, diff, -jnp.inf)
    return jnp.exp(diff)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_recurrent(x, dt, A, Bm, Cm, D, h0=None):
    """Oracle recurrence.  x:[B,T,nh,P] dt:[B,T,nh] A:[nh] B/C:[B,T,N].
    Returns (y [B,T,nh,P], h_final [B,nh,P,N])."""
    Bsz, T, nh, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((Bsz, nh, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, bt, ct = inp  # [B,nh,P],[B,nh],[B,N],[B,N]
        decay = jnp.exp(dtt * A[None, :])  # [B,nh]
        upd = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct) + D[None, :, None] * xt
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3), h


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, h0=None):
    """Blocked SSD (Mamba-2 §6): quadratic attention within chunks, linear
    recurrence across chunk boundaries.  Same signature as ssd_recurrent."""
    Bsz, T, nh, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = x.shape[1]
    nc = Tp // Q

    xc = x.reshape(Bsz, nc, Q, nh, P)
    dtc = dt.reshape(Bsz, nc, Q, nh)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A[None, None, None, :]  # [B,nc,Q,nh] log-decay per step
    a_h = a.transpose(0, 1, 3, 2)  # [B,nc,nh,Q]
    cs = jnp.cumsum(a_h, axis=-1)  # inclusive
    L = _segsum_decay(a_h)  # [B,nc,nh,Q,Q]

    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # [B,nc,Q,Q]
    xdt = xc * dtc[..., None]  # [B,nc,Q,nh,P]
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, L, xdt)

    # chunk-final states
    decay_end = jnp.exp(cs[..., -1:] - cs)  # [B,nc,nh,Q]
    S = jnp.einsum("bckn,bchk,bckhp->bchpn", Bc, decay_end, xdt)

    # inter-chunk recurrence over nc (linear scan; nc is small)
    a_sum = jnp.exp(cs[..., -1])  # [B,nc,nh] total chunk decay

    def boundary(h, inp):
        s_c, decay_c = inp  # [B,nh,P,N], [B,nh]
        h_next = h * decay_c[..., None, None] + s_c
        return h_next, h  # emit state *entering* the chunk

    h_init = jnp.zeros((Bsz, nh, P, N), jnp.float32) if h0 is None else h0
    h_last, h_in = jax.lax.scan(
        boundary, h_init,
        (S.transpose(1, 0, 2, 3, 4), a_sum.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # [B,nc,nh,P,N]

    # inter-chunk contribution
    decay_in = jnp.exp(cs)  # decay from chunk start to each position
    y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cc, h_in, decay_in)

    y = (y_diag + y_off).reshape(Bsz, Tp, nh, P)[:, :T]
    y = y + D[None, None, :, None] * x[:, :T]
    return y, h_last


# ---------------------------------------------------------------------------
# full mixer
# ---------------------------------------------------------------------------
def _split_proj(cfg: ModelConfig, zxbcdt):
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xBC, dt


def mamba_apply(cfg: ModelConfig, p: dict, u, cache=None):
    """u: [B,T,d] -> [B,T,d].  If cache given (prefill), returns new cache."""
    Bsz, T, _ = u.shape
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = u @ p["in_proj"]
    z, xBC_pre, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"], p["conv_b"]))
    x = xBC[..., :di].reshape(Bsz, T, nh, P).astype(jnp.float32)
    Bm = xBC[..., di : di + N].astype(jnp.float32)
    Cm = xBC[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = ssd_chunked(x, dt, A, Bm, Cm, p["D"], cfg.ssm_chunk)
    y = y.reshape(Bsz, T, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        K = cfg.ssm_conv
        tail = xBC_pre[:, -(K - 1):]  # pre-conv stream feeds the decode conv
        pad = (K - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        new_cache = {"conv": tail.astype(cfg.jdtype), "ssm": h_last}
    return out, new_cache


def mamba_step(cfg: ModelConfig, p: dict, u, cache):
    """u: [B,1,d], cache: {conv [B,K-1,ch], ssm [B,nh,P,N]} -> (out, cache)."""
    Bsz = u.shape[0]
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = u[:, 0] @ p["in_proj"]  # [B, ...]
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt[:, None])
    z, xBC, dt_raw = z[:, 0], xBC[:, 0], dt_raw[:, 0]

    conv_in = jnp.concatenate([cache["conv"].astype(jnp.float32),
                               xBC[:, None].astype(jnp.float32)], axis=1)
    xBC_c = jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"].astype(jnp.float32))
    xBC_c = jax.nn.silu(xBC_c + p["conv_b"].astype(jnp.float32))

    x = xBC_c[:, :di].reshape(Bsz, nh, P)
    Bm = xBC_c[:, di : di + N]
    Cm = xBC_c[:, di + N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    A = -jnp.exp(p["A_log"])

    h = cache["ssm"]
    decay = jnp.exp(dt * A[None, :])
    h = h * decay[..., None, None] + jnp.einsum("bhp,bn,bh->bhpn", x, Bm, dt)
    y = jnp.einsum("bhpn,bn->bhp", h, Cm) + p["D"][None, :, None] * x
    y = y.reshape(Bsz, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = (y @ p["out_proj"])[:, None]
    new_conv = jnp.concatenate([cache["conv"][:, 1:], xBC[:, None].astype(cfg.jdtype)], axis=1)
    return out, {"conv": new_conv, "ssm": h}


def mamba_cache_init(cfg: ModelConfig, batch: int) -> dict:
    di, N = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), cfg.jdtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }
