"""Mixture-of-Experts layer: GShard-style einsum dispatch (default) and a
gather/scatter alternative, both capacity-based with top-k renormalization.

EP sharding: the expert axis of the stacked expert weights maps to the
"model" mesh axis; token groups ride the "data" axis, so GSPMD materializes
the dispatch as all-to-all-class collectives.  The einsum path is the
GShard-faithful baseline; the scatter path removes the dispatch-einsum FLOPs
and is evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current_abstract_mesh
from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_apply"]


def _ep_axes(cfg: ModelConfig):
    """(group_axes, expert_axis) for EP sharding constraints, from the
    launcher-set act_spec.  Groups ride the non-expert batch axes; experts
    ride 'model'.  None when unconstrained (tests, single device)."""
    if cfg.act_spec is None:
        return None, None
    mesh = current_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return None, None
    b = cfg.act_spec[0]
    flat = b if isinstance(b, tuple) else ((b,) if b else ())
    if "model" not in flat:
        return None, None
    g = tuple(a for a in flat if a != "model") or None
    return g, "model"


def _constrain_ep(cfg: ModelConfig, xe):
    """xe: [G, E, C, d] expert-major buffer -> groups x data, experts x model.

    Anchors the all-to-all dispatch layout.  Without it GSPMD is free to
    replicate the stacked expert weights instead of exchanging tokens —
    measured as a 3.9 TB/device arctic-480b dry-run before this constraint.
    """
    g, e = _ep_axes(cfg)
    if e is None:
        return xe
    P = jax.sharding.PartitionSpec
    return jax.lax.with_sharding_constraint(xe, P(g, e, None, None))


def moe_init(cfg: ModelConfig, key) -> dict:
    dt = cfg.jdtype
    ks = jax.random.split(key, 4)
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    import math
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) / math.sqrt(d)).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) / math.sqrt(d)).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) / math.sqrt(ff)).astype(dt),
    }
    return p


def _router(cfg: ModelConfig, p: dict, x):
    """x: [..., d] -> (probs [..., E]) in f32."""
    logits = x.astype(jnp.float32) @ p["router"]
    return jax.nn.softmax(logits, axis=-1)


def _topk(probs, k: int):
    """Returns (weights [..., k], indices [..., k]) renormalized over top-k."""
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx


def _expert_ffn(cfg: ModelConfig, p: dict, xe):
    """xe: [..., E, C, d] -> [..., E, C, d] through per-expert SwiGLU."""
    h = jnp.einsum("...ecd,edf->...ecf", xe, p["wi"])
    g = jnp.einsum("...ecd,edf->...ecf", xe, p["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("...ecf,efd->...ecd", h, p["wo"])


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def _group(cfg: ModelConfig, x):
    """[B,T,d] -> ([G,S,d], valid [G,S], ungroup fn).  Pads to whole groups;
    padded slots are masked out of routing so they never consume capacity."""
    B, T, d = x.shape
    flat = x.reshape(B * T, d)
    S = min(cfg.moe_group_size, B * T)
    pad = (-(B * T)) % S
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    G = flat.shape[0] // S
    valid = (jnp.arange(G * S) < B * T).reshape(G, S)

    def ungroup(y):
        return y.reshape(G * S, d)[: B * T].reshape(B, T, d)

    return flat.reshape(G, S, d), valid, S, G, ungroup


def moe_apply_einsum(cfg: ModelConfig, p: dict, x):
    """GShard dense-dispatch MoE.  x: [B, T, d] -> [B, T, d]."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xg, valid, S, G, ungroup = _group(cfg, x)
    C = _capacity(cfg, S)

    probs = _router(cfg, p, xg)  # [G,S,E]
    w, idx = _topk(probs, K)  # [G,S,K]
    w = w * valid[..., None]

    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [G,S,K,E]
    onehot = onehot * valid[..., None, None]  # padding takes no capacity
    flat = onehot.reshape(G, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0  # [G,S*K,E]
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, S, K)  # [G,S,K]
    keep = pos < C
    w = jnp.where(keep, w, 0.0)

    # dispatch/combine tensors [G,S,E,C]
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C).astype(jnp.int32), C,
                            dtype=jnp.float32)  # [G,S,K,C]
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, w)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    xe = _constrain_ep(cfg, xe)  # all-to-all: tokens to their expert shard
    ye = _constrain_ep(cfg, _expert_ffn(cfg, p, xe))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    return ungroup(y)


def moe_apply_scatter(cfg: ModelConfig, p: dict, x):
    """Gather/scatter MoE: no dispatch-einsum FLOPs (beyond-GShard path)."""
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xg, valid, S, G, ungroup = _group(cfg, x)
    C = _capacity(cfg, S)

    probs = _router(cfg, p, xg)
    w, idx = _topk(probs, K)  # [G,S,K]
    w = w * valid[..., None]

    flat_e = idx.reshape(G, S * K)
    flat_valid = jnp.repeat(valid, K, axis=1).reshape(G, S * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32) * flat_valid[..., None]
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # [G,S*K]
    keep = (pos < C) & flat_valid.astype(bool)
    pos_c = jnp.where(keep, pos, C)  # row C = overflow bin

    xr = jnp.repeat(xg, K, axis=1)  # [G,S*K,d] token per choice
    buf = jnp.zeros((G, E, C + 1, d), x.dtype)
    buf = buf.at[
        jnp.arange(G)[:, None], flat_e, pos_c
    ].add(xr, mode="drop")
    xe = _constrain_ep(cfg, buf[:, :, :C])
    ye = _constrain_ep(cfg, _expert_ffn(cfg, p, xe))  # [G,E,C,d]
    ye = jnp.pad(ye, ((0, 0), (0, 0), (0, 1), (0, 0)))
    out = ye[jnp.arange(G)[:, None], flat_e, pos_c]  # [G,S*K,d]
    out = out * jnp.where(keep, w.reshape(G, S * K), 0.0)[..., None].astype(x.dtype)
    y = out.reshape(G, S, K, d).sum(axis=2)
    return ungroup(y)


def moe_apply(cfg: ModelConfig, p: dict, x):
    if cfg.moe_impl == "scatter":
        return moe_apply_scatter(cfg, p, x)
    return moe_apply_einsum(cfg, p, x)
