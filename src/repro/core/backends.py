"""Backend registry for the packed bit-serial hot path (PR 10).

Three execution bodies exist for the packed MAC+reduce that
``bitserial.packed_dot_words`` exposes: the exact numpy host walk, the
bucketed-jit decoded-lane kernel, and the Pallas bit-serial GEMM
(``kernels/bitserial_matmul.py`` — previously only reachable as a
standalone matmul).  This module makes the choice explicit: ONE registry
of :class:`Backend` entries, looked up by name everywhere an
``engine=`` string used to be interpreted ad hoc.

Contract
--------

* **Backends re-time execution, never the model.**  A backend's
  ``dot_words`` returns VALUES only; modeled cycles are charged by
  ``bitserial.packed_dot_words`` from the unchanged §III formula
  (``bitserial.dot_cycles``) before dispatch, so cycle counts are
  bit-identical across backends *by construction*.
* **Byte-identity.**  Every registered backend must reproduce the host
  reference exactly (tests/test_backends.py runs the differential
  conformance harness over the full operating envelope).  A backend may
  delegate inputs outside its native envelope (capability flags below)
  to the host body — delegation is counted in :func:`dispatch_stats` so
  tests can assert the native path actually ran.
* **Selection is configuration.**  Precedence at every call site:
  explicit ``engine=`` argument > the plan's ``backend`` field
  (``schedule.plan_layer(backend=...)`` — the same plan-decision idiom
  as sparsity/overlap/integrity/compression) > the ``NC_BACKEND``
  environment variable > the caller's default.  An explicit engine
  that *contradicts* a backend-carrying plan raises (ambiguous).

Registered backends
-------------------

``host``
    The exact numpy bit-serial walk (``bitserial._dot_words_impl``) —
    the reference every other backend is checked against.  Handles any
    plane width, accumulator width and row layout; zero-operand word
    skipping (``bitserial.ZERO_SKIP``) lives here.
``jit``
    Bucketed compiled decoded-lane kernel: one XLA executable per
    (x planes, w planes, acc, K) bucket (``bitserial.engine_cache_info``
    reports the cache).  Falls back to host when the int32 decode could
    overflow.
``pallas-interpret``
    The byte-packed Pallas bit-serial GEMM (in-kernel shift+mask plane
    unpack, zero-plane-block skip; the W4A4 nibble kernel when both
    operands fit 4 planes) run through the Pallas interpreter on CPU.
    A real-TPU deployment is the SAME adapter with ``interpret=False``
    — ``kernels/ops.py`` flips that off ``ops.on_tpu()`` — registered
    as one new entry plus one bench refresh.  Inputs outside its native
    envelope (traced operands, rows sharing words — ``K <= 16`` —,
    > 8 planes, int32-overflow risk, non-separable broadcast grids,
    oversized tiles) delegate to host, exactly.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import numpy as np

from repro.core import bitserial as bs

__all__ = [
    "Backend",
    "ENV_VAR",
    "register_backend",
    "registered_backends",
    "get_backend",
    "env_backend",
    "default_backend",
    "resolve_backend",
    "dispatch_stats",
    "dispatch_stats_clear",
]

ENV_VAR = "NC_BACKEND"


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered execution body for the packed bit-serial dot.

    The capability flags describe the *native* envelope; inputs outside
    it are delegated to the host body (still byte-exact — see the module
    contract).  ``dot_words(xw, ww, *, K, acc_bits, materialize)``
    returns the integer row values only; cycles are charged by the
    caller (``bitserial.packed_dot_words``) so backends cannot perturb
    the cycle model."""

    name: str
    # accumulator widths executed natively (None = any)
    acc_bits: tuple[int, ...] | None
    w4a4: bool  # dedicated nibble-packed path for <=4-plane operands
    compressed_planes: bool  # consumes CSR-reconstructed filter tiles
    integrity: bool  # safe under the ABFT checked/fault-injected path
    # cap on one operand's word-grid size (None = unbounded)
    max_lane_words: int | None
    dot_words: Callable[..., np.ndarray]

    def supports_acc(self, acc_bits: int) -> bool:
        return self.acc_bits is None or acc_bits in self.acc_bits


_REGISTRY: dict[str, Backend] = {}
# per-backend dispatch counters: name -> [native, fallback-to-host]
_DISPATCH: dict[str, list[int]] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    _DISPATCH.setdefault(backend.name, [0, 0])
    return backend


def registered_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, source: str = "engine") -> Backend:
    """Look up a backend by name; unknown names raise a :class:`ValueError`
    that names every registered backend (the one error surfaced for a bad
    ``engine=`` string and a bad ``NC_BACKEND`` alike)."""
    backend = _REGISTRY.get(name)
    if backend is None:
        raise ValueError(
            f"unknown backend {name!r} (from {source}); registered "
            f"backends: {', '.join(registered_backends())}")
    return backend


def env_backend() -> str | None:
    """The ``NC_BACKEND`` environment selection, validated, or None when
    unset/empty."""
    name = os.environ.get(ENV_VAR)
    if not name:
        return None
    return get_backend(name, source=f"{ENV_VAR} environment variable").name


def default_backend() -> str:
    """``NC_BACKEND`` when set (validated), else the host reference."""
    return env_backend() or "host"


def resolve_backend(explicit: str | None = None,
                    plan_backend: str | None = None,
                    default: str | None = None) -> str:
    """Resolve the backend name by the standing precedence: explicit
    ``engine=`` > plan's ``backend`` field > ``NC_BACKEND`` > ``default``
    (the host reference when no default is given).  Callers raise on the
    ambiguous explicit-vs-plan combination *before* resolving; here an
    explicit name simply wins (they are checked equal upstream)."""
    if explicit is not None:
        return get_backend(explicit).name
    if plan_backend is not None:
        return get_backend(plan_backend, source="plan backend").name
    return env_backend() or (default if default is not None else "host")


def dispatch_stats() -> dict[str, dict[str, int]]:
    """Per-backend dispatch counters since the last clear:
    ``{name: {"native": n, "fallback": m}}`` — ``fallback`` counts calls
    delegated to the host body (inputs outside the native envelope)."""
    return {name: {"native": c[0], "fallback": c[1]}
            for name, c in _DISPATCH.items()}


def dispatch_stats_clear() -> None:
    for c in _DISPATCH.values():
        c[0] = c[1] = 0


def _note(name: str, native: bool) -> None:
    _DISPATCH[name][0 if native else 1] += 1


# ---------------------------------------------------------------------------
# host — the exact reference body
# ---------------------------------------------------------------------------
def _host_dot_words(xw, ww, *, K: int, acc_bits: int,
                    materialize: bool = True):
    _note("host", native=True)
    return bs._dot_words_impl(xw, ww, K=K, acc_bits=acc_bits)


# ---------------------------------------------------------------------------
# jit — bucketed compiled decoded-lane kernel (cache lives in bitserial so
# engine_cache_info/engine_cache_clear keep reporting it)
# ---------------------------------------------------------------------------
def _jit_dot_words(xw, ww, *, K: int, acc_bits: int, materialize: bool = True):
    import functools

    import jax
    import jax.numpy as jnp

    if bs._is_traced(xw, ww):
        _note("jit", native=False)
        return bs._dot_words_impl(xw, ww, K=K, acc_bits=acc_bits)
    max_sum = K * ((1 << xw.shape[0]) - 1) * ((1 << ww.shape[0]) - 1)
    if max_sum >= (1 << 31) and not jax.config.jax_enable_x64:
        # the traced decode saturates at int32 — stay exact on host
        _note("jit", native=False)
        return bs._dot_words_impl(xw, ww, K=K, acc_bits=acc_bits)
    key = (int(xw.shape[0]), int(ww.shape[0]), acc_bits, K)
    fn = bs._ENGINE_CACHE.get(key)
    if fn is None:
        fn = jax.jit(functools.partial(bs._dot_words_decoded, K=K,
                                       acc_bits=acc_bits))
        bs._ENGINE_CACHE[key] = fn
    _note("jit", native=True)
    out = fn(jnp.asarray(xw), jnp.asarray(ww))
    return np.asarray(out) if materialize else out


# ---------------------------------------------------------------------------
# pallas-interpret — the byte-packed Pallas GEMM as a word-grid adapter
# ---------------------------------------------------------------------------
def _decode_rows(words: np.ndarray, K: int) -> np.ndarray:
    """Row-aligned word grid ``(n, *grid, wpr)`` -> ``(*grid, K)`` int64
    lane values (P >= 32 layouts only: one row per grid element)."""
    n = words.shape[0]
    bits = bs._unpack_bits32_np(words)  # (n, *grid, wpr, 32)
    weights = (np.int64(1) << np.arange(n, dtype=np.int64)).reshape(
        (n,) + (1,) * (bits.ndim - 1))
    vals = (bits.astype(np.int64) * weights).sum(axis=0)  # (*grid, wpr, 32)
    return vals.reshape(vals.shape[:-2] + (-1,))[..., :K]


def _pallas_fallback_reason(xw, ww, *, K: int, acc_bits: int,
                            backend: Backend) -> str | None:
    import jax

    if bs._is_traced(xw, ww):
        return "traced operands"
    nx, nw = int(xw.shape[0]), int(ww.shape[0])
    if nx > 8 or nw > 8:
        return "more than 8 bit planes"
    if not backend.supports_acc(acc_bits):
        return f"acc_bits={acc_bits} outside {backend.acc_bits}"
    P, _, r = bs._row_layout(K)
    if r != 1:
        return "rows share words (K <= 16)"
    max_sum = K * ((1 << nx) - 1) * ((1 << nw) - 1)
    if max_sum >= (1 << 31) and not jax.config.jax_enable_x64:
        return "int32 accumulator overflow"
    cap = backend.max_lane_words
    if cap is not None and max(xw.size, ww.size) > cap:
        return "operand grid exceeds max_lane_words"
    gx, gw = xw.shape[1:-1], ww.shape[1:-1]
    if len(gx) != len(gw):
        return "grid ranks differ"
    if any(a > 1 and b > 1 for a, b in zip(gx, gw)):
        return "non-separable broadcast grids"
    return None


def _pallas_dot_words(xw, ww, *, K: int, acc_bits: int,
                      materialize: bool = True):
    """Adapter: decode the two row-aligned word grids to integer row
    matrices, run the byte-packed Pallas kernel (interpret mode off-TPU;
    the W4A4 nibble kernel when both operands fit 4 planes), and scatter
    the exact int32 accumulator back into the broadcast grid."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels import ref as kref

    backend = _REGISTRY["pallas-interpret"]
    reason = _pallas_fallback_reason(xw, ww, K=K, acc_bits=acc_bits,
                                     backend=backend)
    if reason is not None:
        _note("pallas-interpret", native=False)
        return bs._dot_words_impl(xw, ww, K=K, acc_bits=acc_bits)
    _note("pallas-interpret", native=True)

    nx, nw = int(xw.shape[0]), int(ww.shape[0])
    gx, gw = xw.shape[1:-1], ww.shape[1:-1]
    X = _decode_rows(np.asarray(xw), K).reshape(-1, K)  # [Rx, K]
    W = _decode_rows(np.asarray(ww), K).reshape(-1, K)  # [Rw, K]

    w4a4 = backend.w4a4 and nx <= 4 and nw <= 4 and K >= 2
    planes = kref.pack_bitplanes_bytes(jnp.asarray(W.T, jnp.int32), nw)
    if w4a4:
        x_nib = kref.pack_activation_nibbles(jnp.asarray(X, jnp.int8))
        out = ops.bitserial_matmul_exact(x_nib, planes, n_bits=nw,
                                         w4a4=True)
    else:
        out = ops.bitserial_matmul_exact(jnp.asarray(X, jnp.int32), planes,
                                         n_bits=nw)
    O = np.asarray(out, np.int64)  # [Rx, Rw] exact int32 accumulator

    # scatter back into the broadcast grid: each grid axis is owned by at
    # most one operand (separability checked above), so interleaving the
    # (gx_i, gw_i) axis pairs and merging each pair (one side is 1)
    # reproduces np.broadcast_shapes(gx, gw)
    n_axes = len(gx)
    O = O.reshape(tuple(gx) + tuple(gw))
    O = O.transpose([a for i in range(n_axes) for a in (i, n_axes + i)])
    return O.reshape(np.broadcast_shapes(gx, gw))


register_backend(Backend(
    name="host", acc_bits=None, w4a4=True, compressed_planes=True,
    integrity=True, max_lane_words=None, dot_words=_host_dot_words))
register_backend(Backend(
    name="jit", acc_bits=None, w4a4=True, compressed_planes=True,
    integrity=True, max_lane_words=None, dot_words=_jit_dot_words))
register_backend(Backend(
    name="pallas-interpret", acc_bits=(24, 32), w4a4=True,
    compressed_planes=True, integrity=True, max_lane_words=1 << 22,
    dot_words=_pallas_dot_words))
