"""Data-layout mapper: DNN layers -> cache geometry (paper §IV-A/B).

Implements the paper's mapping algorithm:
  * filter splitting  — filters larger than 9 bytes split across bit lines,
  * filter packing    — 1x1 filters pack up to 16 channels per bit line,
  * channel rounding  — effective channels rounded up to a power of two
                        (zero padding), guaranteed to fit in <=2 arrays
                        (512 bit lines) that share sense amps,
  * replication       — filters replicated across arrays/ways/slices so all
                        M x E x E convolutions run in parallel to the extent
                        the geometry allows; the remainder is serialized.

Validated against the paper's two worked examples:
  Conv2D_2b_3x3 (R x S=9, C=32, M=64, E=147): 8 filters/array, 32,256 parallel,
  43 serial passes, 99.7% utilization (§VI-A).
  Figure-9 layer (R x S=9, C=128, M=32, E=32): 2 filters/array, 18x32/slice,
  ~4 serial passes (§IV-B).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Literal

from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB

__all__ = ["LayerSpec", "MappedLayer", "map_layer", "map_network",
           "serial_passes_for", "compressed_filter_bytes"]

MAX_FILTER_BYTES_PER_LINE = 9  # filter splitting threshold (§IV-A)
MAX_PACK_BYTES = 16  # 1x1 filter packing factor (§IV-A)
MAX_REDUCE_LINES = 512  # two arrays sharing sense amps (§III-D)

LayerKind = Literal["conv", "fc", "maxpool", "avgpool"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Geometry of one layer (one *branch* of a mixed block is one spec)."""

    name: str
    kind: LayerKind
    H: int  # input height (=width)
    R: int  # filter height
    S: int  # filter width
    C: int  # input channels
    M: int  # output channels (filter batches)
    E: int  # output height (=width)
    stride: int = 1
    block: str = ""  # mixed-block grouping for per-layer reports

    @property
    def filter_elems(self) -> int:
        return self.R * self.S

    @property
    def conv_count(self) -> int:
        """One convolution per output element (paper Table I 'Conv')."""
        return self.M * self.E * self.E if self.kind in ("conv", "fc") else 0

    @property
    def window_count(self) -> int:
        """Pooling windows (pooling layers do comparisons, not MACs)."""
        return self.M * self.E * self.E if self.kind in ("maxpool", "avgpool") else 0

    @property
    def filter_bytes(self) -> int:
        return self.R * self.S * self.C * self.M if self.kind in ("conv", "fc") else 0

    @property
    def input_bytes(self) -> int:
        return self.H * self.H * self.C

    @property
    def output_bytes(self) -> int:
        return self.M * self.E * self.E


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


def serial_passes_for(work: int, parallel: int) -> int:
    """Serialized passes to cover ``work`` convolutions/windows at
    ``parallel`` per pass (§IV-B) — 0 when there is no work at all.

    The ONE serialization rule shared by :func:`map_layer` (dense pass
    counts) and core/schedule.py's sparsity-aware planner (pass counts over
    the pruned filter set), so mapper and scheduler can never disagree on
    how work rounds up into passes."""
    if work <= 0:
        return 0
    return max(1, math.ceil(work / max(parallel, 1)))


def pass_filter_bytes(filter_bytes: int, passes: int) -> int:
    """Filter bytes streamed per serialized pass when a layer's load is
    spread over its pass sequence (§IV-E double buffering) — 0 when the
    layer loads nothing.

    The ONE per-pass filter-streaming rule shared by core/schedule.py's
    overlap-legality decision (does one pass's worth of columns fit the
    reserved I/O way?) and core/simulator.py's prologue pricing (the first
    pass's load can never hide), so scheduler and simulator can never
    disagree on how a layer's filter bytes split across passes."""
    if filter_bytes <= 0:
        return 0
    return math.ceil(filter_bytes / max(passes, 1))


def compressed_filter_bytes(resident_bytes: int, total_filters: int,
                            plane_bits: int = 8,
                            live_planes: int | None = None) -> int:
    """Resident bytes of the CSR bit-plane filter store (EIE-style
    compressed §IV-A residency) — 0 when the layer loads nothing.

    ``resident_bytes`` is the uncompressed residency of the live filter
    set (pruned columns are already not stored).  Compression keeps only
    the ``live_planes`` bit planes that contain any set bit — the payload
    scales by the live-plane fraction — plus, per live plane, a
    live-column bitmap over the layer's ``total_filters`` columns (the
    CSR index: one bit per filter column, byte-rounded).

    The ONE compressed-residency rule shared by core/schedule.py's
    ``plan_layer(compressed=True)`` (residency, per-pass streaming and
    overlap headroom all derive from it) and the simulator's residency
    credit (dense − compressed priced at filter bandwidth), so planner
    and pricer can never disagree on what compression saves."""
    if resident_bytes <= 0:
        return 0
    if live_planes is None:
        live_planes = plane_bits
    live_planes = max(0, min(int(live_planes), int(plane_bits)))
    payload = math.ceil(resident_bytes * live_planes / max(plane_bits, 1))
    index = live_planes * math.ceil(max(total_filters, 1) / 8)
    return payload + index


@dataclasses.dataclass(frozen=True)
class MappedLayer:
    spec: LayerSpec
    split_factor: int  # filter split across bit lines
    pack_factor: int  # channels packed per bit line (1x1 filters)
    line_filter_bytes: int  # R'xS': filter bytes held by one bit line
    eff_channels: int  # C' after split/pack
    channels_rounded: int  # next pow2, <= MAX_REDUCE_LINES
    lines_per_filter: int  # bit lines holding one logical filter
    filters_per_array: float  # parallel convolutions per 8KB array (0.5 = 2 arrays)
    parallel_convs: int  # across the whole cache
    serial_passes: int
    utilization: float

    @property
    def reduction_steps(self) -> int:
        return int(math.log2(self.channels_rounded)) if self.channels_rounded > 1 else 0

    @property
    def macs_per_line(self) -> int:
        """8-bit MACs each bit line performs per output (R'xS')."""
        return self.line_filter_bytes


def map_layer(spec: LayerSpec, geom: CacheGeometry = XEON_E5_35MB) -> MappedLayer:
    if spec.kind in ("maxpool", "avgpool"):
        # pooling maps like conv but with no filters (§IV-D): window elems
        # occupy lines; comparisons happen per line-group of C channels.
        work = spec.window_count
        c_round = min(_next_pow2(max(spec.filter_elems, 1)), MAX_REDUCE_LINES)
        per_array = max(geom.array_cols // c_round, 1)
        parallel = geom.compute_arrays * per_array
        serial = serial_passes_for(work, parallel) if work else 1
        util = work / (serial * parallel) if work else 0.0
        return MappedLayer(
            spec, 1, 1, spec.filter_elems, spec.C or spec.M, c_round,
            c_round, per_array, parallel, serial, util,
        )

    f = spec.filter_elems
    if f > MAX_FILTER_BYTES_PER_LINE:
        split = math.ceil(f / MAX_FILTER_BYTES_PER_LINE)
        line_bytes = math.ceil(f / split)
        pack = 1
        eff_c = spec.C * split
    elif f == 1:
        split = 1
        pack = min(MAX_PACK_BYTES, max(spec.C, 1))
        line_bytes = pack
        eff_c = math.ceil(spec.C / pack)
    else:
        split, pack, line_bytes, eff_c = 1, 1, f, spec.C

    c_round = _next_pow2(max(eff_c, 1))
    if c_round > MAX_REDUCE_LINES:
        raise ValueError(
            f"{spec.name}: {c_round} reduce lines exceed the 2-array sense-amp "
            f"domain; increase packing"
        )

    if c_round <= geom.array_cols:
        # §IV-B: uniformity over utilization — every array holds the *same*
        # set of (distinct-M) filters, so slots beyond M stay idle.
        per_array = min(geom.array_cols // c_round, spec.M)
    else:  # one filter spans two arrays sharing sense amps
        per_array = geom.array_cols / c_round  # 0.5

    parallel = int(geom.compute_arrays * per_array)
    # degenerate specs (conv_count == 0) still map to one idle pass
    serial = serial_passes_for(spec.conv_count, parallel) or 1
    util = spec.conv_count / (serial * parallel)
    return MappedLayer(
        spec, split, pack, line_bytes, eff_c, c_round,
        c_round, per_array, parallel, serial, util,
    )


def check_wordline_budget(m: MappedLayer, geom: CacheGeometry = XEON_E5_35MB) -> int:
    """Word lines used by one bit line's working set (Figure 10): filter +
    streamed input + 3B partial sum + 2B scratch.  Returns free lines
    (>=0 required; the slack stores outputs + reused inputs).

    Consulted by the conv tiler (core/nc_layers.py) before any lanes are
    allocated: a layer that overflows the budget raises here, with the
    offending spec, instead of silently over-allocating word lines the
    modeled array does not have."""
    filt = m.line_filter_bytes * 8
    inp = 8 if m.pack_factor > 1 else m.line_filter_bytes * 8  # §IV-A: 1x1 streams 1B
    used = filt + inp + 3 * 8 + 2 * 8
    free = geom.array_rows - used
    if free < 0:
        raise ValueError(
            f"word-line budget exceeded: {used} lines needed, {geom.array_rows} "
            f"per array ({geom.name}); split the filter further or shrink the "
            f"working set — offending layer: {m.spec}")
    return free


def map_network(
    specs: Iterable[LayerSpec], geom: CacheGeometry = XEON_E5_35MB
) -> list[MappedLayer]:
    mapped = [map_layer(s, geom) for s in specs]
    for m in mapped:
        if m.spec.kind in ("conv", "fc"):
            check_wordline_budget(m, geom)
    return mapped
