"""Affine quantization — the paper's in-cache 8-bit pipeline (§IV-D).

Neural Cache runs all layers on unsigned 8-bit operands.  After each layer it
(1) reduces min/max over every output element in-cache, (2) ships the two
scalars to the CPU which computes a fixed-point multiplier + zero point, and
(3) requantizes every element in-cache with integer multiply/add/shift.

This module implements that pipeline both in float (production path) and in
pure integer fixed-point (bit-exact with what the in-cache shifter does),
plus per-channel weight quantization used by the TPU kernels.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantParams",
    "choose_qparams",
    "quantize",
    "dequantize",
    "quantize_per_channel",
    "requantize_fixedpoint",
    "fixed_point_multiplier",
    "fake_quant",
]


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine (asymmetric) quantization: real = scale * (q - zero_point)."""

    scale: jax.Array | float
    zero_point: jax.Array | int
    bits: int = 8
    signed: bool = False

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1


def choose_qparams(
    x_min: jax.Array, x_max: jax.Array, bits: int = 8, signed: bool = False
) -> QuantParams:
    """The paper's CPU-side scalar step: min/max -> (scale, zero_point).

    Follows the TF-Lite/gemmlowp convention: the range always includes 0 so
    that zero is exactly representable (padding / ReLU correctness).
    """
    x_min = jnp.minimum(x_min, 0.0)
    x_max = jnp.maximum(x_max, 0.0)
    qmin = -(1 << (bits - 1)) if signed else 0
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = (x_max - x_min) / (qmax - qmin)
    scale = jnp.where(scale <= 0, 1.0, scale)
    zp = jnp.clip(jnp.round(qmin - x_min / scale), qmin, qmax).astype(jnp.int32)
    return QuantParams(scale=scale, zero_point=zp, bits=bits, signed=signed)


def choose_qparams_symmetric(x_absmax: jax.Array, bits: int = 8) -> QuantParams:
    """Symmetric signed quantization (zero_point = 0) — the W8A8 kernel
    activation convention (the affine zero-point correction is instead a
    weight-sum epilogue term; see repro/quant/qlinear.py)."""
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(x_absmax, 1e-12) / qmax
    return QuantParams(scale=scale, zero_point=0, bits=bits, signed=True)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    q = jnp.round(x / qp.scale) + qp.zero_point
    q = jnp.clip(q, qp.qmin, qp.qmax)
    return q.astype(jnp.int8 if qp.signed else jnp.uint8)


def dequantize(q: jax.Array, qp: QuantParams) -> jax.Array:
    return (q.astype(jnp.float32) - qp.zero_point) * qp.scale


def fake_quant(x: jax.Array, bits: int = 8, signed: bool = False) -> jax.Array:
    """Quantize-dequantize roundtrip (per-tensor, dynamic min/max)."""
    qp = choose_qparams(jnp.min(x), jnp.max(x), bits=bits, signed=signed)
    return dequantize(quantize(x, qp), qp)


def quantize_per_channel(w: jax.Array, axis: int = -1, bits: int = 8):
    """Symmetric per-channel weight quantization (TPU kernel path).

    Returns (int8 weights, float32 scales broadcastable against ``w``).
    """
    amax = jnp.max(jnp.abs(w), axis=tuple(i for i in range(w.ndim) if i != axis % w.ndim), keepdims=True)
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Integer-only requantization — what the in-cache shifter actually executes.
# ---------------------------------------------------------------------------
def fixed_point_multiplier(real_multiplier: jax.Array, bits: int = 31):
    """Decompose a positive real multiplier < 1 into (int32 mantissa, right shift).

    gemmlowp's ``QuantizeMultiplierSmallerThanOne``: real = m * 2^-s with
    m in [2^30, 2^31).  These two integers are the "two unsigned integers
    sent back by the CPU" in §IV-D.
    """
    real_multiplier = jnp.asarray(real_multiplier, jnp.float32)
    # exponent such that mantissa in [0.5, 1)
    exp = jnp.ceil(jnp.log2(real_multiplier))
    shift = (-exp).astype(jnp.int32) + bits
    m = jnp.round(real_multiplier * (2.0 ** shift.astype(jnp.float32)))
    m = jnp.clip(m, 0, (1 << bits) - 1).astype(jnp.int64)
    return m, shift


def requantize_fixedpoint(
    acc: jax.Array,
    multiplier: jax.Array,
    shift: jax.Array,
    zero_point: jax.Array | int = 0,
    qmin: int = 0,
    qmax: int = 255,
) -> jax.Array:
    """int32 accumulator -> n-bit output using integer multiply + round-shift.

    Bit-exact with the in-cache multiply/add/shift sequence (§IV-D) and with
    gemmlowp's rounding-doubling-free variant: out = (acc * m + 2^(s-1)) >> s.
    """
    acc = acc.astype(jnp.int64)
    m = multiplier.astype(jnp.int64)
    s = shift.astype(jnp.int64)
    rounded = (acc * m + (jnp.int64(1) << (s - 1))) >> s
    out = rounded + zero_point
    return jnp.clip(out, qmin, qmax).astype(jnp.int32)


def requantize_reference(
    acc: jax.Array, real_multiplier: jax.Array, zero_point=0, qmin=0, qmax=255
) -> jax.Array:
    """Float reference for :func:`requantize_fixedpoint` (tests only)."""
    out = jnp.round(acc.astype(jnp.float32) * real_multiplier) + zero_point
    return jnp.clip(out, qmin, qmax).astype(jnp.int32)
