"""SLO-aware serving control: the cycle model as the admission input.

The paper's throughput claims (§VI-C, Figure 13/16) assume batches sized so
filters stay resident while images stream.  Production inference, though, is
governed by tail-latency bounds, not raw throughput (Jouppi et al., the TPU
datacenter paper: requests carry a 99th-percentile deadline and the server
picks the largest batch that still meets it).  This module closes that loop
for the Neural Cache serving path: the simulator stops being a reporting
tool and becomes the control input for admission.

Two pieces:

* :class:`LatencyModel` — converts a :class:`~repro.core.schedule.
  NetworkSchedule`'s modeled cycles (priced by ``simulator.batch_time_s``:
  filter load once per batch + per-image marginal + §IV-E spill, minus the
  filter-load time hidden by double-buffered plans — schedules planned
  with ``overlap=True`` automatically price the overlapped pipeline, so
  the serving engine's default plans calibrate against overlapped
  predictions with no changes here) into a
  predicted wall-latency curve ``latency(batch)``.  The modeled number is
  hardware time; the emulation (or a real deployment) runs at some
  process-dependent multiple of it, so the model *calibrates*: every
  executed batch reports its measured wall time via :meth:`~LatencyModel.
  observe`, and the running wall/modeled ratio (EWMA) scales predictions.
  The p99 prediction multiplies by the worst *recently* observed ratio
  (a sliding window, so cold-compile/CPU-steal outliers age out; never
  thinner than a safety margin over the mean), so one calibration scalar
  serves every batch size — predictions stay strictly monotone in
  ``batch`` by construction, an invariant
  ``benchmarks/sched_breakdown.py`` gates.

* :class:`AdmissionPolicy` — given a target SLO, picks the largest batch
  whose predicted p99 stays under the *remaining* budget of the oldest
  queued request (queue wait has already spent part of it), bounded by
  ``NetworkSchedule.stream_batch_limit`` (batches past it spill, and the
  spill cost is already inside the predicted latency, so the model
  penalizes them even before the hard cap bites) and the engine's
  ``max_batch``.  Ragged tails are admitted *early* once holding for a
  fuller batch would eat into the oldest request's deadline slack
  (:meth:`~AdmissionPolicy.admit` reasons: ``full`` / ``ragged-early`` /
  ``hold`` / ``flush``).

* :class:`ArrivalRateEstimator` (PR 9, closing PR 5's open thread) — an
  EWMA over observed inter-arrival intervals.  Wired into a policy
  (``arrivals=``), the hold decision stops being slack-only: a shallow
  queue is held *only while the estimated time to fill the target batch
  fits inside the remaining slack* — under sparse traffic the expected
  fill time exceeds the slack immediately and the ragged batch flushes
  early instead of burning deadline budget waiting for arrivals that are
  not coming.

Consumed by ``launch/serve.py::NCServingEngine`` (``--slo-ms``), which
shares its per-batch-size plan cache with the model so admission decisions
and execution price the very same :class:`NetworkSchedule` objects, and by
``launch/orchestrator.py``, which routes a global queue across N engines
by each engine's own calibrated model (one estimator per orchestrator).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

from repro.core.schedule import NetworkSchedule
from repro.core.simulator import (NetworkResult, SimConstants, batch_time_s,
                                  simulate_network)

__all__ = ["LatencyModel", "AdmissionDecision", "AdmissionPolicy",
           "ArrivalRateEstimator"]


class ArrivalRateEstimator:
    """EWMA inter-arrival estimator (PR 9, closes PR 5's open thread).

    ``observe(now)`` is called once per arriving request with the engine
    clock; the estimator keeps an EWMA of the inter-arrival intervals.
    ``expected_fill_time_s(k)`` answers the question the hold decision
    actually asks — "how long until ``k`` more requests show up?" — as
    ``k * mean_interval``.  With fewer than two arrivals there is no
    interval information yet and it returns ``None`` (callers fall back
    to the slack-only hold rule).
    """

    def __init__(self, ewma: float = 0.3):
        self.ewma = float(ewma)
        self.mean_interval_s: float | None = None
        self._last_t: float | None = None
        self.samples = 0  # arrivals observed (intervals = samples - 1)

    def observe(self, now: float) -> None:
        """Fold one arrival timestamp in (monotone engine-clock time)."""
        if self._last_t is not None:
            dt = max(now - self._last_t, 0.0)
            if self.mean_interval_s is None:
                self.mean_interval_s = dt
            else:
                self.mean_interval_s = (self.ewma * dt
                                        + (1.0 - self.ewma)
                                        * self.mean_interval_s)
        self._last_t = now
        self.samples += 1

    @property
    def rate_hz(self) -> float | None:
        """Estimated arrival rate (None until two arrivals were seen)."""
        if self.mean_interval_s is None:
            return None
        return 1.0 / max(self.mean_interval_s, 1e-12)

    def expected_fill_time_s(self, k: int) -> float | None:
        """Expected seconds until ``k`` further requests arrive (None
        when the rate is still unknown)."""
        if k <= 0:
            return 0.0
        if self.mean_interval_s is None:
            return None
        return k * self.mean_interval_s


class LatencyModel:
    """Predicted serving latency per batch size from the priced plan.

    ``schedule_for(n)`` supplies the :class:`NetworkSchedule` for batch
    ``n`` — pass the serving engine's cached planner so the model and the
    execution path share plan objects (one source of truth).  Results are
    priced once per batch size and memoized.

    Calibration: ``observe(batch, wall_s)`` folds a measured batch wall
    time into the running wall/modeled ratio.  ``predict_s`` scales the
    modeled batch time by that EWMA ratio; ``predict_p99_s`` scales by the
    worst ratio over the last ``window`` observations, floored at
    ``tail_safety`` times the mean — a pessimistic tail estimate.  The
    window matters: the very first observation of a batch size includes
    one-time jit compilation, and shared hosts show transient CPU-steal
    spikes; a windowed max lets such outliers age out instead of capping
    admitted batch sizes for the engine's lifetime.  Uncalibrated models
    predict modeled (hardware) time times the safety margin.

    Invariant (gated by ``benchmarks/sched_breakdown.py`` and
    ``tests/test_serving_slo.py``): both predictions are strictly
    increasing in ``batch`` — the calibration is a batch-independent
    scalar over ``batch_time_s``, which is affine increasing in the batch.
    """

    def __init__(self, schedule_for: Callable[[int], NetworkSchedule],
                 const: SimConstants | None = None,
                 tail_safety: float = 1.25,
                 ewma: float = 0.5,
                 window: int = 32):
        self._schedule_for = schedule_for
        self._const = const or SimConstants()
        self._results: dict[int, NetworkResult] = {}
        self.tail_safety = float(tail_safety)
        self.ewma = float(ewma)
        self.scale = 1.0  # EWMA of observed wall_s / modeled_batch_s
        self._recent = collections.deque(maxlen=window)  # recent ratios
        self.samples = 0
        self.excluded = 0  # degraded/fallback batches kept out of calibration

    # -- modeled (hardware) time --------------------------------------------
    def result_for(self, batch: int) -> NetworkResult:
        """The priced :class:`NetworkResult` for ``batch`` (memoized; the
        schedule comes from the shared ``schedule_for`` plan cache)."""
        if batch not in self._results:
            self._results[batch] = simulate_network(
                self._schedule_for(batch), const=self._const)
        return self._results[batch]

    def invalidate_plans(self) -> None:
        """Drop every memoized priced result — call after the serving
        engine re-plans (PR 8 warmup re-planning replaces the schedule
        cache behind ``schedule_for``), so predictions re-price the NEW
        plans instead of serving a stale curve.  Calibration observations
        are kept: the wall/modeled scale tracks host effects, not the
        plan shape (the re-planner excludes the one batch that executed
        the retired plan)."""
        self._results.clear()

    def reset_calibration(self) -> None:
        """Forget every measured wall/modeled observation — call when the
        execution BACKEND changes (PR 10, ``NCServingEngine.set_engine``).
        The wall-clock-per-modeled-cycle scale is a property of the
        execution body (host walk vs bucketed jit vs Pallas interpret),
        so observations from one backend must not price another; modeled
        cycles themselves are backend-invariant and the priced-plan memo
        is handled separately by :meth:`invalidate_plans`."""
        self.scale = 1.0
        self.samples = 0
        self._recent.clear()

    def modeled_batch_s(self, batch: int) -> float:
        """Modeled time to run one admitted batch: filter load once +
        ``batch`` x (marginal + spill) — ``simulator.batch_time_s``."""
        return batch_time_s(self.result_for(batch), batch)

    @property
    def stream_batch_limit(self) -> int:
        """The §VI-C streaming bound of the planned network (images the
        reserved I/O way stages at once).  Pruning-independent for
        uncompressed plans; compressed plans (PR 8) may stage deeper —
        see ``NetworkSchedule.stream_batch_limit``."""
        return self._schedule_for(1).stream_batch_limit

    # -- calibration ---------------------------------------------------------
    @property
    def calibrated(self) -> bool:
        return self.samples > 0

    def observe(self, batch: int, wall_s: float) -> float:
        """Fold one measured batch wall time into the calibration; returns
        the observed wall/modeled ratio."""
        ratio = wall_s / self.modeled_batch_s(batch)
        if self.samples == 0:
            self.scale = ratio
        else:
            self.scale = self.ewma * ratio + (1.0 - self.ewma) * self.scale
        self._recent.append(ratio)
        self.samples += 1
        return ratio

    def exclude(self, batch: int, wall_s: float) -> None:
        """Explicitly keep one measured batch OUT of the calibration.

        Fault-degraded batches (a fallback schedule, or the float reference
        path) do not execute the plan the model prices, so folding their
        wall time into the wall/modeled ratio would poison every later
        prediction.  The engine calls this instead of :meth:`observe` for
        such batches — the exclusion is recorded (``excluded``) so the
        accounting in ``stats()`` stays honest, and ``scale``/``samples``/
        the tail window are untouched."""
        self.excluded += 1

    @property
    def worst(self) -> float:
        """Worst wall/modeled ratio over the last ``window`` observations
        (windowed so a cold-compile or CPU-steal outlier ages out)."""
        return max(self._recent, default=0.0)

    @property
    def p99_scale(self) -> float:
        """Tail multiplier: worst recent observed ratio, never thinner
        than ``tail_safety`` x the running mean."""
        return max(self.worst, self.scale * self.tail_safety)

    # -- predictions ---------------------------------------------------------
    def predict_s(self, batch: int) -> float:
        """Expected wall time for an admitted batch of ``batch`` images."""
        return self.scale * self.modeled_batch_s(batch)

    def predict_p99_s(self, batch: int) -> float:
        """Tail (p99) wall time for an admitted batch of ``batch`` images."""
        return self.p99_scale * self.modeled_batch_s(batch)

    def curve(self, batches) -> list[tuple[int, float, float]]:
        """``[(batch, predict_s, predict_p99_s), ...]`` for reporting."""
        return [(b, self.predict_s(b), self.predict_p99_s(b))
                for b in batches]


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """One admission verdict (kept by the engine for stats/tests).

    ``admit`` is the number of requests to pop now (0 = keep holding for a
    fuller batch); ``target`` the SLO-optimal batch size for the current
    budget; ``budget_s`` the oldest queued request's remaining deadline
    budget (``float("nan")`` when the queue is empty — no oldest request,
    no budget); ``reason`` one of ``full`` (queue covers the target),
    ``ragged-early`` (deadline pressure flushed a partial batch),
    ``flush`` (caller forced draining) or ``hold``."""

    admit: int
    target: int
    budget_s: float
    reason: str


@dataclasses.dataclass
class AdmissionPolicy:
    """SLO-aware batch sizing over a :class:`LatencyModel`.

    ``slo_s`` is the per-request deadline (arrival to completion).  The
    policy never admits more than ``batch_cap`` = min(``max_batch``,
    ``stream_batch_limit``) requests at once, and never *targets* a batch
    whose predicted p99 exceeds the remaining budget.  ``hold_slack_s``
    is how much deadline slack a partial batch may retain before the
    policy keeps holding for more arrivals (default: a quarter of the
    SLO).  ``arrivals`` (optional, PR 9) is an
    :class:`ArrivalRateEstimator`: when set, a shallow queue is held only
    while the estimated time to fill the target batch fits inside the
    remaining slack — sparse traffic flushes ragged batches immediately
    instead of holding until the slack rule fires."""

    model: LatencyModel
    slo_s: float
    max_batch: int
    hold_slack_s: float | None = None
    arrivals: ArrivalRateEstimator | None = None

    @property
    def hold_slack(self) -> float:
        return (self.hold_slack_s if self.hold_slack_s is not None
                else 0.25 * self.slo_s)

    @property
    def batch_cap(self) -> int:
        """Hard admission bound: the engine's batch limit and the §VI-C
        streaming bound, whichever bites first."""
        return max(1, min(self.max_batch, self.model.stream_batch_limit))

    def target_batch(self, budget_s: float) -> int:
        """Largest batch in [1, batch_cap] whose predicted p99 fits the
        budget; 1 when even a single image cannot (admit the smallest
        batch and take the recorded miss rather than starving).  Found by
        bisection — predictions are monotone in the batch."""
        cap = self.batch_cap
        if self.model.predict_p99_s(1) > budget_s:
            return 1
        lo, hi = 1, cap  # predict_p99_s(lo) <= budget_s invariant
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.model.predict_p99_s(mid) <= budget_s:
                lo = mid
            else:
                hi = mid - 1
        return lo

    def admit(self, queued: int, oldest_wait_s: float,
              flush: bool = False) -> AdmissionDecision:
        """Decide how many of ``queued`` requests to admit now.

        ``oldest_wait_s`` is how long the head-of-line request has already
        queued — its remaining budget bounds the batch.  A queue at least
        as deep as the target admits immediately; a shallower (ragged)
        queue is held for more arrivals until its remaining slack after
        execution would drop below ``hold_slack``, then admitted early so
        the deadline survives.  With an ``arrivals`` estimator the hold
        is additionally bounded by traffic: holding is only worth it if
        the expected time to fill the target batch fits inside the slack.
        ``flush=True`` (draining: no more arrivals are coming) disables
        holding but keeps the SLO batch cap.

        An empty queue holds trivially; there is no oldest request, so no
        deadline budget exists — ``budget_s`` is reported as
        ``float("nan")``, not a number pretending to be one."""
        if queued <= 0:
            return AdmissionDecision(0, 0, float("nan"), "hold")
        budget = self.slo_s - oldest_wait_s
        target = self.target_batch(max(budget, 0.0))
        if queued >= target:
            return AdmissionDecision(target, target, budget, "full")
        if flush:
            return AdmissionDecision(queued, target, budget, "flush")
        slack = budget - self.model.predict_p99_s(queued)
        if slack <= self.hold_slack:
            return AdmissionDecision(queued, target, budget, "ragged-early")
        if self.arrivals is not None:
            # holding only pays off if the missing requests are expected
            # to show up before the slack runs out; unknown rate (fewer
            # than two arrivals seen) falls back to the slack-only rule
            fill = self.arrivals.expected_fill_time_s(target - queued)
            if fill is not None and fill >= slack:
                return AdmissionDecision(queued, target, budget,
                                         "ragged-early")
        return AdmissionDecision(0, target, budget, "hold")
