"""Neural Cache cycle/energy/data-movement simulator (paper §V-§VI).

Deterministic performance model with two ingredient classes:

MECHANISTIC (derived, no fitting):
  * the execution plan (core/schedule.py) — filters/array, parallel convs,
    serial passes, spill decisions; the SAME :class:`NetworkSchedule` the
    packed-engine emulation executes, so modeled and emulated runs agree
    on residency by construction (mapping validated against the paper's
    two worked examples),
  * per-conv compute cycles: ``mac8 * macs_per_line + red_step * log2(C')``
    — reproduces the paper's 2784 cycles/conv for Conv2d_2b exactly,
  * byte counts for filters / inputs / outputs from layer geometry,
  * batching model: filters loaded once per layer per batch; outputs of
    early layers spill to DRAM when the batch outgrows the reserved way.

CALIBRATED (constants the paper itself measured with micro-benchmarks and
SPICE, §V — we adopt their published values):
  * mac8 = 236 cycles per 8-bit MAC (§VI-A; first-principles floor is
    mul(8)+add(24) = 127, the rest is tag-load/move orchestration),
  * red_step = 132 cycles per reduction step (660 cycles / 5 steps at C'=32:
    4-byte-segment move+add ~ 97 cycles + 35 measured overhead),
  * effective bandwidths for filter loading (DRAM + ring/bus distribution),
    input streaming and output staging, set from the paper's measured
    latency breakdown (Figure 14) once, then reused for every experiment
    (including the cache-capacity scaling runs of Table IV).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.core import bitserial as bs
from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import LayerSpec, MappedLayer
from repro.core.schedule import NetworkSchedule, SlicePlan, plan_layer, plan_network

__all__ = ["SimConstants", "LayerResult", "NetworkResult", "simulate_layer",
           "simulate_network", "modeled_layer_cycles", "batch_time_s",
           "throughput", "PAPER"]

MIB = 1 << 20


# Published baseline / headline numbers we validate against (paper §VI).
PAPER = dict(
    nc_latency_ms=4.72,
    cpu_latency_ms=86.4,  # 18.3x
    gpu_latency_ms=36.3,  # 7.7x
    latency_speedup_cpu=18.3,
    latency_speedup_gpu=7.7,
    nc_throughput=604.0,  # dual-socket node, max batch
    cpu_throughput=48.7,  # 604 / 12.4
    gpu_throughput=274.5,  # 604 / 2.2
    nc_energy_j=0.246,
    cpu_energy_j=9.137,
    gpu_energy_j=4.087,
    nc_power_w=52.92,
    cpu_power_w=105.56,
    gpu_power_w=112.87,
    breakdown=dict(filter=0.46, input=0.15, output=0.04, mac=0.20,
                   reduce=0.10, quant=0.05, pool=0.0004),
    capacity_ms={35: 4.72, 45: 4.12, 60: 3.79},
    conv2d_2b_cycles_per_conv=2784,
    conv2d_2b_serial=43,
)


@dataclasses.dataclass(frozen=True)
class SimConstants:
    """Calibrated constants (see module docstring for provenance).

    The word-packed emulation engine (core/bitserial.py) models the same
    hardware with unchanged per-op cycle formulas, so its mechanistic
    costs are hard floors for these calibrated constants —
    :meth:`validate` asserts that invariant and runs once per
    :func:`simulate_network` call."""

    mac8_cycles: int = 236
    reduce_step_cycles: int = 132
    reduce_xstep_cycles: int = 111  # extra per step beyond 5: moves cross the
    #   sense-amp pair boundary once partial sums span >32 lines
    pass_stage_cycles: int = 453  # per serial pass: stage the next window's
    #   input bytes into word lines + move finished outputs out (folded into
    #   the paper's 'MACs' share of Figure 14)
    pool_cmp_cycles: int = 27  # sub(8) + masked copy + tag load
    quant_pass_cycles: int = 3546  # 3 x 32-bit fixed-point multiplies (BN + requant)
    quant_layer_overhead_cycles: int = 2500  # min/max tree + bus reduction
    checksum_pass_cycles: int = 368  # PR 7 ABFT verify per executed pass:
    #   the checksum column is one extra lane group riding the pass's
    #   MAC (mac8) plus one reduce step to fold its partial sum (236+132);
    #   priced ONLY when the plan sets integrity (exact additive term)
    # effective bandwidths (bytes/s) — measured by the paper's micro-benchmarks
    filter_bw: float = 10.96e9  # DRAM read + ring/bus broadcast + array stores
    input_bw: float = 51.5e9  # reserved-way reads + intra-slice broadcast
    output_bw: float = 61.8e9  # compute arrays -> reserved way
    dram_bw: float = 11.0e9  # batched-output spill/reload
    # energy model
    dram_pj_per_byte: float = 20.0
    bus_pj_per_byte: float = 5.0

    def validate(self) -> "SimConstants":
        """Check the calibrated constants against the emulation's
        mechanistic cycle floors (paper §III formulas)."""
        card = bs.OpCycles(bits=8, acc_bits=24, mac8=self.mac8_cycles)
        assert card.mac_overhead >= 0, (
            f"mac8={self.mac8_cycles} below the mul(8)+add(24) floor "
            f"{card.mac_floor}")
        # one reduce step on a 32-bit partial sum: move(w) + add(w) minimum
        floor = bs.move_cycles(32) + bs.add_cycles(32)
        assert self.reduce_step_cycles >= floor, (
            self.reduce_step_cycles, floor)
        return self

    def scaled_bandwidths(self, geom: CacheGeometry, base: CacheGeometry):
        """Input/output movement parallelizes over slices (§VI-D); filter
        loading is DRAM-bound and does not (filters are replicated)."""
        r = geom.n_slices / base.n_slices
        return dataclasses.replace(self, input_bw=self.input_bw * r,
                                   output_bw=self.output_bw * r)


@dataclasses.dataclass(frozen=True)
class LayerResult:
    spec: LayerSpec
    mapped: MappedLayer
    mac_s: float
    reduce_s: float
    quant_s: float
    pool_s: float
    filter_s: float
    input_s: float
    output_s: float
    compute_cycles_per_pass: float
    energy_j: float
    plan: SlicePlan | None = None  # the schedule entry this result priced
    # §IV-E double buffering (plan.overlap): the first pass's filter columns
    # have no predecessor to hide under
    prologue_s: float = 0.0  # un-hideable load of pass 0's filter columns
    overlap: bool = False
    # PR 7 integrity: per-pass ABFT checksum verification (plan.integrity).
    # Kept OUT of mac_s/reduce_s so the §IV-E hidden-load credit (capped by
    # mac+reduce) is untouched and the additive-credit invariant is exact.
    integrity_s: float = 0.0
    # PR 8 compressed residency: the filter-load seconds compression
    # keeps off the §VI-C per-batch load — (dense live-set bytes −
    # compressed bytes) / filter_bw, already inside filter_s because the
    # plan's filter_bytes IS the compressed footprint.  An exact additive
    # credit: dense total_s − compressed total_s == residency_credit_s for
    # overlap-off plans (zero when the plan is uncompressed).
    residency_credit_s: float = 0.0

    @property
    def compute_s(self) -> float:
        return (self.mac_s + self.reduce_s + self.quant_s + self.pool_s
                + self.integrity_s)

    @property
    def total_s(self) -> float:
        return self.compute_s + self.filter_s + self.input_s + self.output_s

    @property
    def hidden_s(self) -> float:
        """Filter-load seconds hidden under MAC+reduce when the plan
        granted §IV-E double buffering — the layer's overlapped filter cost
        is ``prologue + max(filter_s - prologue, mac_s + reduce_s)`` in
        place of the serial ``filter_s + mac_s + reduce_s``, so the credit
        is ``min(filter_s - prologue_s, mac_s + reduce_s)``.

        The cap is ONE image's MAC+reduce even in a batch: layer-serial
        §IV-E streams image 1's pass sequence first, and pass k's columns
        must land before pass k consumes them, so every load has to
        interleave into the FIRST image's passes (images 2..N then run
        fully resident).  The credit is therefore batch-independent, which
        keeps ``batch_time_s`` strictly increasing in the batch.  Zero
        when overlap is off — serial pricing is bit-identical."""
        if not self.overlap:
            return 0.0
        return min(max(self.filter_s - self.prologue_s, 0.0),
                   self.mac_s + self.reduce_s)


def _fresh_input_fraction(spec: LayerSpec) -> float:
    """Input-reuse model (§IV-A): for an RxS window with stride U, (R-U)xS of
    the RxS bytes are reused across consecutive output pixels held in-array
    (e.g. 6 of 9 for 3x3 stride 1)."""
    if spec.filter_elems <= 1:
        return 1.0
    reuse = max(spec.R - spec.stride, 0) / spec.R
    return 1.0 - reuse


def simulate_layer(
    spec: LayerSpec | SlicePlan,
    geom: CacheGeometry = XEON_E5_35MB,
    const: SimConstants = SimConstants(),
) -> LayerResult:
    """Price one layer.  Accepts a raw :class:`LayerSpec` (planned here at
    batch 1) or a :class:`SlicePlan` straight from the schedule — the same
    plan object the packed-engine emulation executes, so residency, pass
    counts and spill decisions are never re-derived."""
    if isinstance(spec, SlicePlan):
        plan = spec
        spec = plan.spec
    else:
        plan = plan_layer(spec, geom)
    m = plan.mapped
    f_hz = geom.compute_freq_hz

    if spec.kind in ("maxpool", "avgpool"):
        # window_size-1 comparisons per window, all lanes in lockstep
        cmps = max(spec.filter_elems - 1, 1)
        pass_cycles = cmps * const.pool_cmp_cycles
        if spec.kind == "avgpool":
            pass_cycles = spec.filter_elems * bs.add_cycles(16) + bs.div_cycles(8)
        pool_s = plan.serial_passes * pass_cycles / f_hz
        input_s = spec.window_count * spec.filter_elems * _fresh_input_fraction(spec) / const.input_bw
        output_s = spec.output_bytes / const.output_bw
        energy = (
            plan.serial_passes * pass_cycles * geom.compute_arrays * m.utilization
            * geom.compute_energy_pj * 1e-12
        )
        return LayerResult(spec, m, 0.0, 0.0, 0.0, pool_s, 0.0, input_s,
                           output_s, pass_cycles, energy, plan)

    # ---- convolution / fc -------------------------------------------------
    mac_cycles = const.mac8_cycles * m.macs_per_line
    steps = m.reduction_steps
    red_cycles = const.reduce_step_cycles * steps + const.reduce_xstep_cycles * max(steps - 5, 0)
    per_conv = mac_cycles + red_cycles

    # sparsity-aware: the plan may have dropped serialized passes whose
    # filters are all zero (plan.skipped_passes); dense plans price the
    # identical expression with a zero credit — bit-identical numbers.
    passes = plan.executed_passes
    mac_s = passes * (mac_cycles + const.pass_stage_cycles) / f_hz
    reduce_s = passes * red_cycles / f_hz

    # requantization (+folded BN) applies to output elements in lockstep
    # across lanes: once per lane-full of outputs (the plan's quant
    # passes), plus the per-layer min/max tree + inter-array bus reduction
    # (§IV-D; the calibrated constant — the schedule's mechanistic
    # ``minmax_cycles`` is the emulation-side per-tensor tree).
    quant_s = (plan.quant_passes * const.quant_pass_cycles
               + const.quant_layer_overhead_cycles) / f_hz

    # §VI-C residency: filters load once per layer per batch
    filter_bytes = plan.filter_bytes
    filter_s = filter_bytes / const.filter_bw
    input_stream = spec.conv_count * spec.filter_elems * _fresh_input_fraction(spec)
    input_s = input_stream / const.input_bw
    output_s = spec.output_bytes / const.output_bw

    compute_cycles = passes * (per_conv + const.pass_stage_cycles) + quant_s * f_hz
    active = geom.compute_arrays * m.utilization
    energy = (
        compute_cycles * active * geom.compute_energy_pj * 1e-12
        + filter_bytes * (const.dram_pj_per_byte + const.bus_pj_per_byte) * 1e-12
        + (input_stream + spec.output_bytes) * const.bus_pj_per_byte * 1e-12
    )
    # §IV-E double buffering: pass k+1's filter columns stream while pass
    # k's MAC+reduce runs; only the first pass's chunk is un-hideable
    overlap = plan.overlap
    prologue_s = (plan.filter_bytes_per_pass / const.filter_bw
                  if overlap else 0.0)
    # PR 7 integrity: one checksum verification per executed pass, an
    # exact additive term (zero — bit-identical pricing — when off)
    integrity_s = (passes * const.checksum_pass_cycles / f_hz
                   if plan.integrity else 0.0)
    return LayerResult(spec, m, mac_s, reduce_s, quant_s, 0.0, filter_s,
                       input_s, output_s, per_conv, energy, plan,
                       prologue_s=prologue_s, overlap=overlap,
                       integrity_s=integrity_s,
                       residency_credit_s=(plan.residency_credit_bytes
                                           / const.filter_bw))


def modeled_layer_cycles(
    spec: LayerSpec | SlicePlan,
    geom: CacheGeometry = XEON_E5_35MB,
    const: SimConstants = SimConstants(),
) -> dict:
    """Paper-style modeled compute cycles for one layer: the mapper's
    serialized passes times the per-pass cost (MAC + log-tree + staging).

    This is the analytic counterpart of the emulation's arithmetic cycle
    count (core/nc_layers.py): the emulation charges the §III formulas per
    lane group, the model charges the calibrated per-pass constants per
    serialized pass — models/inception.py's ``nc_forward`` reports both
    side by side.

    Accepts a :class:`SlicePlan` for sparse plans: ``total_cycles`` then
    covers only the executed passes and ``skip_credit_cycles`` is the
    exact credit — ``dense_total - sparse_total == skip_credit_cycles``
    holds to the cycle (same per-pass cost, the occupancy never changes
    the mapped layout).

    Overlap (§IV-E double buffering) never changes the compute cycles —
    it re-times the filter LOAD against them — so ``total_cycles`` is
    overlap-invariant; the hidden-load credit is reported in seconds
    (``hidden_s``, with the un-hideable ``prologue_s``) and
    ``overlapped_total_s = total_s - hidden_s`` is the layer's §IV-E
    double-buffered wall time (== ``total_s`` when overlap is off).

    Integrity (PR 7) is the same additive idiom: when the plan sets
    ``integrity``, each executed pass also pays ``checksum_pass_cycles``
    (``integrity_cycles`` in total, folded into ``total_cycles`` and the
    skip credit so EVERY credit identity stays exact), and
    ``reexec_pass_cycles`` is the price of re-running one pass after a
    detected fault — the engine multiplies it by its measured re-execution
    count.  Integrity-off plans price bit-identically (both terms zero)."""
    res = simulate_layer(spec, geom, const)
    per_pass = res.compute_cycles_per_pass
    passes = (res.plan.serial_passes if res.plan is not None
              else res.mapped.serial_passes)
    skipped = res.plan.skipped_passes if res.plan is not None else 0
    cs_per_pass = (const.checksum_pass_cycles
                   if res.plan is not None and res.plan.integrity else 0)
    return dict(
        per_pass_cycles=per_pass,
        serial_passes=passes,
        skipped_passes=skipped,
        skip_credit_cycles=(per_pass + cs_per_pass) * skipped,
        total_cycles=(per_pass + cs_per_pass) * (passes - skipped),
        integrity_cycles=cs_per_pass * (passes - skipped),
        reexec_pass_cycles=per_pass + cs_per_pass,
        compute_s=res.compute_s,
        total_s=res.total_s,
        overlap=res.overlap,
        prologue_s=res.prologue_s,
        hidden_s=res.hidden_s,
        overlapped_total_s=res.total_s - res.hidden_s,
        integrity_s=res.integrity_s,
        residency_credit_s=res.residency_credit_s,
    )


@dataclasses.dataclass(frozen=True)
class NetworkResult:
    layers: tuple[LayerResult, ...]
    geom: CacheGeometry
    const: SimConstants
    schedule: NetworkSchedule | None = None  # the plan this result priced

    @property
    def filter_s(self) -> float:
        return sum(l.filter_s for l in self.layers)

    @property
    def input_s(self) -> float:
        return sum(l.input_s for l in self.layers)

    @property
    def output_s(self) -> float:
        return sum(l.output_s for l in self.layers)

    @property
    def mac_s(self) -> float:
        return sum(l.mac_s for l in self.layers)

    @property
    def reduce_s(self) -> float:
        return sum(l.reduce_s for l in self.layers)

    @property
    def quant_s(self) -> float:
        return sum(l.quant_s for l in self.layers)

    @property
    def pool_s(self) -> float:
        return sum(l.pool_s for l in self.layers)

    @property
    def integrity_s(self) -> float:
        """PR 7 per-pass checksum verification, summed over layers — the
        network's exact additive integrity cost (zero when off)."""
        return sum(l.integrity_s for l in self.layers)

    @property
    def residency_credit_s(self) -> float:
        """PR 8 compressed residency: filter-load seconds compression
        keeps off the per-batch load, summed over layers.  Batch-
        independent (filters load once per batch), so for overlap-off
        schedules ``batch_time_s(dense, N) - batch_time_s(compressed, N)
        == residency_credit_s`` exactly, for every N (zero when off)."""
        return sum(l.residency_credit_s for l in self.layers)

    @property
    def compute_s(self) -> float:
        return (self.mac_s + self.reduce_s + self.quant_s + self.pool_s
                + self.integrity_s)

    @property
    def marginal_s(self) -> float:
        """Per-image time with filters resident (batched steady state)."""
        return self.compute_s + self.input_s + self.output_s

    @property
    def hidden_s(self) -> float:
        """Filter-load seconds hidden under MAC+reduce across the network
        (§IV-E double buffering; zero for overlap-off schedules).
        Batch-independent — see :attr:`LayerResult.hidden_s`."""
        return sum(l.hidden_s for l in self.layers)

    @property
    def latency_s(self) -> float:
        return self.filter_s + self.marginal_s

    @property
    def overlapped_latency_s(self) -> float:
        """Single-image latency with the schedule's §IV-E double buffering
        applied: per layer, ``prologue + max(load_rest, mac+reduce)``
        instead of ``load + mac + reduce``.  Equals :attr:`latency_s` when
        overlap is off."""
        return self.latency_s - self.hidden_s

    @property
    def energy_j(self) -> float:
        return sum(l.energy_j for l in self.layers)

    @property
    def power_w(self) -> float:
        return self.energy_j / self.latency_s

    def breakdown(self) -> dict[str, float]:
        t = self.latency_s
        return dict(
            filter=self.filter_s / t, input=self.input_s / t,
            output=self.output_s / t, mac=self.mac_s / t,
            reduce=self.reduce_s / t, quant=self.quant_s / t,
            pool=self.pool_s / t,
        )

    @property
    def filter_bytes_loaded(self) -> int:
        """Filter bytes loaded per batch — once per layer, independent of
        batch size, because filters stay resident while the batch streams
        (§VI-C; the schedule's residency accounting)."""
        if self.schedule is not None:
            return self.schedule.filter_bytes_loaded
        return sum(l.spec.filter_bytes for l in self.layers)

    def spill_s_per_image(self) -> float:
        """Batched mode: a layer's batch-wide output set must stay resident
        until the next layer consumes it; when it exceeds the reserved way it
        round-trips DRAM (§IV-E: 'the first five [layers]' for Inception v3).
        The spill decision lives in the schedule (one source of truth); a
        hand-built NetworkResult without one falls back to the same rule."""
        if self.schedule is not None:
            return self.schedule.spill_bytes_per_image / self.const.dram_bw
        cap = self.geom.io_way_bytes / 2  # staging holds inputs + outputs
        spill = sum(2 * l.spec.output_bytes for l in self.layers
                    if l.spec.output_bytes > cap / 2)
        return spill / self.const.dram_bw


def simulate_network(
    specs: Sequence[LayerSpec] | NetworkSchedule,
    geom: CacheGeometry = XEON_E5_35MB,
    const: SimConstants = SimConstants(),
    base_geom: CacheGeometry = XEON_E5_35MB,
) -> NetworkResult:
    """Price a network.  Accepts the layer specs (planned here at batch 1)
    or a ready :class:`NetworkSchedule` — e.g. the very object a batched
    ``nc_forward``/serving run executed — so residency, spill and pass
    counts come from one plan."""
    if isinstance(specs, NetworkSchedule):
        schedule = specs
        geom = schedule.geom
    else:
        schedule = plan_network(specs, geom, batch=1)
    const = const.validate().scaled_bandwidths(geom, base_geom)
    return NetworkResult(
        tuple(simulate_layer(p, geom, const) for p in schedule.layers),
        geom, const, schedule)


def batch_time_s(result: NetworkResult, batch: int) -> float:
    """Modeled time to process ONE admitted batch of ``batch`` images,
    layer-serially (§IV-E):

    total(N) = filter_load + N * marginal + N * spill - hidden  (spill only
    when the batch outgrows the reserved way, i.e. N >= 2; ``hidden`` is
    the schedule's §IV-E double-buffering credit — per layer the filter
    cost collapses from ``load + mac + reduce`` to
    ``prologue + max(load_rest, mac + reduce)``, and the credit is
    batch-independent because every load must land inside the FIRST
    image's pass sequence — see :attr:`LayerResult.hidden_s`).

    This is the per-batch latency the serving admission policy predicts
    against (core/slo.py): strictly increasing in ``batch`` (marginal and
    spill are per-image costs, the hidden credit a constant), with the
    filter load amortizing — the latency/throughput trade the SLO knob
    walks.  Overlap-off schedules price bit-identically to the serial
    PR 3/4 model (``hidden == 0``).  ``throughput`` is its reciprocal
    view."""
    spill = result.spill_s_per_image() if batch > 1 else 0.0
    return result.filter_s + batch * (result.marginal_s + spill) - result.hidden_s


def throughput(result: NetworkResult, batch: int, sockets: int = 2) -> float:
    """Inferences/s for a batch processed layer-serially (§IV-E): the
    batch count over :func:`batch_time_s`, scaled by ``sockets``."""
    return sockets * batch / batch_time_s(result, batch)
