"""Functional execution of DNN layers through the bit-serial engine.

This is the *correctness* counterpart of core/simulator.py (which models
time/energy): each layer is computed element-for-element the way the cache
would — uint8 operands, bit-plane transposed layout, tag-predicated MACs,
in-array log-tree channel reduction, fixed-point requantization — and is
validated against jnp oracles in tests/test_nc_layers.py.

Packed-resident, tiled pipeline
-------------------------------
The engine's :class:`~repro.core.bitserial.PackedPlanes` word format is the
resident representation end to end: operands are packed straight into
row-aligned word space (``pack_values(..., row_align=True)``), the MAC and
the §III-D log-tree reduction run on words, and only the final per-row sums
are decoded — no per-lane plane tensor is ever materialized.

Work is tiled over **(image, output pixel) rows x filters** the way the
slice scheduler plans it (core/schedule.py, fed by the mapper's
serialized passes): a tile's lane count is bounded by the cache geometry
(``geom.compute_slots`` bit lines), so peak host memory follows the
modeled hardware instead of B*E*F*M*K.  Within a tile, the
packed *window* rows are packed once and broadcast across every filter at
word granularity (and the packed filter rows across every pixel) — the
word-level analogue of filter replication across arrays (§IV-B).  The
planner consults ``mapper.check_wordline_budget`` and refuses layers
whose per-bit-line working set cannot fit the modeled array.

Batch dimension (§VI-C): every layer accepts a leading batch axis
(``[B, H, W, C]``); the batch folds into the packed lane axis, so one
MAC+reduce serves rows from several images of a batch tile while the
filters stay packed once per layer per batch — the residency the
scheduler accounts as ``filter_bytes`` loaded once.  Quantization may be
per-image: ``x_qp`` accepts a sequence of per-image
:class:`~repro.core.quantize.QuantParams` (the integer MAC is shared
across the batch; only the affine zero-point correction and the padding
constant vary per image), and already-quantized *integer* inputs skip the
quantize step entirely (the §IV-D resident-uint8 pipeline).

Layer cycle counts are Python ints and are *unchanged* by tiling or
packing: each (image, pixel, filter) lane group still reports the same
``per_dot_cycles`` (mul + accumulate + log-tree), so total modeled cycles
are bit-identical to the untiled formulation — the emulation got faster,
the modeled hardware did not.  ``engine="jit"`` routes tiles through the
bucketed compiled engine (see core/bitserial.py) for sweep workloads.

:func:`nc_minmax` is the §IV-D in-cache dynamic-range reduction: a
bit-serial log tree of subtract + tag-masked copies over packed lanes —
only the two scalars per image ever leave the cache.

The TPU-fast path lives in repro/kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as _backends
from repro.core import bitserial as bs
from repro.core import faults
from repro.core import quantize as q
from repro.core import schedule as sched
from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import LayerSpec

__all__ = [
    "nc_dot",
    "nc_conv2d",
    "nc_maxpool2d",
    "nc_avgpool2d",
    "nc_minmax",
    "nc_relu_requant",
    "nc_fc",
    "ConvStats",
]


@dataclasses.dataclass(frozen=True)
class ConvStats:
    """Per-layer emulation accounting notes (cycles stay formula-exact for
    the passes that RUN; sparse plans drop zero-filter passes and their
    §III charges with them)."""

    lanes: int  # B*E*F*M*K MAC lanes
    zero_operand_lanes: int  # lanes a tag latch could predicate off (EIE-style)
    tiles: int
    tile_pixels: int  # (image, pixel) rows per tile
    tile_filters: int
    serial_passes: int  # mapper's modeled pass count for the layer (per image)
    engine_words_total: int  # host-engine word columns seen by the multiplier
    engine_words_skipped: int  # word columns elided (all-zero operand)
    batch: int = 1  # images folded into the lane axis this call
    filter_loads: int = 1  # times the filter word grid was packed (§VI-C: 1/batch)
    zero_filters: int = 0  # all-zero filters the sparse plan pruned
    skipped_passes: int = 0  # serialized passes the plan dropped (per image)
    overlap: bool = False  # §IV-E double buffering ran (prefetch + deferred store)
    # PR 7 integrity/fault path (all zero when integrity is off and no
    # fault environment is active — the unchecked path never touches them)
    integrity: bool = False  # ABFT checksum verification ran per pass
    verify_passes: int = 0  # checksum verifications charged (attempts incl.)
    reexec_passes: int = 0  # tile passes re-executed after detected faults
    faults_detected: int = 0  # verification mismatches caught
    integrity_cycles: int = 0  # §III cycles charged for checksum columns
    reexec_cycles: int = 0  # §III cycles charged for pass re-executions
    quarantined_slices: tuple = ()  # slices lost to repeated failures
    # PR 8 compressed residency (all zero/False when the plan is
    # uncompressed — the dense store runs bit for bit)
    compressed: bool = False  # filters lived CSR-per-bit-plane resident
    csr_payload_bytes: int = 0  # measured packed-word bytes of the store
    csr_index_bytes: int = 0  # measured per-plane live-column index bytes
    # the plan actually executed — differs from the caller's only after a
    # quarantine re-plan (excluded from equality: plans carry the spec)
    plan: object = dataclasses.field(default=None, compare=False, repr=False)


def nc_dot(x_q, w_q, acc_bits: int = 24, n_bits: int = 8):
    """Quantized dot products, one per bit-line group.

    x_q: [..., K] uint8 inputs, w_q: [..., K] uint8 filters (same shape).
    Each of the K lanes performs one ``n_bits`` MAC into a ``acc_bits``-bit
    partial sum, then the lanes reduce via the in-array log tree.  Returns
    (int values [...], cycles) — bit-exact with the integer dot product.

    Packed-resident: operands go straight to row-aligned words and the
    MAC feeds the reducer without leaving packed space.
    """
    x_q = np.asarray(x_q)
    w_q = np.asarray(w_q)
    K = x_q.shape[-1]
    P, wpr, r = bs._row_layout(K)
    xw = bs.pack_values(x_q, n_bits, row_align=True).words
    ww = bs.pack_values(w_q, n_bits, row_align=True).words
    if r == 1:
        xw = xw.reshape(n_bits, -1, wpr)
        ww = ww.reshape(n_bits, -1, wpr)
    vals, cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=acc_bits)
    n_rows = int(np.prod(x_q.shape[:-1])) if x_q.ndim > 1 else 1
    vals = np.asarray(vals).reshape(-1)[:n_rows]
    return vals.reshape(x_q.shape[:-1]), cycles


def _quantize_np(x, qp: q.QuantParams) -> np.ndarray:
    """Host mirror of core.quantize.quantize (float32 divide +
    round-half-even + clip — bit-identical to the jnp path)."""
    scale = np.float32(qp.scale)
    zp = int(qp.zero_point)
    vals = np.round(np.asarray(x, np.float32) / scale) + zp
    return np.clip(vals, qp.qmin, qp.qmax).astype(np.int64)


def _as_qp_list(qp, B: int) -> list[q.QuantParams]:
    """Normalize a QuantParams-or-per-image-sequence to a length-B list."""
    if isinstance(qp, q.QuantParams):
        return [qp] * B
    qps = list(qp)
    if len(qps) != B:
        raise ValueError(f"got {len(qps)} per-image QuantParams for batch {B}")
    if any(p.bits != qps[0].bits for p in qps):
        raise ValueError("per-image QuantParams must share a bit width")
    return qps


def _quantize_images(x4: np.ndarray, qps: list[q.QuantParams]) -> np.ndarray:
    """Per-image quantize of ``[B, H, W, C]`` — each image uses its own
    scale/zero-point, bit-identical to :func:`_quantize_np` per image."""
    if np.issubdtype(x4.dtype, np.integer):
        return x4.astype(np.int64)  # resident path: already quantized
    scales = np.array([np.float32(p.scale) for p in qps], np.float32)
    zps = np.array([int(p.zero_point) for p in qps], np.int64)
    vals = (np.round(x4.astype(np.float32) / scales[:, None, None, None])
            + zps[:, None, None, None])
    return np.clip(vals, qps[0].qmin, qps[0].qmax).astype(np.int64)


def _same_pad(h: int, r: int, stride: int) -> tuple[int, int]:
    """TF/lax SAME convention: total pad so out = ceil(h/stride); extra
    padding goes after (bottom/right)."""
    out = -(-h // stride)
    total = max((out - 1) * stride + r - h, 0)
    return total // 2, total - total // 2


def _extract_windows(x: np.ndarray, R: int, S: int, stride: int):
    """[H, W, C] -> ([E, F, R*S*C] window tensor, E, F) (VALID padding)."""
    H, W, C = x.shape
    E = (H - R) // stride + 1
    F = (W - S) // stride + 1
    rows = np.arange(E)[:, None] * stride + np.arange(R)[None, :]  # (E, R)
    cols = np.arange(F)[:, None] * stride + np.arange(S)[None, :]  # (F, S)
    win = x[rows][:, :, cols]  # (E, R, F, S, C)
    return win.transpose(0, 2, 1, 3, 4).reshape(E, F, R * S * C), E, F


def _extract_windows_batch(x4: np.ndarray, R: int, S: int, stride: int):
    """[B, H, W, C] -> ([B, E, F, R*S*C] window tensor, E, F)."""
    B, H, W, C = x4.shape
    E = (H - R) // stride + 1
    F = (W - S) // stride + 1
    rows = np.arange(E)[:, None] * stride + np.arange(R)[None, :]  # (E, R)
    cols = np.arange(F)[:, None] * stride + np.arange(S)[None, :]  # (F, S)
    win = x4[:, rows][:, :, :, cols]  # (B, E, R, F, S, C)
    return (win.transpose(0, 1, 3, 2, 4, 5).reshape(B, E, F, R * S * C),
            E, F)


def _pack_x_rows(rows: np.ndarray, n_bits: int) -> np.ndarray:
    """Window rows (T, K) -> broadcastable word grid (n, 1, ...) shared by
    every filter in the tile (the packed-plane reuse across filters)."""
    K = rows.shape[-1]
    P, wpr, r = bs._row_layout(K)
    w = bs.pack_values(rows, n_bits, row_align=True).words
    if r == 1:
        return w.reshape(n_bits, 1, rows.shape[0], wpr)
    return w.reshape(n_bits, 1, -1)  # (n, 1, ceil(T/r)) — rows share words


def _pack_w_rows(rows: np.ndarray, n_bits: int) -> np.ndarray:
    """Filter rows (M, K) -> broadcastable word grid (n, M, 1[, wpr]).

    For P < 32 each word of the dot grid holds 32/P *pixel* rows of one
    filter, so the filter's P-bit pattern is replicated across the word."""
    K = rows.shape[-1]
    P, wpr, r = bs._row_layout(K)
    if r == 1:
        w = bs.pack_values(rows, n_bits, row_align=True).words
        return w.reshape(n_bits, rows.shape[0], 1, wpr)
    rep = sum(1 << (j * P) for j in range(r))
    ks = np.arange(K, dtype=np.uint64)
    out = np.empty((n_bits, rows.shape[0]), np.uint64)
    rows = rows.astype(np.uint64)
    for p in range(n_bits):
        rowval = (((rows >> np.uint64(p)) & 1) << ks).sum(axis=1)
        out[p] = rowval * rep
    return out.astype(np.uint32)[:, :, None]


def nc_conv2d(
    x: jax.Array,
    w: jax.Array,
    x_qp: q.QuantParams | Sequence[q.QuantParams],
    w_qp: q.QuantParams,
    stride: int = 1,
    *,
    padding: str = "VALID",
    tile_pixels: int | None = None,
    tile_filters: int | None = None,
    geom: CacheGeometry = XEON_E5_35MB,
    layer_spec: LayerSpec | None = None,
    plan: sched.SlicePlan | None = None,
    occupancy: sched.LayerOccupancy | str | None = None,
    engine: str | None = None,
    overlap: bool = False,
    integrity: bool = False,
    compressed: bool = False,
    return_stats: bool = False,
):
    """Quantized conv through the array model (packed-resident + tiled).

    x: [H, W, C] or [B, H, W, C] float, w: [R, S, C, M] float.  Both are
    quantized (zero-point affine, ``qp.bits`` planes), the cross terms of
    (x-zx)(w-zw) are handled exactly as the integer expansion, and the
    result is returned as int32 — what the reserved-way staging would hold
    before requantization.  Integer-dtype inputs are treated as *already
    quantized* (the resident-uint8 pipeline) and skip the quantize step;
    ``x_qp`` may be a per-image sequence for batched inputs.
    ``padding="SAME"`` pads with the (per-image) quantized zero point
    (exact under the affine identity).

    Every (image, output pixel, filter) triple is a lane group.  Work is
    tiled over (image, pixel) rows and filters so a tile's bit lines fit
    the cache geometry (peak memory is bounded by ``geom.compute_slots``,
    not B*E*F*M*K); the batch folds into the row axis, and the packed
    window rows of a row tile are packed once and broadcast across every
    filter, while the filter word grid packs ONCE per layer per batch
    (§VI-C residency).  Tile sizes come from ``plan`` (a
    :class:`~repro.core.schedule.SlicePlan`) when given, else from
    :func:`~repro.core.schedule.plan_layer` — one plan object from the
    mapper to the packed engine.  Cycle accounting is unchanged by tiling
    or batching: each lane group reports the same ``per_dot_cycles`` as
    the untiled single-image formulation.

    ``engine`` names a registered backend (``core/backends.py``:
    ``host``, ``jit``, ``pallas-interpret``, ...); ``None`` resolves by
    the standing precedence explicit ``engine=`` > the plan's
    ``backend`` field (``plan_layer(..., backend=...)``) > the
    ``NC_BACKEND`` environment variable > host.  An explicit engine that
    contradicts a backend-carrying plan raises (the plan already
    decided).  ``engine="jit"`` runs tiles through the bucketed compiled
    engine (tiles are padded to a uniform shape so one executable serves
    the whole layer); ``return_stats=True`` appends a :class:`ConvStats`
    with the EIE-style zero-operand skip counts.

    Sparsity-aware execution: a plan carrying a
    :class:`~repro.core.schedule.LayerOccupancy` executes the PRUNED pass
    list — only live filter columns run through the packed engine, while
    the outputs of all-zero filters are filled from the exact affine
    identity ``zw * sum(x)`` (bit-identical to computing them; the cycle
    charge follows the executed lanes).  ``occupancy="detect"`` scans the
    quantized filter rows at pack time (``bitserial.filter_occupancy``)
    and plans sparse; an explicit :class:`LayerOccupancy` is validated
    against the actual weights (a filter it marks zero must BE zero —
    under-claiming sparsity is allowed, over-claiming raises).  Dense
    plans (no occupancy) behave exactly as before.

    §IV-E double buffering (``overlap=True``, or a plan that granted it):
    the engine runs the plan's explicit (load, compute) stage split —
    while tile k's MAC+reduce is in flight (the bucketed-jit dispatch is
    asynchronous), the host packs tile k+1's filter columns and window
    rows (the load stage), and tile k-1's finished result is retired; the
    device->host copy is deferred by exactly one tile (depth-1 pipeline,
    matching the single prefetch buffer the reserved I/O way has headroom
    for).  Results are byte-identical to the serial path — the flag only
    reorders WHEN packing and copies happen.  Like sparsity, overlap is a
    plan decision: requesting ``overlap=True`` alongside an explicit plan
    raises (the plan already decided).

    Integrity + fault path (PR 7, ``integrity=True`` or a plan that set
    it, and/or an active ``faults.inject`` scope): each tile pass runs
    checked — ABFT checksum columns (``bitserial.abft_checksums``) are
    verified against the pass's MAC+reduce output; a mismatch triggers
    bounded re-execution (clean operands are re-packed from the resident
    caches, which faults never mutate), repeated failure quarantines the
    pass's slice and re-plans through ``schedule.plan_layer`` over the
    survivors, and an unrecoverable pass raises
    ``faults.IntegrityError``.  Verification and re-execution charge §III
    cycles (one extra lane group per row + filter per verify; the full
    tile per re-execution).  The checked path executes tiles serially and
    stores immediately — outputs stay byte-identical to the unchecked
    path on clean passes, and with integrity off and no fault scope the
    original unchecked loop runs bit for bit.  Like sparsity and overlap,
    integrity is a plan decision: ``integrity=True`` alongside an
    explicit plan raises.

    Compressed filter residency (PR 8, ``compressed=True`` or a plan
    that set it): the layer's resident filter store is the CSR-per-bit-
    plane :class:`~repro.core.bitserial.CompressedPlanes` — live columns
    of live planes only — and each tile's filter slice is reconstructed
    from it before the packed MAC+reduce.  Dead columns/planes come back
    as zero words (the multiply's identity), so outputs are BYTE-
    IDENTICAL to dense execution at every pruning level
    (tests/test_sparsity.py's differential sweep).  Like sparsity,
    overlap and integrity, compression is a plan decision:
    ``compressed=True`` alongside an explicit plan raises.
    """
    xin = np.asarray(x)
    batched = xin.ndim == 4
    x4 = xin if batched else xin[None]
    B = x4.shape[0]
    x_qps = _as_qp_list(x_qp, B)
    wq = (np.asarray(w, np.int64)
          if np.issubdtype(np.asarray(w).dtype, np.integer)
          else _quantize_np(np.asarray(w), w_qp))
    xq = _quantize_images(x4, x_qps)
    R, S, Cw, M = wq.shape
    assert xq.shape[3] == Cw
    zxs = np.array([int(p.zero_point) for p in x_qps], np.int64)
    if padding == "SAME":
        ph = _same_pad(xq.shape[1], R, stride)
        pw = _same_pad(xq.shape[2], S, stride)
        padded = np.empty((B, xq.shape[1] + sum(ph), xq.shape[2] + sum(pw),
                           Cw), np.int64)
        padded[:] = zxs[:, None, None, None]  # per-image zero point
        padded[:, ph[0]:ph[0] + xq.shape[1], pw[0]:pw[0] + xq.shape[2]] = xq
        xq = padded
    elif padding != "VALID":
        raise ValueError(f"padding must be VALID or SAME, got {padding!r}")
    H = xq.shape[1]
    win, E, F = _extract_windows_batch(xq, R, S, stride)  # (B, E, F, K)
    K = R * S * Cw
    n_bits = max(x_qps[0].bits, w_qp.bits)
    acc_bits = 32

    # scheduler contract: the plan carries the mapper layout (word-line
    # budget already enforced), the geometry-bounded tile sizes and the
    # value-sparsity occupancy (the pruned pass list executed below).
    spec = layer_spec or LayerSpec(
        name="nc_conv2d", kind="conv", H=H, R=R, S=S, C=Cw, M=M, E=E,
        stride=stride)
    rows_total = B * E * F
    win_flat = win.reshape(rows_total, K).astype(np.uint8 if n_bits <= 8
                                                 else np.uint32)
    w_rows = wq.reshape(K, M).T.astype(np.uint8 if n_bits <= 8 else np.uint32)
    zw_int = int(w_qp.zero_point)
    replan = plan is None or tile_pixels is not None or tile_filters is not None
    if occupancy is not None and not replan:
        raise ValueError("pass sparsity through the plan's occupancy, or "
                         "let nc_conv2d plan (occupancy= with an explicit "
                         "plan is ambiguous)")
    if overlap and not replan:
        raise ValueError("request overlap through the plan "
                         "(plan_layer(..., overlap=True)); overlap= with "
                         "an explicit plan is ambiguous")
    if integrity and not replan:
        raise ValueError("request integrity through the plan "
                         "(plan_layer(..., integrity=True)); integrity= "
                         "with an explicit plan is ambiguous")
    if compressed and not replan:
        raise ValueError("request compression through the plan "
                         "(plan_layer(..., compressed=True)); compressed= "
                         "with an explicit plan is ambiguous")
    if (engine is not None and plan is not None
            and plan.backend not in (None, engine)):
        raise ValueError("pick the backend through the plan "
                         "(plan_layer(..., backend=...)); engine= "
                         "contradicting a backend-carrying plan is "
                         "ambiguous")
    if replan:
        occ = occupancy
        if isinstance(occ, str):
            if occ != "detect":
                raise ValueError(f"occupancy must be a LayerOccupancy, "
                                 f"'detect' or None, got {occ!r}")
            occ = sched.LayerOccupancy.from_filter_rows(
                w_rows, w_qp.bits, zw_int)
        quarantined: tuple = ()
        backend_pin: str | None = None
        if plan is not None:
            if occ is None:
                occ = plan.occupancy  # tile overrides must not drop sparsity
            overlap = overlap or plan.overlap  # ... nor drop double buffering
            integrity = integrity or plan.integrity  # ... nor drop checking
            compressed = compressed or plan.compressed  # ... nor decompress
            backend_pin = plan.backend  # ... nor drop the backend pin
            quarantined = plan.quarantined_slices
        plan = sched.plan_layer(spec, geom, batch=B, tile_pixels=tile_pixels,
                                tile_filters=tile_filters, occupancy=occ,
                                overlap=overlap, integrity=integrity,
                                quarantined_slices=quarantined,
                                compressed=compressed, backend=backend_pin)
    # backend selection is pure configuration: explicit engine= > the
    # plan's pin > NC_BACKEND > host (contradictions raised above)
    engine = _backends.resolve_backend(engine, plan.backend)
    tile_rows = max(1, min(plan.tile_rows, rows_total))
    tile_filters = max(1, min(plan.tile_filters, M))

    # sparse plans prune all-zero filters out of the engine's filter axis;
    # an over-claiming occupancy (marking a live filter zero) would corrupt
    # results, so it is validated against the actual quantized weights here
    occ = plan.occupancy
    if occ is not None and occ.zero_filters:
        if occ.total_filters != M:
            raise ValueError(f"{spec.name}: occupancy covers "
                             f"{occ.total_filters} filters, layer has {M}")
        zero_idx = np.asarray(occ.zero_filters, np.int64)
        not_zero = ~(w_rows[zero_idx] == zw_int).all(axis=1)
        if not_zero.any():
            raise ValueError(
                f"{spec.name}: occupancy marks filters "
                f"{zero_idx[not_zero].tolist()} as zero but their weights "
                f"are live (stale plan?)")
        zero_mask = np.zeros(M, bool)
        zero_mask[zero_idx] = True
        live_idx = np.flatnonzero(~zero_mask)
    else:
        zero_mask = live_idx = None

    w_rows_live = w_rows if live_idx is None else w_rows[live_idx]
    M_live = w_rows_live.shape[0]
    overlap_exec = bool(plan.overlap)
    compressed_exec = bool(plan.compressed)
    # filters packed once per layer per batch; tiles slice the word grid.
    # Under §IV-E double buffering the pack is deferred to the per-tile
    # load stage instead (each tile's columns still pack exactly once).
    # Compressed plans (PR 8) keep the CSR-per-bit-plane store resident
    # instead of the dense grid; tiles reconstruct their column slice.
    ww_all = cw_all = None
    if M_live and not overlap_exec:
        grid = _pack_w_rows(w_rows_live, w_qp.bits)
        if compressed_exec:
            cw_all = bs.CompressedPlanes.compress(grid)
        else:
            ww_all = grid
        del grid
    csr_bytes = [0, 0]  # measured (payload, index) bytes of the CSR store
    if cw_all is not None:
        csr_bytes = [cw_all.payload_bytes, cw_all.index_bytes]

    skip0_words = bs.SKIP_STATS.words_total
    skip0_skipped = bs.SKIP_STATS.words_skipped
    per_dot = bs.dot_cycles(K, n_bits, acc_bits)
    out = np.empty((rows_total, M), np.int64)
    n_tiles = 0
    # jit engine: pad every tile (ragged tails included) to the layer's
    # bucket_words sizes so one compiled executable serves the whole layer
    # (and any other layer landing on the same bucket)
    bt = bs.bucket_words(tile_rows) if engine == "jit" else tile_rows
    bf = bs.bucket_words(tile_filters) if engine == "jit" else None
    p_tiles = ([(p0, min(p0 + tile_rows, rows_total))
                for p0 in range(0, rows_total, tile_rows)] if M_live else [])
    m_tiles = [(m0, min(m0 + tile_filters, M_live))
               for m0 in range(0, M_live, tile_filters)]
    w_cache: dict[int, np.ndarray] = {}
    x_cache: dict[int, np.ndarray] = {}

    def _filter_tile(mi: int) -> np.ndarray:
        """Load stage: one pass's packed filter columns (§VI-C: each
        tile's columns pack exactly once per layer per batch)."""
        ww = w_cache.get(mi)
        if ww is None:
            m0, m1 = m_tiles[mi]
            if cw_all is not None:
                ww = cw_all.dense_columns(m0, m1)
            elif ww_all is not None:
                ww = ww_all[:, m0:m1]
            else:
                ww = _pack_w_rows(w_rows_live[m0:m1], w_qp.bits)
                if compressed_exec:
                    # §IV-E overlap defers packing per tile: the tile's
                    # columns still live CSR-compressed and reconstruct
                    # byte-identically before the MAC
                    cp = bs.CompressedPlanes.compress(ww)
                    csr_bytes[0] += cp.payload_bytes
                    csr_bytes[1] += cp.index_bytes
                    ww = cp.dense()
            if engine == "jit" and m1 - m0 < bf:
                pad = ((0, 0), (0, bf - (m1 - m0))) + ((0, 0),) * (ww.ndim - 2)
                ww = np.pad(ww, pad)
            w_cache[mi] = ww
        return ww

    def _x_tile(pi: int) -> np.ndarray:
        xw = x_cache.get(pi)
        if xw is None:
            p0, p1 = p_tiles[pi]
            rows = win_flat[p0:p1]
            if engine == "jit" and rows.shape[0] < bt:
                rows = np.pad(rows, ((0, bt - rows.shape[0]), (0, 0)))
            xw = _pack_x_rows(rows, x_qps[0].bits)
            x_cache[pi] = xw
        return xw

    def _store(vals, pi: int, mi: int) -> None:
        p0, p1 = p_tiles[pi]
        m0, m1 = m_tiles[mi]
        v = np.asarray(vals)  # (Mt, T[, expanded rows]); blocks on jit
        sel = slice(m0, m1) if live_idx is None else live_idx[m0:m1]
        out[p0:p1, sel] = v[: m1 - m0, : p1 - p0].T

    order = [(pi, mi) for pi in range(len(p_tiles))
             for mi in range(len(m_tiles))]
    # PR 7 checked path: active fault scope and/or an integrity plan runs
    # every tile serially through verify/retry/quarantine; otherwise the
    # unchecked loop below runs bit for bit (standing off-switch idiom)
    fs = faults.active()
    integrity_on = bool(plan.integrity)
    checked = integrity_on or fs is not None
    eff_plan = plan
    verify_passes = reexec_passes = faults_detected = 0
    integrity_cycles = reexec_cycles = 0
    if checked:
        P_lay, _, r_lay = bs._row_layout(K)
        cs_refs: dict = {}
        lanes_f: dict = {}
        lanes_a: dict = {}

        def _refs(pi: int, mi: int):
            """Clean ABFT references for tile (pi, mi), encoded once from
            the resident operands (the load-time checksum columns)."""
            got = cs_refs.get((pi, mi))
            if got is None:
                p0, p1 = p_tiles[pi]
                m0, m1 = m_tiles[mi]
                got = cs_refs[(pi, mi)] = bs.abft_checksums(
                    win_flat[p0:p1], w_rows_live[m0:m1])
            return got

        def _live_lanes_filter(pi: int) -> np.ndarray:
            """Lanes where a filter-side fault provably changes output:
            the window rows riding bit slot 0 (the injected replica) have
            a nonzero lane sum there."""
            got = lanes_f.get(pi)
            if got is None:
                p0, p1 = p_tiles[pi]
                sums = win_flat[p0:p1][0::r_lay].sum(axis=0, dtype=np.int64)
                got = lanes_f[pi] = np.flatnonzero(sums > 0)
            return got

        def _live_lanes_act(mi: int) -> np.ndarray:
            """Lanes where an activation-side fault provably changes
            output: some live filter is nonzero there."""
            got = lanes_a.get(mi)
            if got is None:
                m0, m1 = m_tiles[mi]
                sums = w_rows_live[m0:m1].sum(axis=0, dtype=np.int64)
                got = lanes_a[mi] = np.flatnonzero(sums > 0)
            return got

        max_retries = fs.profile.max_retries if fs is not None else 1
        for t, (pi, mi) in enumerate(order):
            for stale in [k for k in x_cache if k < pi]:
                del x_cache[stale]
            p0, p1 = p_tiles[pi]
            m0, m1 = m_tiles[mi]
            attempts = 0       # retry budget (refreshed by a quarantine)
            execs = 0          # total executions of this tile
            quarantine_rounds = 0
            while True:
                execs += 1
                xw = _x_tile(pi)
                ww = _filter_tile(mi)
                corrupted = False
                if fs is not None:
                    fs.maybe_stall(spec.name, t)
                    ww2 = fs.corrupt_filter_words(
                        ww, spec.name, t, lanes=_live_lanes_filter(pi),
                        filters=m1 - m0, P=P_lay, r=r_lay)
                    xw2 = fs.corrupt_act_words(
                        xw, spec.name, t, lanes=_live_lanes_act(mi),
                        rows=p1 - p0, P=P_lay, r=r_lay)
                    corrupted = ww2 is not ww or xw2 is not xw
                    xw, ww = xw2, ww2
                vals, _ = bs.packed_dot_words(
                    xw, ww, K=K, acc_bits=acc_bits, engine=engine)
                v2 = np.asarray(vals)[: m1 - m0, : p1 - p0]
                if fs is not None:
                    v3 = fs.corrupt_values(v2, spec.name, t,
                                           filters=m1 - m0, rows=p1 - p0)
                    corrupted = corrupted or v3 is not v2
                    v2 = v3
                    if corrupted:
                        fs.note_corrupt_attempt()
                if execs == 1:
                    n_tiles += 1
                else:
                    reexec_passes += 1
                    reexec_cycles += per_dot * (p1 - p0) * (m1 - m0)
                    if fs is not None:
                        fs.note_reexecution()
                if not integrity_on:
                    break  # faults without checking: corruption flows through
                verify_passes += 1
                integrity_cycles += per_dot * ((p1 - p0) + (m1 - m0))
                ref_col, ref_row = _refs(pi, mi)
                if ((v2.sum(axis=0, dtype=np.int64) == ref_col).all()
                        and (v2.sum(axis=1, dtype=np.int64) == ref_row).all()):
                    break
                faults_detected += 1
                if fs is not None:
                    fs.note_detected()
                attempts += 1
                if attempts <= max_retries:
                    continue
                # retry budget exhausted — only a persistent (stuck-at)
                # fault survives clean re-execution, so quarantine the
                # pass's slice, re-plan over the survivors (the pass ->
                # slice map shifts off the dead slice) and grant one
                # fresh budget; unrecoverable passes raise
                sid = fs.slice_for(spec.name, t) if fs is not None else None
                can_quarantine = (
                    fs is not None and sid is not None
                    and sid not in fs.quarantined
                    and len(fs.quarantined) < geom.n_slices - 1
                    and quarantine_rounds < geom.n_slices)
                if not can_quarantine:
                    raise faults.IntegrityError(spec.name, t, attempts)
                fs.quarantine(sid)
                quarantine_rounds += 1
                eff_plan = sched.plan_layer(
                    spec, geom, batch=B,
                    tile_pixels=tile_rows, tile_filters=tile_filters,
                    occupancy=plan.occupancy, overlap=plan.overlap,
                    integrity=True,
                    quarantined_slices=tuple(sorted(fs.quarantined)),
                    compressed=plan.compressed)
                attempts = 0
            _store(v2, pi, mi)
    else:
        pending = None  # §IV-E double buffer: one dispatched tile in flight
        for t, (pi, mi) in enumerate(order):
            for stale in [k for k in x_cache if k < pi]:
                del x_cache[stale]  # row tiles behind the pipeline are done
            vals, _ = bs.packed_dot_words(
                _x_tile(pi), _filter_tile(mi), K=K, acc_bits=acc_bits,
                engine=engine, materialize=not overlap_exec)
            n_tiles += 1
            if not overlap_exec:
                _store(vals, pi, mi)
                continue
            # tile t's MAC+reduce is in flight (asynchronous dispatch): run
            # tile t+1's load stage NOW — pack the next pass's filter columns
            # and window rows while t computes — then retire tile t-1, whose
            # result the device finished before starting t
            if t + 1 < len(order):
                npi, nmi = order[t + 1]
                _filter_tile(nmi)
                _x_tile(npi)
            if pending is not None:
                _store(*pending)
            pending = (vals, pi, mi)
        if pending is not None:
            _store(*pending)
    if zero_mask is not None:
        # pruned passes: an all-zero filter's dot is the affine constant
        # zw * sum_k(x_k) — exact, no engine lanes clocked for it
        row_sums = win_flat.sum(axis=1, dtype=np.int64)
        out[:, zero_mask] = zw_int * row_sums[:, None]
    total_cycles = per_dot * rows_total * M_live  # one dot per live (b,e,f,m)
    # PR 7: checksum verifications + re-executed tiles charge the same §III
    # formulas as the real work — an additive term, zero when unchecked
    total_cycles += integrity_cycles + reexec_cycles

    # affine-zero-point correction (done by the accumulating requant step
    # in-cache; exact integer identity — zero points are per image)
    sx = win.sum(axis=-1)  # (B, E, F)
    sw = wq.sum(axis=(0, 1, 2))  # (M,)
    zx = zxs[:, None, None, None]
    acc = (
        out.reshape(B, E, F, M)
        - int(w_qp.zero_point) * sx[..., None]
        - zx * sw[None, None, None, :]
        + K * zx * int(w_qp.zero_point)
    )
    result = jnp.asarray(acc if batched else acc[0], jnp.int32)
    if not return_stats:
        return result, total_cycles
    # separable zero-operand count: sum_k (#zero-free windows_k)*(#zero-free w_k)
    cx = (win_flat != 0).sum(axis=0).astype(np.int64)  # (K,)
    cw = (w_rows != 0).sum(axis=0).astype(np.int64)  # (K,)
    live = int((cx * cw).sum())
    stats = ConvStats(
        lanes=rows_total * M * K,
        zero_operand_lanes=rows_total * M * K - live,
        tiles=n_tiles,
        tile_pixels=tile_rows,
        tile_filters=tile_filters,
        serial_passes=eff_plan.serial_passes,
        engine_words_total=bs.SKIP_STATS.words_total - skip0_words,
        engine_words_skipped=bs.SKIP_STATS.words_skipped - skip0_skipped,
        batch=B,
        filter_loads=1,
        zero_filters=M - M_live,
        skipped_passes=eff_plan.skipped_passes,
        overlap=overlap_exec and not checked,  # checked path runs serially
        integrity=integrity_on,
        verify_passes=verify_passes,
        reexec_passes=reexec_passes,
        faults_detected=faults_detected,
        integrity_cycles=integrity_cycles,
        reexec_cycles=reexec_cycles,
        quarantined_slices=eff_plan.quarantined_slices,
        compressed=compressed_exec,
        csr_payload_bytes=csr_bytes[0],
        csr_index_bytes=csr_bytes[1],
        plan=eff_plan,
    )
    return result, total_cycles, stats


def nc_maxpool2d(x_q: jax.Array, window: int, stride: int,
                 padding: str = "VALID"):
    """uint8 max pooling via subtract + MSB-masked copies (§IV-D).

    Accepts ``[H, W, C]`` or ``[B, H, W, C]``; all B x E x F x C output
    lanes advance in lockstep through the window^2 - 1 sequential max
    steps (cycle count stays per-pixel, as the per-pixel formulation
    reported it)."""
    xin = np.asarray(x_q, np.int64)
    batched = xin.ndim == 4
    xq = xin if batched else xin[None]
    if padding == "SAME":
        ph = _same_pad(xq.shape[1], window, stride)
        pw = _same_pad(xq.shape[2], window, stride)
        xq = np.pad(xq, ((0, 0), ph, pw, (0, 0)))  # uint8 min
    win, E, F = _extract_windows_batch(xq, window, window, stride)
    B, C = xq.shape[0], xq.shape[3]
    win = win.reshape(B, E, F, window * window, C)
    cur = bs.pack_values(win[:, :, :, 0].astype(np.uint32), 8)
    cycles = 0
    for t in range(1, window * window):
        nxt = bs.pack_values(win[:, :, :, t].astype(np.uint32), 8)
        cur, c = bs.bitserial_max(cur, nxt)
        cur = cur[:8]
        cycles += c * B * E * F
    out = bs.unpack_values(cur)  # (B, E, F, C)
    return jnp.asarray(out if batched else out[0], jnp.uint8), cycles


def nc_avgpool2d(x_q: jax.Array, window: int, stride: int,
                 padding: str = "VALID"):
    """uint8 average pooling: in-array window-sum via the §III-D log tree,
    then the §III-C bit-serial divide (rounded; SAME padding divides by the
    pad-excluded window population, matching the float reference — exact
    under the affine identity only for zero_point == 0, which holds for
    every post-ReLU activation in the §IV-D pipeline).

    Accepts ``[H, W, C]`` or ``[B, H, W, C]``.  Cycles per output lane
    group: the widening sum tree over the window plus one 8-bit divide."""
    xin = np.asarray(x_q, np.int64)
    batched = xin.ndim == 4
    xq = xin if batched else xin[None]
    B, H, W, C = xq.shape
    ones = np.ones((H, W, 1), np.int64)
    if padding == "SAME":
        ph = _same_pad(H, window, stride)
        pw = _same_pad(W, window, stride)
        xq = np.pad(xq, ((0, 0), ph, pw, (0, 0)))
        ones = np.pad(ones, (ph, pw, (0, 0)))
    win, E, F = _extract_windows_batch(xq, window, window, stride)
    w2 = window * window
    # reduce axis last: (B, E, F, C, W2) rows of the window population
    rows = win.reshape(B, E, F, w2, C).transpose(0, 1, 2, 4, 3)
    pp = bs.pack_values(rows.astype(np.uint32), 8, row_align=True)
    red, c_red = bs.bitserial_reduce(pp)
    sums = bs.unpack_values(red)[..., 0]  # (B, E, F, C)
    counts, _, _ = _extract_windows(ones, window, window, stride)
    counts = counts.reshape(E, F, w2, 1).sum(axis=2)  # (E, F, 1)
    out = (sums + counts // 2) // counts  # rounded integer divide
    cycles = int(B * E * F * (c_red + bs.div_cycles(8)))
    out = np.clip(out, 0, 255)
    return jnp.asarray(out if batched else out[0], jnp.uint8), cycles


def nc_minmax(x_q, bits: int = 32, signed: bool = False):
    """§IV-D in-cache dynamic range: min AND max of quantized values via a
    bit-serial log tree (subtract + tag-masked copy per halving step), run
    entirely in packed word space — only the two scalars per row leave the
    cache, exactly the "two numbers sent to the CPU" of the paper's
    quantization pipeline.

    ``x_q``: integer array whose LAST axis is reduced; leading axes (e.g.
    the image batch) are independent rows advancing in lockstep.  Rows are
    pre-padded to the next power of two with copies of their first lane so
    padding never pollutes the min.  ``signed`` treats values as
    ``bits``-wide two's complement (the int32 accumulator case): the sign
    plane is biased on the way in and the scalars un-biased on the way out
    (one extra cycle each way — an XOR pass on a single plane).

    Returns ``(mins, maxs, cycles)`` — arrays shaped like the leading
    axes — with ``cycles == bitserial.minmax_cycles(K, bits)``
    (+2 when ``signed``); all rows share the one lockstep tree.
    """
    x = np.asarray(x_q)
    lead = x.shape[:-1]
    K = x.shape[-1] if x.ndim else 1
    rows = x.reshape(-1, K).astype(np.int64)
    bias = (1 << (bits - 1)) if signed else 0
    u = ((rows + bias) & ((1 << bits) - 1)).astype(np.uint64)
    P = 1 << max(0, (K - 1).bit_length())
    padded = np.empty((u.shape[0], P), np.uint64)
    padded[:, :K] = u
    padded[:, K:] = u[:, :1]  # neutral pad: a copy of a real lane
    pp = bs.pack_values(padded, bits, row_align=True)
    (mn_pp, mx_pp), cycles = bs.bitserial_minmax(pp)
    mn = bs.unpack_values(mn_pp).reshape(-1) - bias
    mx = bs.unpack_values(mx_pp).reshape(-1) - bias
    if signed:
        cycles += 2  # sign-plane bias in + un-bias out
    return mn.reshape(lead), mx.reshape(lead), cycles


def nc_relu_requant(
    acc: jax.Array, real_multiplier: float, out_zp: int = 0
) -> jax.Array:
    """ReLU on the int32 accumulator then fixed-point requant to uint8 —
    the in-cache epilogue of every conv layer."""
    acc = jnp.maximum(acc, 0)  # MSB-masked zero write
    m, s = q.fixed_point_multiplier(jnp.float32(real_multiplier))
    return q.requantize_fixedpoint(acc, m, s, zero_point=out_zp).astype(jnp.uint8)


def nc_fc(x: jax.Array, w: jax.Array,
          x_qp: q.QuantParams | Sequence[q.QuantParams],
          w_qp: q.QuantParams, **conv_kwargs):
    """FC as a 1x1 conv over a 1x1 'image' (§IV-D).

    ``x``: [K] or batched [B, K] (each row one image's feature vector —
    the batch folds into the conv's row axis); tiling kwargs pass through
    to :func:`nc_conv2d`."""
    xa = np.asarray(x)
    w4 = np.asarray(w)[None, None, :, :]
    if xa.ndim == 2:  # batched: [B, K] -> [B, 1, 1, K] image batch
        res = nc_conv2d(xa[:, None, None, :], w4, x_qp, w_qp, **conv_kwargs)
        if len(res) == 3:
            out, cycles, stats = res
            return out[:, 0, 0], cycles, stats
        out, cycles = res
        return out[:, 0, 0], cycles
    res = nc_conv2d(xa[None, None, :], w4, x_qp, w_qp, **conv_kwargs)
    if len(res) == 3:
        out, cycles, stats = res
        return out[0, 0], cycles, stats
    out, cycles = res
    return out[0, 0], cycles
