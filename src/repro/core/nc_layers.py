"""Functional execution of DNN layers through the bit-serial engine.

This is the *correctness* counterpart of core/simulator.py (which models
time/energy): each layer is computed element-for-element the way the cache
would — uint8 operands, bit-plane transposed layout, tag-predicated MACs,
in-array log-tree channel reduction, fixed-point requantization — and is
validated against jnp oracles in tests/test_nc_layers.py.

Packed-resident, tiled pipeline
-------------------------------
The engine's :class:`~repro.core.bitserial.PackedPlanes` word format is the
resident representation end to end: operands are packed straight into
row-aligned word space (``pack_values(..., row_align=True)``), the MAC and
the §III-D log-tree reduction run on words, and only the final per-row sums
are decoded — no per-lane plane tensor is ever materialized.

Work is tiled over **output pixels x filters** the way the mapper
serializes passes (core/mapper.py): a tile's lane count is bounded by the
cache geometry (``geom.compute_slots`` bit lines), so peak host memory
follows the modeled hardware instead of E*F*M*K.  Within a tile, the
packed *window* rows are packed once and broadcast across every filter at
word granularity (and the packed filter rows across every pixel) — the
word-level analogue of filter replication across arrays (§IV-B).  The
tiler consults ``mapper.check_wordline_budget`` and refuses layers whose
per-bit-line working set cannot fit the modeled array.

Layer cycle counts are Python ints and are *unchanged* by tiling or
packing: each (pixel, filter) lane group still reports the same
``per_dot_cycles`` (mul + accumulate + log-tree), so total modeled cycles
are bit-identical to the untiled formulation — the emulation got faster,
the modeled hardware did not.  ``engine="jit"`` routes tiles through the
bucketed compiled engine (see core/bitserial.py) for sweep workloads.

The TPU-fast path lives in repro/kernels.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitserial as bs
from repro.core import quantize as q
from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import LayerSpec, check_wordline_budget, map_layer

__all__ = [
    "nc_dot",
    "nc_conv2d",
    "nc_maxpool2d",
    "nc_avgpool2d",
    "nc_relu_requant",
    "nc_fc",
    "ConvStats",
]


@dataclasses.dataclass(frozen=True)
class ConvStats:
    """Per-layer emulation accounting notes (cycles stay formula-exact)."""

    lanes: int  # E*F*M*K MAC lanes
    zero_operand_lanes: int  # lanes a tag latch could predicate off (EIE-style)
    tiles: int
    tile_pixels: int
    tile_filters: int
    serial_passes: int  # mapper's modeled pass count for the layer
    engine_words_total: int  # host-engine word columns seen by the multiplier
    engine_words_skipped: int  # word columns elided (all-zero operand)


def nc_dot(x_q, w_q, acc_bits: int = 24, n_bits: int = 8):
    """Quantized dot products, one per bit-line group.

    x_q: [..., K] uint8 inputs, w_q: [..., K] uint8 filters (same shape).
    Each of the K lanes performs one ``n_bits`` MAC into a ``acc_bits``-bit
    partial sum, then the lanes reduce via the in-array log tree.  Returns
    (int values [...], cycles) — bit-exact with the integer dot product.

    Packed-resident: operands go straight to row-aligned words and the
    MAC feeds the reducer without leaving packed space.
    """
    x_q = np.asarray(x_q)
    w_q = np.asarray(w_q)
    K = x_q.shape[-1]
    P, wpr, r = bs._row_layout(K)
    xw = bs.pack_values(x_q, n_bits, row_align=True).words
    ww = bs.pack_values(w_q, n_bits, row_align=True).words
    if r == 1:
        xw = xw.reshape(n_bits, -1, wpr)
        ww = ww.reshape(n_bits, -1, wpr)
    vals, cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=acc_bits)
    n_rows = int(np.prod(x_q.shape[:-1])) if x_q.ndim > 1 else 1
    vals = np.asarray(vals).reshape(-1)[:n_rows]
    return vals.reshape(x_q.shape[:-1]), cycles


def _quantize_np(x, qp: q.QuantParams) -> np.ndarray:
    """Host mirror of core.quantize.quantize (float32 divide +
    round-half-even + clip — bit-identical to the jnp path)."""
    scale = np.float32(qp.scale)
    zp = int(qp.zero_point)
    vals = np.round(np.asarray(x, np.float32) / scale) + zp
    return np.clip(vals, qp.qmin, qp.qmax).astype(np.int64)


def _same_pad(h: int, r: int, stride: int) -> tuple[int, int]:
    """TF/lax SAME convention: total pad so out = ceil(h/stride); extra
    padding goes after (bottom/right)."""
    out = -(-h // stride)
    total = max((out - 1) * stride + r - h, 0)
    return total // 2, total - total // 2


def _extract_windows(x: np.ndarray, R: int, S: int, stride: int):
    """[H, W, C] -> ([E, F, R*S*C] window tensor, E, F) (VALID padding)."""
    H, W, C = x.shape
    E = (H - R) // stride + 1
    F = (W - S) // stride + 1
    rows = np.arange(E)[:, None] * stride + np.arange(R)[None, :]  # (E, R)
    cols = np.arange(F)[:, None] * stride + np.arange(S)[None, :]  # (F, S)
    win = x[rows][:, :, cols]  # (E, R, F, S, C)
    return win.transpose(0, 2, 1, 3, 4).reshape(E, F, R * S * C), E, F


def _pack_x_rows(rows: np.ndarray, n_bits: int) -> np.ndarray:
    """Window rows (T, K) -> broadcastable word grid (n, 1, ...) shared by
    every filter in the tile (the packed-plane reuse across filters)."""
    K = rows.shape[-1]
    P, wpr, r = bs._row_layout(K)
    w = bs.pack_values(rows, n_bits, row_align=True).words
    if r == 1:
        return w.reshape(n_bits, 1, rows.shape[0], wpr)
    return w.reshape(n_bits, 1, -1)  # (n, 1, ceil(T/r)) — rows share words


def _pack_w_rows(rows: np.ndarray, n_bits: int) -> np.ndarray:
    """Filter rows (M, K) -> broadcastable word grid (n, M, 1[, wpr]).

    For P < 32 each word of the dot grid holds 32/P *pixel* rows of one
    filter, so the filter's P-bit pattern is replicated across the word."""
    K = rows.shape[-1]
    P, wpr, r = bs._row_layout(K)
    if r == 1:
        w = bs.pack_values(rows, n_bits, row_align=True).words
        return w.reshape(n_bits, rows.shape[0], 1, wpr)
    rep = sum(1 << (j * P) for j in range(r))
    ks = np.arange(K, dtype=np.uint64)
    out = np.empty((n_bits, rows.shape[0]), np.uint64)
    rows = rows.astype(np.uint64)
    for p in range(n_bits):
        rowval = (((rows >> np.uint64(p)) & 1) << ks).sum(axis=1)
        out[p] = rowval * rep
    return out.astype(np.uint32)[:, :, None]


def _conv_tiles(E: int, F: int, M: int, K: int,
                geom: CacheGeometry,
                tile_pixels: int | None,
                tile_filters: int | None) -> tuple[int, int]:
    """Default tile sizes: bound a tile's bit-line count (rows x P padded
    lanes) by the cache's compute slots, preferring whole-pixel tiles."""
    P = bs._row_layout(K)[0]
    cap = max(geom.compute_slots, P)
    # clamp caller-supplied sizes first so the derived dimension is sized
    # for the effective tile, not an oversized request
    if tile_pixels is not None:
        tile_pixels = min(tile_pixels, E * F)
    if tile_filters is not None:
        tile_filters = min(tile_filters, M)
    if tile_pixels is None and tile_filters is None:
        if P * E * F * M <= cap:
            return E * F, M
        tf = cap // (P * E * F)
        if tf >= 1:
            return E * F, int(tf)
        return max(1, cap // P), 1
    if tile_filters is None:
        tile_filters = max(1, min(M, cap // (P * tile_pixels)))
    if tile_pixels is None:
        tile_pixels = max(1, min(E * F, cap // (P * tile_filters)))
    return min(tile_pixels, E * F), min(tile_filters, M)


def nc_conv2d(
    x: jax.Array,
    w: jax.Array,
    x_qp: q.QuantParams,
    w_qp: q.QuantParams,
    stride: int = 1,
    *,
    padding: str = "VALID",
    tile_pixels: int | None = None,
    tile_filters: int | None = None,
    geom: CacheGeometry = XEON_E5_35MB,
    layer_spec: LayerSpec | None = None,
    engine: str = "host",
    return_stats: bool = False,
):
    """Quantized conv through the array model (packed-resident + tiled).

    x: [H, W, C] float, w: [R, S, C, M] float.  Both are quantized
    (zero-point affine, ``qp.bits`` planes), the cross terms of
    (x-zx)(w-zw) are handled exactly as the integer expansion, and the
    result is returned as int32 — what the reserved-way staging would hold
    before requantization.  ``padding="SAME"`` pads with the quantized
    zero point (exact under the affine identity).

    Every (output pixel, filter) pair is a lane group.  Work is tiled over
    output pixels and filters so a tile's bit lines fit the cache geometry
    (peak memory is bounded by ``geom.compute_slots``, not E*F*M*K); the
    packed window rows of a pixel tile are packed once and broadcast
    across every filter.  Cycle accounting is unchanged by tiling: each
    lane group reports the same ``per_dot_cycles`` as the untiled
    formulation.

    ``engine="jit"`` runs tiles through the bucketed compiled engine
    (tiles are padded to a uniform shape so one executable serves the
    whole layer); ``return_stats=True`` appends a :class:`ConvStats` with
    the EIE-style zero-operand skip counts.
    """
    xq = _quantize_np(np.asarray(x), x_qp)
    wq = _quantize_np(np.asarray(w), w_qp)
    R, S, Cw, M = wq.shape
    assert xq.shape[2] == Cw
    if padding == "SAME":
        ph = _same_pad(xq.shape[0], R, stride)
        pw = _same_pad(xq.shape[1], S, stride)
        xq = np.pad(xq, (ph, pw, (0, 0)),
                    constant_values=int(x_qp.zero_point))
    elif padding != "VALID":
        raise ValueError(f"padding must be VALID or SAME, got {padding!r}")
    H = xq.shape[0]
    win, E, F = _extract_windows(xq, R, S, stride)  # (E, F, K)
    K = R * S * Cw
    n_bits = max(x_qp.bits, w_qp.bits)
    acc_bits = 32

    # mapper contract: refuse layers whose bit-line working set overflows
    # the array's word lines (a silent over-allocation in hardware).
    spec = layer_spec or LayerSpec(
        name="nc_conv2d", kind="conv", H=H, R=R, S=S, C=Cw, M=M, E=E,
        stride=stride)
    mapped = map_layer(spec, geom)
    check_wordline_budget(mapped, geom)

    tile_pixels, tile_filters = _conv_tiles(E, F, M, K, geom, tile_pixels,
                                            tile_filters)

    win_flat = win.reshape(E * F, K).astype(np.uint8 if n_bits <= 8
                                            else np.uint32)
    w_rows = wq.reshape(K, M).T.astype(np.uint8 if n_bits <= 8 else np.uint32)
    # filters packed once for the whole layer; tiles slice the word grid
    ww_all = _pack_w_rows(w_rows, w_qp.bits)

    skip0_words = bs.SKIP_STATS.words_total
    skip0_skipped = bs.SKIP_STATS.words_skipped
    per_dot = bs.dot_cycles(K, n_bits, acc_bits)
    out = np.empty((E * F, M), np.int64)
    n_tiles = 0
    # jit engine: pad every tile (ragged tails included) to the layer's
    # bucket_words sizes so one compiled executable serves the whole layer
    # (and any other layer landing on the same bucket)
    bt = bs.bucket_words(tile_pixels) if engine == "jit" else tile_pixels
    bf = bs.bucket_words(tile_filters) if engine == "jit" else None
    for p0 in range(0, E * F, tile_pixels):
        p1 = min(p0 + tile_pixels, E * F)
        rows = win_flat[p0:p1]
        if engine == "jit" and rows.shape[0] < bt:
            rows = np.pad(rows, ((0, bt - rows.shape[0]), (0, 0)))
        xw = _pack_x_rows(rows, x_qp.bits)
        for m0 in range(0, M, tile_filters):
            m1 = min(m0 + tile_filters, M)
            ww = ww_all[:, m0:m1]
            if engine == "jit" and m1 - m0 < bf:
                pad = ((0, 0), (0, bf - (m1 - m0))) + ((0, 0),) * (ww.ndim - 2)
                ww = np.pad(ww, pad)
            vals, _ = bs.packed_dot_words(xw, ww, K=K, acc_bits=acc_bits,
                                          engine=engine)
            vals = np.asarray(vals)  # (Mt, T[, expanded rows])
            out[p0:p1, m0:m1] = vals[: m1 - m0, : p1 - p0].T
            n_tiles += 1
    total_cycles = per_dot * E * F * M  # per-dot cost, one dot per (e,f,m)

    # affine-zero-point correction (done by the accumulating requant step
    # in-cache; exact integer identity)
    sx = win.sum(axis=-1)  # (E, F)
    sw = wq.sum(axis=(0, 1, 2))  # (M,)
    acc = (
        out.reshape(E, F, M)
        - int(w_qp.zero_point) * sx[:, :, None]
        - int(x_qp.zero_point) * sw[None, None, :]
        + K * int(x_qp.zero_point) * int(w_qp.zero_point)
    )
    result = jnp.asarray(acc, jnp.int32)
    if not return_stats:
        return result, total_cycles
    # separable zero-operand count: sum_k (#zero-free windows_k)*(#zero-free w_k)
    cx = (win_flat != 0).sum(axis=0).astype(np.int64)  # (K,)
    cw = (w_rows != 0).sum(axis=0).astype(np.int64)  # (K,)
    live = int((cx * cw).sum())
    stats = ConvStats(
        lanes=E * F * M * K,
        zero_operand_lanes=E * F * M * K - live,
        tiles=n_tiles,
        tile_pixels=tile_pixels,
        tile_filters=tile_filters,
        serial_passes=mapped.serial_passes,
        engine_words_total=bs.SKIP_STATS.words_total - skip0_words,
        engine_words_skipped=bs.SKIP_STATS.words_skipped - skip0_skipped,
    )
    return result, total_cycles, stats


def nc_maxpool2d(x_q: jax.Array, window: int, stride: int,
                 padding: str = "VALID"):
    """uint8 max pooling via subtract + MSB-masked copies (§IV-D).

    All E x F x C output lanes advance in lockstep through the window^2 - 1
    sequential max steps (cycle count stays per-pixel, as the per-pixel
    formulation reported it)."""
    xq = np.asarray(x_q, np.int64)
    if padding == "SAME":
        ph = _same_pad(xq.shape[0], window, stride)
        pw = _same_pad(xq.shape[1], window, stride)
        xq = np.pad(xq, (ph, pw, (0, 0)))  # uint8 min
    win, E, F = _extract_windows(xq, window, window, stride)
    C = x_q.shape[2]
    win = win.reshape(E, F, window * window, C)
    cur = bs.pack_values(win[:, :, 0].astype(np.uint32), 8)
    cycles = 0
    for t in range(1, window * window):
        nxt = bs.pack_values(win[:, :, t].astype(np.uint32), 8)
        cur, c = bs.bitserial_max(cur, nxt)
        cur = cur[:8]
        cycles += c * E * F
    out = bs.unpack_values(cur)  # (E, F, C)
    return jnp.asarray(out, jnp.uint8), cycles


def nc_avgpool2d(x_q: jax.Array, window: int, stride: int,
                 padding: str = "VALID"):
    """uint8 average pooling: in-array window-sum via the §III-D log tree,
    then the §III-C bit-serial divide (rounded; SAME padding divides by the
    pad-excluded window population, matching the float reference).

    Cycles per output lane group: the widening sum tree over the window
    plus one 8-bit divide."""
    xq = np.asarray(x_q, np.int64)
    H, W, C = xq.shape
    ones = np.ones((H, W, 1), np.int64)
    if padding == "SAME":
        ph = _same_pad(H, window, stride)
        pw = _same_pad(W, window, stride)
        xq = np.pad(xq, (ph, pw, (0, 0)))
        ones = np.pad(ones, (ph, pw, (0, 0)))
    win, E, F = _extract_windows(xq, window, window, stride)  # (E,F,W2*C)
    w2 = window * window
    # reduce axis last: (E, F, C, W2) rows of the window population
    rows = win.reshape(E, F, w2, C).transpose(0, 1, 3, 2).astype(np.uint32)
    pp = bs.pack_values(rows, 8, row_align=True)
    red, c_red = bs.bitserial_reduce(pp)
    sums = bs.unpack_values(red)[..., 0]  # (E, F, C)
    counts, _, _ = _extract_windows(ones, window, window, stride)
    counts = counts.reshape(E, F, w2, 1).sum(axis=2)  # (E, F, 1)
    out = (sums + counts // 2) // counts  # rounded integer divide
    cycles = int(E * F * (c_red + bs.div_cycles(8)))
    return jnp.asarray(np.clip(out, 0, 255), jnp.uint8), cycles


def nc_relu_requant(
    acc: jax.Array, real_multiplier: float, out_zp: int = 0
) -> jax.Array:
    """ReLU on the int32 accumulator then fixed-point requant to uint8 —
    the in-cache epilogue of every conv layer."""
    acc = jnp.maximum(acc, 0)  # MSB-masked zero write
    m, s = q.fixed_point_multiplier(jnp.float32(real_multiplier))
    return q.requantize_fixedpoint(acc, m, s, zero_point=out_zp).astype(jnp.uint8)


def nc_fc(x: jax.Array, w: jax.Array, x_qp: q.QuantParams, w_qp: q.QuantParams,
          **conv_kwargs):
    """FC as a 1x1 conv over a 1x1 'image' (§IV-D); tiling kwargs pass
    through to :func:`nc_conv2d`."""
    res = nc_conv2d(np.asarray(x)[None, None, :],
                    np.asarray(w)[None, None, :, :], x_qp, w_qp, **conv_kwargs)
    if len(res) == 3:
        out, cycles, stats = res
        return out[0, 0], cycles, stats
    out, cycles = res
    return out[0, 0], cycles
