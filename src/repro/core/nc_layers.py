"""Functional execution of DNN layers through the bit-serial engine.

This is the *correctness* counterpart of core/simulator.py (which models
time/energy): each layer is computed element-for-element the way the cache
would — uint8 operands, bit-plane transposed layout, tag-predicated MACs,
in-array log-tree channel reduction, fixed-point requantization — and is
validated against jnp oracles in tests/test_nc_layers.py.

All output pixels and filters are *lanes*: conv extracts every RxSxC window
up front and runs ONE packed MAC + log-tree reduction over (E, F, M, K)
lanes, exactly the way the cache computes every output in lockstep (and the
way the word-packed engine in core/bitserial.py wants its work: 32 lanes
per uint32 word, no Python loops over pixels).  Layer cycle counts are
Python ints (these functions are inherently eager, like the per-pixel
formulation before them), so the layer math runs on the engine's host
(numpy) fast path; accounting is unchanged: each lane group still reports
``per_dot_cycles * n_dots`` — the emulation got faster, the modeled
hardware did not.  The TPU-fast path lives in repro/kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitserial as bs
from repro.core import quantize as q

__all__ = ["nc_dot", "nc_conv2d", "nc_maxpool2d", "nc_relu_requant", "nc_fc"]


def nc_dot(x_q, w_q, acc_bits: int = 24):
    """Quantized dot products, one per bit-line group.

    x_q: [..., K] uint8 inputs, w_q: [..., K] uint8 filters (same shape).
    Each of the K lanes performs one 8-bit MAC into a ``acc_bits``-bit
    partial sum, then the lanes reduce via the in-array log tree.  Returns
    (int values [...], cycles) — bit-exact with the integer dot product.
    """
    xp = bs.bitplane_pack(np.asarray(x_q, np.uint32), 8)
    wp = bs.bitplane_pack(np.asarray(w_q, np.uint32), 8)
    acc = np.zeros((acc_bits,) + xp.shape[1:], np.uint8)
    acc, c_mac = bs.bitserial_mac(acc, xp, wp)
    red, c_red = bs.bitserial_reduce(acc)
    return bs.bitplane_unpack(red)[..., 0], c_mac + c_red


def _quantize_np(x, qp: q.QuantParams) -> np.ndarray:
    """Host mirror of core.quantize.quantize (float32 divide +
    round-half-even + clip — bit-identical to the jnp path)."""
    scale = np.float32(qp.scale)
    zp = int(qp.zero_point)
    vals = np.round(np.asarray(x, np.float32) / scale) + zp
    return np.clip(vals, qp.qmin, qp.qmax).astype(np.int64)


def _extract_windows(x: np.ndarray, R: int, S: int, stride: int):
    """[H, W, C] -> ([E, F, R*S*C] window tensor, E, F) (VALID padding)."""
    H, W, C = x.shape
    E = (H - R) // stride + 1
    F = (W - S) // stride + 1
    rows = np.arange(E)[:, None] * stride + np.arange(R)[None, :]  # (E, R)
    cols = np.arange(F)[:, None] * stride + np.arange(S)[None, :]  # (F, S)
    win = x[rows][:, :, cols]  # (E, R, F, S, C)
    return win.transpose(0, 2, 1, 3, 4).reshape(E, F, R * S * C), E, F


def nc_conv2d(
    x: jax.Array,
    w: jax.Array,
    x_qp: q.QuantParams,
    w_qp: q.QuantParams,
    stride: int = 1,
):
    """Quantized VALID conv through the array model.

    x: [H, W, C] float, w: [R, S, C, M] float.  Both are quantized to uint8
    (zero-point affine), the cross terms of (x-zx)(w-zw) are handled exactly
    as the integer expansion, and the result is returned as int32 — what the
    reserved-way staging would hold before requantization.

    Every (output pixel, filter) pair is a lane group: one packed MAC +
    reduction computes the whole [E, F, M] output in lockstep.  Peak host
    memory scales with E*F*M*K lanes (~40 bit-planes of packed words plus
    the uint8 window broadcast) — emulation-scale layers only; tile over
    output pixels or filters before pointing this at ImageNet-size layers.
    """
    xq = _quantize_np(np.asarray(x), x_qp)
    wq = _quantize_np(np.asarray(w), w_qp)
    R, S, Cw, M = wq.shape
    assert xq.shape[2] == Cw
    win, E, F = _extract_windows(xq, R, S, stride)  # (E, F, K)
    K = R * S * Cw

    # lanes = E x F x M x K (filter splitting across lines is a layout
    # detail; arithmetic is identical) — all pixels/filters in lockstep
    xb = np.broadcast_to(win[:, :, None, :], (E, F, M, K))
    wb = np.broadcast_to(wq.reshape(K, M).T[None, None], (E, F, M, K))
    val, cyc = nc_dot(xb.astype(np.uint8), wb.astype(np.uint8), acc_bits=32)
    total_cycles = int(cyc) * E * F * M  # per-dot cost, one dot per (e,f,m)

    # affine-zero-point correction (done by the accumulating requant step
    # in-cache; exact integer identity)
    sx = win.sum(axis=-1)  # (E, F)
    sw = wq.sum(axis=(0, 1, 2))  # (M,)
    out = (
        val.astype(np.int64)
        - int(w_qp.zero_point) * sx[:, :, None]
        - int(x_qp.zero_point) * sw[None, None, :]
        + K * int(x_qp.zero_point) * int(w_qp.zero_point)
    )
    return jnp.asarray(out, jnp.int32), total_cycles


def nc_maxpool2d(x_q: jax.Array, window: int, stride: int):
    """uint8 max pooling via subtract + MSB-masked copies (§IV-D).

    All E x F x C output lanes advance in lockstep through the window^2 - 1
    sequential max steps (cycle count stays per-pixel, as the per-pixel
    formulation reported it)."""
    win, E, F = _extract_windows(np.asarray(x_q, np.int64), window, window,
                                 stride)
    C = x_q.shape[2]
    win = win.reshape(E, F, window * window, C)
    cur = bs.pack_lanes(bs.bitplane_pack(win[:, :, 0].astype(np.uint32), 8))
    cycles = 0
    for t in range(1, window * window):
        nxt = bs.pack_lanes(bs.bitplane_pack(win[:, :, t].astype(np.uint32), 8))
        cur, c = bs.bitserial_max(cur, nxt)
        cur = cur[:8]
        cycles += c * E * F
    out = bs.bitplane_unpack(cur)  # (E, F, C)
    return jnp.asarray(out, jnp.uint8), cycles


def nc_relu_requant(
    acc: jax.Array, real_multiplier: float, out_zp: int = 0
) -> jax.Array:
    """ReLU on the int32 accumulator then fixed-point requant to uint8 —
    the in-cache epilogue of every conv layer."""
    acc = jnp.maximum(acc, 0)  # MSB-masked zero write
    m, s = q.fixed_point_multiplier(jnp.float32(real_multiplier))
    return q.requantize_fixedpoint(acc, m, s, zero_point=out_zp).astype(jnp.uint8)


def nc_fc(x: jax.Array, w: jax.Array, x_qp: q.QuantParams, w_qp: q.QuantParams):
    """FC as a 1x1 conv over a 1x1 'image' (§IV-D)."""
    out, cycles = nc_conv2d(np.asarray(x)[None, None, :],
                            np.asarray(w)[None, None, :, :], x_qp, w_qp)
    return out[0, 0], cycles
