"""Functional execution of DNN layers through the bit-serial engine.

This is the *correctness* counterpart of core/simulator.py (which models
time/energy): each layer is computed element-for-element the way the cache
would — uint8 operands, bit-plane transposed layout, tag-predicated MACs,
in-array log-tree channel reduction, fixed-point requantization — and is
validated against jnp oracles in tests/test_nc_layers.py.

It is intentionally written for clarity over speed (python loops over bit
positions); use it on small shapes.  The TPU-fast path lives in repro/kernels.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitserial as bs
from repro.core import quantize as q

__all__ = ["nc_dot", "nc_conv2d", "nc_maxpool2d", "nc_relu_requant", "nc_fc"]


def nc_dot(x_q: jax.Array, w_q: jax.Array, acc_bits: int = 24):
    """Quantized dot products, one per bit-line group.

    x_q: [..., K] uint8 inputs, w_q: [..., K] uint8 filters (same shape).
    Each of the K lanes performs one 8-bit MAC into a 24-bit partial sum,
    then the lanes reduce via the in-array log tree.  Returns (int values
    [...], cycles) — bit-exact with the integer dot product.
    """
    xp = bs.bitplane_pack(x_q.astype(jnp.uint32), 8)
    wp = bs.bitplane_pack(w_q.astype(jnp.uint32), 8)
    acc = jnp.zeros((acc_bits,) + x_q.shape, jnp.uint8)
    acc, c_mac = bs.bitserial_mac(acc, xp, wp)
    red, c_red = bs.bitserial_reduce(acc)
    return bs.bitplane_unpack(red)[..., 0], c_mac + c_red


def nc_conv2d(
    x: jax.Array,
    w: jax.Array,
    x_qp: q.QuantParams,
    w_qp: q.QuantParams,
    stride: int = 1,
):
    """Quantized VALID conv through the array model.

    x: [H, W, C] float, w: [R, S, C, M] float.  Both are quantized to uint8
    (zero-point affine), the cross terms of (x-zx)(w-zw) are handled exactly
    as the integer expansion, and the result is returned as int32 — what the
    reserved-way staging would hold before requantization.
    """
    xq = q.quantize(x, x_qp).astype(jnp.int64)
    wq = q.quantize(w, w_qp).astype(jnp.int64)
    H, W, C = x.shape
    R, S, Cw, M = w.shape
    assert C == Cw
    E = (H - R) // stride + 1
    F = (W - S) // stride + 1
    out = np.zeros((E, F, M), np.int64)
    total_cycles = 0
    for e in range(E):
        for f in range(F):
            win = xq[e * stride : e * stride + R, f * stride : f * stride + S]
            # lanes = RxSxC (filter splitting across lines is a layout detail;
            # arithmetic is identical) — all M computed by replicated lanes
            for m in range(M):
                val, cyc = nc_dot(
                    win.reshape(-1).astype(jnp.uint8),
                    wq[..., m].reshape(-1).astype(jnp.uint8),
                    acc_bits=32,
                )
                total_cycles += cyc
                # affine-zero-point correction (done by the accumulating
                # requant step in-cache; exact integer identity)
                sx = int(jnp.sum(win))
                sw = int(jnp.sum(wq[..., m]))
                k = R * S * C
                out[e, f, m] = (
                    int(val)
                    - int(w_qp.zero_point) * sx
                    - int(x_qp.zero_point) * sw
                    + k * int(x_qp.zero_point) * int(w_qp.zero_point)
                )
    return jnp.asarray(out, jnp.int32), total_cycles


def nc_maxpool2d(x_q: jax.Array, window: int, stride: int):
    """uint8 max pooling via subtract + MSB-masked copies (§IV-D)."""
    H, W, C = x_q.shape
    E = (H - window) // stride + 1
    F = (W - window) // stride + 1
    out = np.zeros((E, F, C), np.uint8)
    cycles = 0
    for e in range(E):
        for f in range(F):
            win = x_q[e * stride : e * stride + window, f * stride : f * stride + window]
            cur = bs.bitplane_pack(win[0, 0].astype(jnp.uint32), 8)
            for i in range(window):
                for j in range(window):
                    if i == j == 0:
                        continue
                    nxt = bs.bitplane_pack(win[i, j].astype(jnp.uint32), 8)
                    cur, c = bs.bitserial_max(cur, nxt)
                    cur = cur[:8]
                    cycles += c
            out[e, f] = np.asarray(bs.bitplane_unpack(cur))
    return jnp.asarray(out), cycles


def nc_relu_requant(
    acc: jax.Array, real_multiplier: float, out_zp: int = 0
) -> jax.Array:
    """ReLU on the int32 accumulator then fixed-point requant to uint8 —
    the in-cache epilogue of every conv layer."""
    acc = jnp.maximum(acc, 0)  # MSB-masked zero write
    m, s = q.fixed_point_multiplier(jnp.float32(real_multiplier))
    return q.requantize_fixedpoint(acc, m, s, zero_point=out_zp).astype(jnp.uint8)


def nc_fc(x: jax.Array, w: jax.Array, x_qp: q.QuantParams, w_qp: q.QuantParams):
    """FC as a 1x1 conv over a 1x1 'image' (§IV-D)."""
    out, cycles = nc_conv2d(x[None, None, :], w[None, None, :, :], x_qp, w_qp)
    return out[0, 0], cycles
