"""Cache geometry of the modeled Xeon-E5 LLC (paper §II-C, Figure 3).

Hierarchy: processor -> 14 x 2.5MB slices -> 20 ways/slice -> 4 banks/way
(80 32KB banks per slice) -> 4 x 8KB SRAM arrays/bank -> 256x256 bit cells.

Way-20 is reserved for normal CPU operation, way-19 for input/output staging;
the remaining 18 ways compute.  Frequencies/energies come from the paper's
28nm SPICE model scaled to 22nm (§V): compute mode 2.5 GHz @ 15.4 pJ/cycle
per array, SRAM-access mode 4 GHz @ 8.6 pJ/cycle.
"""
from __future__ import annotations

import dataclasses

__all__ = ["CacheGeometry", "XEON_E5_35MB", "XEON_45MB", "XEON_60MB"]


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    name: str = "xeon-e5-2697v3-35MB"
    n_slices: int = 14
    ways: int = 20
    reserved_cpu_ways: int = 1  # way-20: normal processing
    reserved_io_ways: int = 1  # way-19: input/output staging
    banks_per_way: int = 4  # 80 banks / 20 ways
    arrays_per_bank: int = 4  # 32KB bank = 2 x 16KB sub-array = 4 x 8KB array
    array_rows: int = 256  # word lines
    array_cols: int = 256  # bit lines
    compute_freq_hz: float = 2.5e9
    access_freq_hz: float = 4.0e9
    compute_energy_pj: float = 15.4  # per array per compute cycle (22nm)
    access_energy_pj: float = 8.6  # per array per access cycle (22nm)
    bus_bits: int = 256  # intra-slice data bus (4 x 64-bit quadrant buses)

    # ---- derived -----------------------------------------------------------
    @property
    def compute_ways(self) -> int:
        return self.ways - self.reserved_cpu_ways - self.reserved_io_ways

    @property
    def arrays_per_way(self) -> int:
        return self.banks_per_way * self.arrays_per_bank

    @property
    def arrays_per_slice(self) -> int:
        return self.ways * self.arrays_per_way

    @property
    def compute_arrays_per_slice(self) -> int:
        return self.compute_ways * self.arrays_per_way

    @property
    def compute_arrays(self) -> int:
        return self.n_slices * self.compute_arrays_per_slice

    @property
    def total_arrays(self) -> int:
        return self.n_slices * self.arrays_per_slice

    @property
    def alu_slots(self) -> int:
        """Bit-serial ALU slots = every bit line in the cache (paper: 1,146,880)."""
        return self.total_arrays * self.array_cols

    @property
    def compute_slots(self) -> int:
        return self.compute_arrays * self.array_cols

    @property
    def array_bytes(self) -> int:
        return self.array_rows * self.array_cols // 8

    @property
    def capacity_bytes(self) -> int:
        return self.total_arrays * self.array_bytes

    @property
    def io_way_bytes(self) -> int:
        """Reserved-way staging capacity (128 KB per slice on the 35MB part)."""
        return self.n_slices * self.reserved_io_ways * self.arrays_per_way * self.array_bytes

    def scaled(self, n_slices: int, name: str | None = None) -> "CacheGeometry":
        return dataclasses.replace(
            self, n_slices=n_slices, name=name or f"scaled-{n_slices}slices"
        )


XEON_E5_35MB = CacheGeometry()
XEON_45MB = XEON_E5_35MB.scaled(18, "xeon-45MB")
XEON_60MB = XEON_E5_35MB.scaled(24, "xeon-60MB")
