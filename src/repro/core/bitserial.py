"""Bit-serial in-SRAM arithmetic — functional, bit-exact emulation.

This module is the paper's §III (Neural Cache Arithmetic) as executable JAX.
Data lives in the *transposed* layout: an unsigned n-bit tensor becomes n
binary *planes* (LSB first).  Plane axis == word-line axis; every other axis
is a bit line.  All element lanes advance in lockstep, exactly like the
SRAM array: one bit-slice per cycle, carry/tag held in per-bit-line latches.

Packed bit-lane engine
----------------------
Every operation runs on a **word-packed** representation
(:class:`PackedPlanes`): 32 element lanes are packed into one ``uint32``
word, so a single bitwise AND/XOR/OR advances 32 lanes at once — the
software analogue of the SRAM array clocking thousands of bit lines per
cycle (and of Xcel-RAM's word-parallel bitwise reorganization).

``PackedPlanes`` resident-format contract
-----------------------------------------
``PackedPlanes`` is the *resident* format of the whole layer pipeline:
``bitserial_mac -> bitserial_reduce -> requantize`` chains stay in packed
word space end to end and never round-trip through
:func:`bitplane_unpack`/:func:`bitplane_pack`.  Two lane layouts share the
``words[(n_planes, n_words)]`` container, selected by ``row_lanes``:

* **flat** (``row_lanes == 0``)::

      words[p, w]  bit l  ==  plane p of lane (w * 32 + l)

  lanes flattened C-order from ``lane_shape``, zero-padded up to a
  multiple of 32.  This is the element-wise layout.

* **row-aligned** (``row_lanes == P > 0``): the last ``lane_shape`` axis
  (length K, the reduce axis) is padded to ``P = next_pow2(K)`` bit
  positions so the §III-D log-tree reduction is a pure word-slice
  (``P >= 32``: ``P/32`` dedicated words per row) or an in-word shift
  (``P < 32``: ``32/P`` rows share one word, each owning a P-bit
  segment).  Rows are the remaining lane axes, flattened C-order.

:func:`shuffle_to_rows` / :func:`shuffle_to_flat` convert between the two
(the software analogue of an in-array lane move) so a MAC result can feed
the reducer without reconstructing integer values: the shuffle is a
C-speed bit-grid gather below the value-plane API, not a
``bitplane_unpack``/``bitplane_pack`` round-trip.  Producers that know
their reduce axis pack row-aligned up front with
``pack_values(x, n, row_align=True)`` and skip even that; the row layout
also makes operand *broadcast* free at word granularity (a window row
packs once and is reused by every filter — see core/nc_layers.py).

Because the full adder, tag predication and selective copy are pure
bitwise ops, lanes never interact across bit positions: carries propagate
across *planes* (held in a packed carry word), never across lanes, so
padding lanes stay zero and results are bit-exact with the per-lane
reference in either layout.

Engine dispatch and the bucketed jit cache
------------------------------------------
The engine has two dispatch modes for the same packed algorithm:

* **concrete operands** (the emulation/test/bench path) run the
  bit-position loops directly on host ``numpy`` words — thousands of
  32-lane bitwise ops cost microseconds and nothing is ever compiled;
* **traced operands** (inside ``jax.jit``) run the same loops under
  ``lax.scan``, so traces stay O(1) in both lane count and bit width and
  the ops compile cleanly into larger jitted pipelines.

For repeated tile work (the conv tiler in core/nc_layers.py), a third
path amortizes compilation: :func:`packed_dot_words` with
``engine="jit"`` looks up a jitted kernel in a **small compilation
cache** keyed by ``(plane counts, acc width, K)`` — the *bucket*.  Word
counts are padded to power-of-two buckets (:func:`bucket_words`) before
entering the jitted kernel, so every tile of a layer (including the
ragged last one) replays the same compiled executable instead of
recompiling per lane shape.  ``engine_cache_info()`` reports the cache
contents.

The ``engine=`` string names an entry in the explicit backend registry
(core/backends.py — ``host``, ``jit``, ``pallas-interpret``; an unknown
name raises listing the registered set, ``None`` resolves through the
``NC_BACKEND`` environment variable).  Backends return values only;
:func:`packed_dot_words` charges :func:`dot_cycles` before dispatch, so
modeled cycles are bit-identical across backends by construction.

Beyond-paper zero-operand skipping (EIE-style): the host multiply drops
word columns whose 32 lanes all have a zero operand (the product lanes
are provably zero, exactly what the tag latch would predicate off);
``SKIP_STATS`` accounts skipped lanes/words for the cycle notes.  Modeled
cycles are *never* changed by skipping — the SRAM clocks every bit-slice.

Cycle-model invariants (unchanged by packing — the packed engine models
the *same* hardware, it is only a faster emulation):

    add        : n + 1                     (§III-B)
    multiply   : n^2 + 5n - 2              (§III-C)
    divide     : 1.5 n^2 + 5.5 n           (§III-C)
    reduction  : log2(k) x (move + widening add)   (§III-D)

Every operation still returns ``(result_planes, cycles)`` with these
formulas, and :func:`bitserial_reduce` keeps asserting its step-summed
cycles against the closed form.  The public API is unchanged: ops accept
either raw ``{0,1}`` plane tensors (``(n_bits, *lanes)`` uint8) or
:class:`PackedPlanes`, and return the representation they were given.

The emulation is *bit-exact* against integer arithmetic
(tests/test_bitserial.py sweeps this); the cycle counts feed
core/simulator.py.
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackedPlanes",
    "pack_lanes",
    "unpack_lanes",
    "pack_values",
    "unpack_values",
    "shuffle_to_rows",
    "shuffle_to_flat",
    "bitplane_pack",
    "bitplane_unpack",
    "add_cycles",
    "mul_cycles",
    "div_cycles",
    "reduce_cycles",
    "minmax_cycles",
    "dot_cycles",
    "abft_checksums",
    "checksum_cycles",
    "bitserial_add",
    "bitserial_sub",
    "bitserial_multiply",
    "bitserial_mac",
    "bitserial_reduce",
    "bitserial_minmax",
    "selective_copy",
    "bitserial_relu",
    "bitserial_max",
    "packed_dot_words",
    "bucket_words",
    "engine_cache_info",
    "engine_cache_clear",
    "filter_occupancy",
    "CompressedPlanes",
    "SKIP_STATS",
]

_PLANE_DTYPE = jnp.uint8
_WORD = 32
_FULL_WORD = np.uint32(0xFFFFFFFF)
_LITTLE = sys.byteorder == "little"


def _is_traced(*xs) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in xs)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


def _row_layout(K: int) -> tuple[int, int, int]:
    """Reduce-axis layout: (P, words_per_row, rows_per_word) for K lanes."""
    P = _next_pow2(max(K, 1))
    if P >= _WORD:
        return P, P // _WORD, 1
    return P, 1, _WORD // P


# ---------------------------------------------------------------------------
# Word <-> bit helpers (host side uses C-speed packbits on little-endian).
# ---------------------------------------------------------------------------
def _pack_bits32_np(bits: np.ndarray) -> np.ndarray:
    """(..., 32) {0,1} -> (...,) uint32."""
    bits = np.ascontiguousarray(bits, np.uint8)
    if _LITTLE:
        packed = np.packbits(bits, axis=-1, bitorder="little")
        return packed.view(np.uint32)[..., 0]
    shifts = np.arange(_WORD, dtype=np.uint32)
    return np.bitwise_or.reduce(bits.astype(np.uint32) << shifts, axis=-1)


def _unpack_bits32_np(words: np.ndarray) -> np.ndarray:
    """(...,) uint32 -> (..., 32) uint8."""
    words = np.ascontiguousarray(words, np.uint32)
    if _LITTLE:
        return np.unpackbits(words[..., None].view(np.uint8), axis=-1,
                             bitorder="little")
    shifts = np.arange(_WORD, dtype=np.uint32)
    return ((words[..., None] >> shifts) & 1).astype(np.uint8)


def _pack_bits32_jnp(bits) -> jax.Array:
    shifts = jnp.arange(_WORD, dtype=jnp.uint32)
    return (bits.astype(jnp.uint32) << shifts).sum(axis=-1).astype(jnp.uint32)


def _unpack_bits32_jnp(words) -> jax.Array:
    shifts = jnp.arange(_WORD, dtype=jnp.uint32)
    return ((words[..., None] >> shifts) & jnp.uint32(1)).astype(_PLANE_DTYPE)


def _popcount(w: np.ndarray) -> int:
    if hasattr(np, "bitwise_count"):
        return int(np.bitwise_count(w).sum())
    return int(np.unpackbits(np.ascontiguousarray(w).view(np.uint8)).sum())


# ---------------------------------------------------------------------------
# Transposed (bit-plane) layout — the software analogue of the paper's TMU.
# ---------------------------------------------------------------------------
def bitplane_pack(x, n_bits: int):
    """Pack an unsigned integer tensor into ``n_bits`` binary planes (LSB first).

    Returns shape ``(n_bits, *x.shape)`` with values in {0, 1}.  This is the
    paper's transpose layout: plane index == word line, remaining axes == bit
    lines.
    """
    if _is_traced(x):
        x = x.astype(jnp.uint32)
        shifts = jnp.arange(n_bits, dtype=jnp.uint32)
        planes = (x[None, ...] >> shifts.reshape((n_bits,) + (1,) * x.ndim)) & 1
        return planes.astype(_PLANE_DTYPE)
    x = np.asarray(x).astype(np.uint32)
    shifts = np.arange(n_bits, dtype=np.uint32).reshape((n_bits,) + (1,) * x.ndim)
    return ((x[None, ...] >> shifts) & 1).astype(np.uint8)


def bitplane_unpack(planes, signed: bool = False):
    """Inverse of :func:`bitplane_pack`.  ``signed`` interprets the planes as
    two's complement of width ``planes.shape[0]``."""
    if isinstance(planes, PackedPlanes):
        return unpack_values(planes, signed=signed)
    n = planes.shape[0]
    if _is_traced(planes):
        weights = (jnp.uint32(1) << jnp.arange(n, dtype=jnp.uint32)).reshape(
            (n,) + (1,) * (planes.ndim - 1)
        )
        val = jnp.sum(planes.astype(jnp.uint32) * weights, axis=0).astype(jnp.int64)
        if signed:
            val = jnp.where(planes[-1].astype(bool), val - (1 << n), val)
        return val
    p = np.asarray(planes, np.uint64)
    weights = (np.uint64(1) << np.arange(n, dtype=np.uint64)).reshape(
        (n,) + (1,) * (p.ndim - 1)
    )
    val = (p * weights).sum(axis=0).astype(np.int64)
    if signed:
        val = np.where(p[-1].astype(bool), val - (1 << n), val)
    return val


# ---------------------------------------------------------------------------
# Packed bit-lane container: 32 lanes per uint32 word.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackedPlanes:
    """Word-packed bit planes (see module docstring for the layout contract).

    ``row_lanes == 0``: flat — ``words[p, w]`` bit ``l`` is plane ``p`` of
    lane ``w * 32 + l`` (lanes flattened C-order from ``lane_shape``,
    zero-padded to a multiple of 32).

    ``row_lanes == P``: row-aligned — the last ``lane_shape`` axis is padded
    to ``P`` (a power of two) bit positions per row; ``P >= 32`` gives
    ``P/32`` words per row, ``P < 32`` packs ``32/P`` rows per word."""

    words: jax.Array  # (n_planes, n_words) uint32
    lane_shape: tuple[int, ...]
    row_lanes: int = 0

    @property
    def n_planes(self) -> int:
        return self.words.shape[0]

    @property
    def n_lanes(self) -> int:
        return int(np.prod(self.lane_shape)) if self.lane_shape else 1

    @property
    def n_words(self) -> int:
        return self.words.shape[1]

    @property
    def n_rows(self) -> int:
        """Row count of the row-aligned layout (reduce groups)."""
        if not self.row_lanes:
            raise ValueError("flat-packed planes have no row structure")
        shape = self.lane_shape[:-1]
        return int(np.prod(shape)) if shape else 1

    def __getitem__(self, idx) -> "PackedPlanes":
        """Plane-axis slicing (lane layout is preserved)."""
        if not isinstance(idx, slice):
            raise TypeError("PackedPlanes supports plane-axis slices only")
        return PackedPlanes(self.words[idx], self.lane_shape, self.row_lanes)


jax.tree_util.register_dataclass(
    PackedPlanes, data_fields=["words"], meta_fields=["lane_shape", "row_lanes"]
)


@dataclasses.dataclass(frozen=True)
class CompressedPlanes:
    """CSR-style per-bit-plane filter store (PR 8, EIE-inspired).

    The sibling of :class:`PackedPlanes` for RESIDENT filters: instead of
    a dense ``(n_planes, n_columns, ...)`` word grid (one column per
    filter), each bit plane keeps only its LIVE columns — the filters
    with at least one set bit in that plane — as a sorted column index
    plus their packed words.  Planes with no set bit anywhere store
    nothing at all; a pruned (all-zero-plane) filter column appears in no
    plane's index.  :meth:`dense` reconstructs the original grid
    byte-identically (round trip asserted by tests/test_sparsity.py), so
    the packed MAC+reduce consumes exactly the words it would have seen
    uncompressed.

    The modeled residency of this store is
    ``mapper.compressed_filter_bytes`` (live-plane payload + per-plane
    live-column bitmap); :attr:`index_bytes` mirrors the bitmap term."""

    column_index: tuple[np.ndarray, ...]  # per plane: sorted int32 live cols
    columns: tuple[np.ndarray, ...]  # per plane: (n_live, *tail) uint32 words
    n_columns: int  # dense column (filter) count
    tail_shape: tuple[int, ...]  # per-column word shape of the dense grid

    @property
    def n_planes(self) -> int:
        return len(self.column_index)

    @property
    def live_planes(self) -> int:
        """Planes with at least one live column (the only ones stored)."""
        return sum(1 for idx in self.column_index if idx.size)

    @property
    def payload_bytes(self) -> int:
        """Bytes of packed words actually stored (live columns only)."""
        return sum(int(c.nbytes) for c in self.columns)

    @property
    def index_bytes(self) -> int:
        """Per-plane live-column bitmap bytes (one bit per filter column,
        byte-rounded, live planes only) — the CSR index overhead."""
        return self.live_planes * (-(-self.n_columns // 8))

    @property
    def nbytes(self) -> int:
        return self.payload_bytes + self.index_bytes

    @classmethod
    def compress(cls, words) -> "CompressedPlanes":
        """Compress a dense per-plane filter word grid ``(n_planes,
        n_columns, ...)`` uint32 (e.g. the packed filter block the engine
        feeds ``packed_dot_words``) into CSR-per-plane form."""
        grid = np.asarray(words, np.uint32)
        if grid.ndim < 2:
            raise ValueError(
                f"expected (n_planes, n_columns, ...) words, got {grid.shape}")
        flat = grid.reshape(grid.shape[0], grid.shape[1], -1)
        live = flat.any(axis=2)  # (n_planes, n_columns)
        index = tuple(np.flatnonzero(live[p]).astype(np.int32)
                      for p in range(grid.shape[0]))
        cols = tuple(np.ascontiguousarray(grid[p, index[p]])
                     for p in range(grid.shape[0]))
        return cls(column_index=index, columns=cols,
                   n_columns=int(grid.shape[1]),
                   tail_shape=tuple(grid.shape[2:]))

    def dense(self) -> np.ndarray:
        """Reconstruct the dense ``(n_planes, n_columns, *tail_shape)``
        word grid, byte-identical to what :meth:`compress` consumed —
        dead columns and dead planes come back as zero words (a zero
        word is the multiply's identity, so consumers are unchanged)."""
        return self.dense_columns(0, self.n_columns)

    def dense_columns(self, start: int, stop: int) -> np.ndarray:
        """Reconstruct columns ``[start, stop)`` of the dense grid — the
        per-tile filter slice the packed engine consumes — without
        materializing the rest (the CSR index is sorted, so the slice is
        two binary searches per plane)."""
        if not (0 <= start <= stop <= self.n_columns):
            raise ValueError(
                f"columns [{start}, {stop}) out of range for "
                f"{self.n_columns}")
        grid = np.zeros((self.n_planes, stop - start) + self.tail_shape,
                        np.uint32)
        for p, (idx, cols) in enumerate(zip(self.column_index, self.columns)):
            if idx.size:
                lo = int(np.searchsorted(idx, start))
                hi = int(np.searchsorted(idx, stop))
                if lo < hi:
                    grid[p, idx[lo:hi] - start] = cols[lo:hi]
        return grid


def _grid_bits_np(flat: np.ndarray, lane_shape: tuple[int, ...],
                  row_align: bool) -> np.ndarray:
    """Arrange per-lane values (any int dtype, all planes at once:
    ``(n, n_lanes)``) into the ``(n, n_words, 32)`` bit-position grid of
    the requested layout (padding positions zero)."""
    n, n_lanes = flat.shape
    if not row_align:
        n_words = max(-(-n_lanes // _WORD), 1)
        grid = np.zeros((n, n_words * _WORD), flat.dtype)
        grid[:, :n_lanes] = flat
        return grid.reshape(n, n_words, _WORD)
    K = lane_shape[-1] if lane_shape else 1
    B = max(n_lanes // max(K, 1), 1)
    P, wpr, r = _row_layout(K)
    if r == 1:
        grid = np.zeros((n, B, wpr * _WORD), flat.dtype)
        grid[:, :, :K] = flat.reshape(n, B, K)
        return grid.reshape(n, B * wpr, _WORD)
    Bp = -(-B // r) * r
    grid = np.zeros((n, Bp, P), flat.dtype)
    grid[:, :B, :K] = flat.reshape(n, B, K)
    return grid.reshape(n, Bp // r, _WORD)


def _ungrid_np(grid: np.ndarray, lane_shape: tuple[int, ...],
               row_lanes: int) -> np.ndarray:
    """Inverse of :func:`_grid_bits_np`: (n, n_words, 32) grid -> (n, lanes)."""
    n = grid.shape[0]
    n_lanes = int(np.prod(lane_shape)) if lane_shape else 1
    if not row_lanes:
        return grid.reshape(n, -1)[:, :n_lanes]
    K = lane_shape[-1] if lane_shape else 1
    B = max(n_lanes // max(K, 1), 1)
    P, wpr, r = _row_layout(K)
    if r == 1:
        return grid.reshape(n, B, wpr * _WORD)[:, :, :K].reshape(n, -1)
    return grid.reshape(n, -1, P)[:, :B, :K].reshape(n, -1)


def pack_lanes(planes, row_align: bool = False) -> PackedPlanes:
    """Raw ``{0,1}`` planes ``(n, *lanes)`` -> :class:`PackedPlanes`.

    ``row_align=True`` packs the last lane axis row-aligned (the reduce
    layout; see the class docstring)."""
    n = planes.shape[0]
    lane_shape = tuple(planes.shape[1:])
    if _is_traced(planes):
        flat = planes.reshape(n, -1)
        return PackedPlanes(
            _pack_bits32_jnp(_grid_bits_jnp(flat, lane_shape, row_align)),
            lane_shape,
            _row_layout(lane_shape[-1] if lane_shape else 1)[0] if row_align else 0,
        )
    flat = np.asarray(planes, np.uint8).reshape(n, -1)
    words = _pack_bits32_np(_grid_bits_np(flat, lane_shape, row_align))
    rl = _row_layout(lane_shape[-1] if lane_shape else 1)[0] if row_align else 0
    return PackedPlanes(words, lane_shape, rl)


def _grid_bits_jnp(flat, lane_shape: tuple[int, ...], row_align: bool):
    """Traced analogue of :func:`_grid_bits_np` (operates on all planes at
    once: flat is (n, n_lanes) -> (n, n_words, 32))."""
    n, n_lanes = flat.shape
    if not row_align:
        n_words = max(-(-n_lanes // _WORD), 1)
        pad = n_words * _WORD - n_lanes
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        return flat.reshape(n, n_words, _WORD)
    K = lane_shape[-1] if lane_shape else 1
    B = max(n_lanes // max(K, 1), 1)
    P, wpr, r = _row_layout(K)
    x = flat.reshape(n, B, K)
    if r == 1:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, wpr * _WORD - K)))
        return x.reshape(n, B * wpr, _WORD)
    Bp = -(-B // r) * r
    x = jnp.pad(x, ((0, 0), (0, Bp - B), (0, P - K)))
    return x.reshape(n, Bp // r, _WORD)


def unpack_lanes(pp: PackedPlanes):
    """:class:`PackedPlanes` -> raw ``{0,1}`` planes ``(n, *lanes)`` uint8."""
    n = pp.n_planes
    if _is_traced(pp.words):
        bits = _unpack_bits32_jnp(pp.words)  # (n, n_words, 32)
        flat = _ungrid_jnp(bits, pp.lane_shape, pp.row_lanes)
        return flat.reshape((n,) + pp.lane_shape).astype(_PLANE_DTYPE)
    bits = _unpack_bits32_np(np.asarray(pp.words))
    flat = _ungrid_np(bits, pp.lane_shape, pp.row_lanes)
    return flat.reshape((n,) + pp.lane_shape).astype(np.uint8)


def _ungrid_jnp(bits, lane_shape: tuple[int, ...], row_lanes: int):
    n = bits.shape[0]
    n_lanes = int(np.prod(lane_shape)) if lane_shape else 1
    if not row_lanes:
        return bits.reshape(n, -1)[:, :n_lanes]
    K = lane_shape[-1] if lane_shape else 1
    B = max(n_lanes // max(K, 1), 1)
    P, wpr, r = _row_layout(K)
    if r == 1:
        return bits.reshape(n, B, wpr * _WORD)[:, :, :K].reshape(n, -1)
    return bits.reshape(n, -1, P)[:, :B, :K].reshape(n, -1)


def pack_values(x, n_bits: int, row_align: bool = False) -> PackedPlanes:
    """Integer tensor -> :class:`PackedPlanes` directly, without ever
    materializing the raw ``(n_bits, *lanes)`` plane tensor.

    This is the packed-resident producer: layers pack their quantized
    operands straight into word space (``row_align=True`` when the last
    axis is the reduce axis)."""
    lane_shape = tuple(np.shape(x))
    if _is_traced(x):
        flat = x.astype(jnp.uint32).reshape(-1)
        shifts = jnp.arange(n_bits, dtype=jnp.uint32)
        planes = ((flat[None, :] >> shifts[:, None]) & 1).astype(jnp.uint32)
        grids = _grid_bits_jnp(planes, lane_shape, row_align)
        rl = _row_layout(lane_shape[-1] if lane_shape else 1)[0] if row_align else 0
        return PackedPlanes(_pack_bits32_jnp(grids), lane_shape, rl)
    flat = np.asarray(x).astype(np.uint64).reshape(1, -1)
    grid = _grid_bits_np(flat, lane_shape, row_align)[0]  # (n_words, 32) values
    words = np.empty((n_bits, grid.shape[0]), np.uint32)
    for p in range(n_bits):
        words[p] = _pack_bits32_np(((grid >> np.uint64(p)) & 1).astype(np.uint8))
    rl = _row_layout(lane_shape[-1] if lane_shape else 1)[0] if row_align else 0
    return PackedPlanes(words, lane_shape, rl)


def unpack_values(pp: PackedPlanes, signed: bool = False):
    """:class:`PackedPlanes` -> integer tensor of ``lane_shape`` (int64),
    without materializing raw planes (the packed-resident consumer)."""
    n = pp.n_planes
    if _is_traced(pp.words):
        bits = _unpack_bits32_jnp(pp.words).astype(jnp.int64)
        flat = _ungrid_jnp(bits, pp.lane_shape, pp.row_lanes).astype(jnp.int64)
        weights = (jnp.int64(1) << jnp.arange(n, dtype=jnp.int64))[:, None]
        val = (flat * weights).sum(axis=0)
        if signed:
            val = jnp.where(flat[-1].astype(bool), val - (1 << n), val)
        return val.reshape(pp.lane_shape)
    words = np.asarray(pp.words)
    acc = np.zeros((words.shape[1], _WORD), np.int64)
    for p in range(n):
        acc += _unpack_bits32_np(words[p]).astype(np.int64) << p
    val = _ungrid_np(acc[None], pp.lane_shape, pp.row_lanes)[0]
    if signed:
        sign = _ungrid_np(_unpack_bits32_np(words[n - 1])[None],
                          pp.lane_shape, pp.row_lanes)[0]
        val = np.where(sign.astype(bool), val - (1 << n), val)
    return val.reshape(pp.lane_shape)


# ---------------------------------------------------------------------------
# In-packed lane shuffle: flat <-> row-aligned without leaving word space.
# ---------------------------------------------------------------------------
def shuffle_to_rows(pp: PackedPlanes) -> PackedPlanes:
    """Flat-packed -> row-aligned (reduce layout) lane shuffle.

    The software analogue of the in-array move that lines the reduce axis
    up row-wise (§III-D).  Implementation note: the gather transiently
    expands the words to a {0,1} bit grid (C-speed packbits/unpackbits)
    and repacks — it stays below the value-plane API (no
    ``bitplane_unpack`` integer reconstruction), but it is NOT free;
    producers that know their reduce axis should pack row-aligned up
    front (``pack_values(..., row_align=True)``) and skip it, as the conv
    tiler does."""
    if pp.row_lanes:
        return pp
    K = pp.lane_shape[-1] if pp.lane_shape else 1
    n = pp.n_planes
    if _is_traced(pp.words):
        bits = _ungrid_jnp(_unpack_bits32_jnp(pp.words), pp.lane_shape, 0)
        grids = _grid_bits_jnp(bits, pp.lane_shape, True)
        return PackedPlanes(_pack_bits32_jnp(grids), pp.lane_shape,
                            _row_layout(K)[0])
    bits = _unpack_bits32_np(np.asarray(pp.words)).reshape(n, -1)[:, :pp.n_lanes]
    grids = _grid_bits_np(bits, pp.lane_shape, True)
    return PackedPlanes(_pack_bits32_np(grids), pp.lane_shape, _row_layout(K)[0])


def shuffle_to_flat(pp: PackedPlanes) -> PackedPlanes:
    """Row-aligned -> flat-packed, in packed space (inverse shuffle)."""
    if not pp.row_lanes:
        return pp
    n = pp.n_planes
    if _is_traced(pp.words):
        bits = _ungrid_jnp(_unpack_bits32_jnp(pp.words), pp.lane_shape,
                           pp.row_lanes)
        grids = _grid_bits_jnp(bits, pp.lane_shape, False)
        return PackedPlanes(_pack_bits32_jnp(grids), pp.lane_shape, 0)
    bits = _unpack_bits32_np(np.asarray(pp.words))
    flat = _ungrid_np(bits, pp.lane_shape, pp.row_lanes)
    grids = _grid_bits_np(flat, pp.lane_shape, False)
    return PackedPlanes(_pack_bits32_np(grids), pp.lane_shape, 0)


def _coerce(x) -> tuple[PackedPlanes, bool]:
    if isinstance(x, PackedPlanes):
        return x, True
    return pack_lanes(x), False


def _align_pair(pa: PackedPlanes, pb: PackedPlanes):
    """Bring two operands to a common lane layout (packed-space shuffle)."""
    if pa.row_lanes == pb.row_lanes:
        return pa, pb
    if pa.row_lanes and not pb.row_lanes:
        return pa, shuffle_to_rows(pb)
    if pb.row_lanes and not pa.row_lanes:
        return shuffle_to_rows(pa), pb
    raise ValueError(
        f"incompatible row layouts: {pa.row_lanes} vs {pb.row_lanes}")


def _emit(words, lane_shape: tuple[int, ...], packed: bool, row_lanes: int = 0):
    pp = PackedPlanes(words, lane_shape, row_lanes)
    return pp if packed else unpack_lanes(pp)


def _pack_mask(mask, like: PackedPlanes | None = None):
    """Per-lane predicate -> packed tag word row (n_words,) uint32, in the
    same lane layout as ``like`` (flat when omitted)."""
    if isinstance(mask, PackedPlanes):
        return mask.words[0]
    row = bool(like is not None and like.row_lanes)
    if _is_traced(mask):
        return pack_lanes(mask.astype(_PLANE_DTYPE)[None], row_align=row).words[0]
    return pack_lanes(np.asarray(mask, np.uint8)[None], row_align=row).words[0]


# ---------------------------------------------------------------------------
# Cycle formulas (paper §III).
# ---------------------------------------------------------------------------
def add_cycles(n: int) -> int:
    return n + 1


def mul_cycles(n: int) -> int:
    return n * n + 5 * n - 2


def div_cycles(n: int) -> float:
    return 1.5 * n * n + 5.5 * n


def move_cycles(n: int) -> int:
    # Word-line move: read + write-back per bit; sense-amp cycling folds this
    # to ~1 cycle/bit in column-multiplexed arrays (§III-D, [18]).
    return n


def reduce_cycles(k: int, width: int) -> int:
    """Cycles to reduce ``k`` elements of ``width`` bits to one sum in-array."""
    cyc = 0
    w = width
    steps = int(np.ceil(np.log2(max(k, 1))))
    for _ in range(steps):
        cyc += move_cycles(w) + add_cycles(w)
        w += 1
    return cyc


def minmax_cycles(k: int, width: int) -> int:
    """Cycles for the §IV-D in-cache min/max log tree over ``k`` lanes of
    ``width``-bit values.

    Each halving step is one subtract (whose sign drives the tag latch),
    one tag-masked selective copy, and a tag load — min and max candidate
    lanes are separate bit-line groups advancing in lockstep, so a single
    pass serves both trees (like the §IV-D max-pool sequence)."""
    steps = int(np.ceil(np.log2(max(k, 1))))
    return steps * (add_cycles(width) + (width + 1) + 1)


def dot_cycles(k: int, n_bits: int, acc_bits: int) -> int:
    """Per-lane-group dot cycles: one n-bit MAC into an ``acc_bits`` partial
    sum, then the §III-D log tree over ``k`` lanes (the conv inner loop)."""
    return (mul_cycles(n_bits) + add_cycles(max(acc_bits, 2 * n_bits))
            + reduce_cycles(k, acc_bits))


# ---------------------------------------------------------------------------
# ABFT integrity layer (PR 7): checksum columns over one pass's operands.
# ---------------------------------------------------------------------------
def abft_checksums(x_rows, w_rows):
    """ABFT reference sums for one pass over CLEAN unsigned operands.

    The pass computes ``v[m, t] = w_m . x_t``.  Two checksum vectors bound
    every entry:

    * column reference ``col[t] = x_t . sum_m(w_m)`` — one extra "filter"
      (the column checksum appended to the packed filter block at load
      time) dotted against every window row; a corrupted filter word or a
      corrupted pass output shifts some per-row filter sum,
    * row reference ``row[m] = sum_t(x_t) . w_m`` — one extra "window row"
      dotted against every filter; a corrupted activation word shifts some
      per-filter row sum.

    Because operands are unsigned, a monotone stuck-at-1 corruption can
    only *raise* sums, and any single-bit flip at a live lane (a lane where
    the opposing checksum vector is nonzero) shifts exactly one reference
    — so a verification pass over (col, row) detects every output-changing
    fault the injector covers; mismatch-free means output-identical.

    Returns ``(col, row)`` as exact int64 vectors."""
    xr = np.asarray(x_rows, dtype=np.int64)
    wr = np.asarray(w_rows, dtype=np.int64)
    return xr @ wr.sum(axis=0), wr @ xr.sum(axis=0)


def checksum_cycles(k: int, n_bits: int, acc_bits: int, rows: int,
                    filters: int) -> int:
    """Cycles to verify one pass of ``rows`` window rows x ``filters``
    filter columns: the column checksum is one extra filter lane-group
    dotted per row, the row checksum one extra window row dotted per
    filter — each priced at the same per-lane-group :func:`dot_cycles` as
    the real work (the checksum columns ride the §III-D reduce tree)."""
    return dot_cycles(k, n_bits, acc_bits) * (max(rows, 0) + max(filters, 0))


# ---------------------------------------------------------------------------
# EIE-style zero-operand lane skipping (beyond-paper, host path only).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SkipStats:
    """Accounting for zero-operand lane skipping (does NOT change modeled
    cycles — the SRAM clocks every bit-slice; this is emulation-side work
    elision plus the note the cycle reports print).

    ``planes_*`` count multiplier bit-plane steps: a plane whose tag word
    carries no set bit makes the tag-predicated shifted-add an identity, so
    the host engine elides the whole step (value-sparsity at bit-plane
    granularity — the per-plane half of the sparsity-aware scheduling; the
    per-filter half lives in core/schedule.py, where it DOES earn modeled
    skipped-pass credits)."""

    lanes_total: int = 0
    lanes_zero: int = 0  # lanes with a provably-zero operand (tag-skippable)
    words_total: int = 0
    words_skipped: int = 0  # whole 32-lane words elided by the host engine
    planes_total: int = 0  # multiplier bit-plane steps seen by the host engine
    planes_skipped: int = 0  # all-zero tag planes elided (step is an identity)

    def reset(self) -> None:
        self.lanes_total = self.lanes_zero = 0
        self.words_total = self.words_skipped = 0
        self.planes_total = self.planes_skipped = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


SKIP_STATS = SkipStats()
ZERO_SKIP = True  # module switch for the host multiply's word/plane elision


def filter_occupancy(rows, n_bits: int, zero: int = 0):
    """Pack-time operand occupancy scan for sparsity-aware scheduling.

    ``rows``: integer filter rows ``(M, K)`` (one row per filter, reduce
    lanes last — the grid :func:`pack_values` consumes).  Returns
    ``(zero_mask, plane_live)``:

    * ``zero_mask`` ``(M,)`` bool — filters whose every weight equals
      ``zero`` (the quantized zero point): their dot contribution is the
      analytically-known ``zero * sum(x)``, so the scheduler can drop their
      serialized passes entirely (core/schedule.py turns this into
      skipped-pass cycle credits),
    * ``plane_live`` ``(n_bits,)`` bool — bit planes carrying at least one
      set bit across the *live* filters; dead planes make the multiplier's
      shifted-add step an identity (see :func:`_mul_words_dense`).

    Pure metadata: results/cycles of any individual op are never changed by
    this scan — it only feeds the plan."""
    rows = np.asarray(rows)
    if rows.ndim != 2:
        rows = rows.reshape(rows.shape[0], -1)
    zero_mask = (rows == zero).all(axis=1)
    live = rows[~zero_mask].astype(np.uint64)
    plane_live = np.array(
        [bool(((live >> np.uint64(p)) & 1).any()) for p in range(n_bits)])
    return zero_mask, plane_live


# ---------------------------------------------------------------------------
# The column peripheral, word-packed: full adder + carry latch + tag latch,
# one bit-slice per cycle.  One uint32 word advances 32 lanes per bitwise op.
# Concrete operands run numpy loops (microseconds, nothing compiled); traced
# operands run the identical recurrence under lax.scan (O(1) trace size).
# Word arrays broadcast over their lane axes, so row-aligned operands can be
# thin views (a window row packed once serves every filter).
# ---------------------------------------------------------------------------
def _word_full_adder(a, b, c):
    s = a ^ b ^ c
    carry = (a & b) | ((a ^ b) & c)
    return s, carry


def _zext_np(w: np.ndarray, n: int) -> np.ndarray:
    if w.shape[0] == n:
        return w
    if w.shape[0] > n:
        return w[:n]
    out = np.zeros((n,) + w.shape[1:], np.uint32)
    out[: w.shape[0]] = w
    return out


def _zext_jnp(w, n: int):
    if w.shape[0] == n:
        return w
    if w.shape[0] > n:
        return w[:n]
    pad = [(0, n - w.shape[0])] + [(0, 0)] * (w.ndim - 1)
    return jnp.pad(w, pad)


def _add_words(aw, bw, *, out_bits: int, invert_b: bool = False,
               carry_one: bool = False):
    """Packed ripple add over ``out_bits`` planes (operands broadcast).

    ``invert_b``/``carry_one`` give two's-complement subtraction for free —
    complement planes come from BLB, carry latch preset to 1 (§III-B).
    """
    if _is_traced(aw, bw):
        a = _zext_jnp(jnp.asarray(aw), out_bits)
        b = _zext_jnp(jnp.asarray(bw), out_bits)
        if invert_b:
            b = ~b
        shape = jnp.broadcast_shapes(a.shape[1:], b.shape[1:])
        init = jnp.full(shape, _FULL_WORD if carry_one else 0, jnp.uint32)

        def step(carry, planes):
            s, carry = _word_full_adder(planes[0], planes[1], carry)
            return carry, s

        _, out = jax.lax.scan(step, init, (a, b))
        return out
    a = _zext_np(np.asarray(aw), out_bits)
    b = _zext_np(np.asarray(bw), out_bits)
    if invert_b:
        b = ~b
    shape = np.broadcast_shapes(a.shape[1:], b.shape[1:])
    carry = np.full(shape, _FULL_WORD if carry_one else 0, np.uint32)
    out = np.empty((out_bits,) + shape, np.uint32)
    for i in range(out_bits):
        out[i], carry = _word_full_adder(a[i], b[i], carry)
    return out


def _nonzero_word(w) -> np.ndarray:
    """OR over planes: bit l set iff lane l has any live bit."""
    return np.bitwise_or.reduce(np.asarray(w), axis=0)


def _mul_words_dense(apad, bw, shape):
    """Tag-predicated shifted-add multiply on (broadcastable) word arrays.

    A multiplier plane whose tag word has no set bit makes every lane's
    predicated write a no-op, so the whole shifted-add step is elided on the
    host path (``SKIP_STATS.planes_skipped``) — the per-plane face of value
    sparsity (a pruned filter's dead bit planes never clock the adder).
    Results are bit-identical; modeled cycles are charged by the caller's
    unchanged formula."""
    total, nb = apad.shape[0], bw.shape[0]
    prod = np.zeros((total,) + shape, np.uint32)
    SKIP_STATS.planes_total += nb
    for j in range(nb):
        tag = bw[j]
        if ZERO_SKIP and not tag.any():
            SKIP_STATS.planes_skipped += 1
            continue
        ntag = ~tag
        shifted = np.roll(apad, j, axis=0)
        carry = np.zeros(shape, np.uint32)
        for i in range(total):
            s, carry = _word_full_adder(prod[i], shifted[i], carry)
            prod[i] = (tag & s) | (ntag & prod[i])
    return prod


def _mul_words(aw, bw):
    """Packed tag-predicated shifted-add multiply (§III-C).

    One step per multiplier plane: full-add the (plane-shifted) multiplicand
    into the product under that plane's tag word.  On the host path, word
    columns whose 32 lanes all carry a zero operand are elided (EIE-style
    zero-operand skipping — their product lanes are exactly zero); the
    elision is accounted in ``SKIP_STATS`` and never alters results or the
    modeled cycle count.
    """
    na, nb = aw.shape[0], bw.shape[0]
    total = na + nb
    if _is_traced(aw, bw):
        apad = _zext_jnp(jnp.asarray(aw), total)
        bw = jnp.asarray(bw)
        shape = jnp.broadcast_shapes(apad.shape[1:], bw.shape[1:])
        # plane-shifted copies of the multiplicand: roll is exact because
        # the top nb planes of apad are zero.
        shifted = jnp.stack([jnp.roll(apad, j, axis=0) for j in range(nb)])

        def step(prod, tj):
            tag, sh = tj

            def astep(carry, planes):
                s, carry = _word_full_adder(planes[0], planes[1], carry)
                return carry, s

            _, summed = jax.lax.scan(astep, jnp.zeros(shape, jnp.uint32),
                                     (prod, sh))
            return (tag & summed) | (~tag & prod), None

        prod, _ = jax.lax.scan(step, jnp.zeros((total,) + shape, jnp.uint32),
                               (bw, shifted))
        return prod
    aw = np.asarray(aw)
    bw = np.asarray(bw)
    apad = _zext_np(aw, total)
    shape = np.broadcast_shapes(aw.shape[1:], bw.shape[1:])
    n_words = int(np.prod(shape)) if shape else 1
    if ZERO_SKIP and n_words > 1:
        active = np.broadcast_to(_nonzero_word(aw) & _nonzero_word(bw), shape)
        idx = np.flatnonzero(active.reshape(-1))
        SKIP_STATS.words_total += n_words
        SKIP_STATS.lanes_total += n_words * _WORD
        SKIP_STATS.lanes_zero += n_words * _WORD - _popcount(
            np.ascontiguousarray(active))
        if idx.size < n_words - n_words // 8:  # worth compressing
            # only count elision that actually happens — below the threshold
            # the dense path still clocks every word
            SKIP_STATS.words_skipped += n_words - idx.size
            a_c = np.broadcast_to(apad, (total,) + shape).reshape(total, -1)[:, idx]
            b_c = np.broadcast_to(bw, (nb,) + shape).reshape(nb, -1)[:, idx]
            prod_c = _mul_words_dense(a_c, b_c, (idx.size,))
            prod = np.zeros((total, n_words), np.uint32)
            prod[:, idx] = prod_c
            return prod.reshape((total,) + shape)
    return _mul_words_dense(apad, bw, shape)


def _select_words(dst, src, tag):
    """Tag-predicated copy: dst where tag bit is 0, src where it is 1."""
    if _is_traced(dst, src, tag):
        src = _zext_jnp(jnp.asarray(src), dst.shape[0])
        return (tag & src) | (~tag & dst)
    src = _zext_np(np.asarray(src), dst.shape[0])
    return (tag & src) | (~tag & np.asarray(dst))


def bitserial_add(a, b, out_bits: int | None = None):
    """Element-wise sum of two plane tensors.  Returns (planes, cycles)."""
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    pa, pb = _align_pair(pa, pb)
    n = max(pa.n_planes, pb.n_planes)
    out_bits = out_bits if out_bits is not None else n + 1
    ow = _add_words(pa.words, pb.words, out_bits=out_bits)
    return _emit(ow, pa.lane_shape, packed_a or packed_b,
                 pa.row_lanes), add_cycles(n)


def bitserial_sub(a, b, out_bits: int | None = None):
    """a - b in two's complement (width = max width + 1 by default).

    Implemented the SRAM way: complement planes of ``b`` are read from BLB
    (free), carry latch preset to 1.  Returns (planes, cycles); MSB of the
    result is the sign — it drives the tag latch for max/ReLU predication.
    """
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    pa, pb = _align_pair(pa, pb)
    n = max(pa.n_planes, pb.n_planes)
    out_bits = out_bits if out_bits is not None else n + 1
    ow = _add_words(pa.words, pb.words, out_bits=out_bits,
                    invert_b=True, carry_one=True)
    return _emit(ow, pa.lane_shape, packed_a or packed_b,
                 pa.row_lanes), add_cycles(n)


def bitserial_multiply(a, b):
    """Element-wise product via tag-predicated shifted adds (§III-C).

    ``a`` is the multiplicand, ``b`` the multiplier; product has
    ``a_bits + b_bits`` planes.  Cycle count is the paper's n^2+5n-2 with
    n = max(a_bits, b_bits).
    """
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    pa, pb = _align_pair(pa, pb)
    ow = _mul_words(pa.words, pb.words)
    n = max(pa.n_planes, pb.n_planes)
    return _emit(ow, pa.lane_shape, packed_a or packed_b,
                 pa.row_lanes), mul_cycles(n)


def bitserial_mac(acc, a, b):
    """acc += a * b.  Returns (planes, cycles) with acc width preserved."""
    pacc, packed_acc = _coerce(acc)
    pa, _ = _coerce(a)
    pb, _ = _coerce(b)
    pa, pb = _align_pair(pa, pb)
    pacc, pa = _align_pair(pacc, pa)
    pacc, pb = _align_pair(pacc, pb)
    prod = _mul_words(pa.words, pb.words)
    n_mul = max(pa.n_planes, pb.n_planes)
    n_add = max(pacc.n_planes, prod.shape[0])
    out = _add_words(pacc.words, prod, out_bits=pacc.n_planes)
    cycles = mul_cycles(n_mul) + add_cycles(n_add)
    return _emit(out, pacc.lane_shape, packed_acc, pacc.row_lanes), cycles


# ---------------------------------------------------------------------------
# Reduction (§III-D): log-tree over the last lane axis, entirely in packed
# space.  Row-aligned operands reduce in place; flat operands are first
# lane-shuffled to the row layout (shuffle_to_rows — a packed-space gather,
# not a plane round-trip).  Each halving step is either a word-slice
# (half >= 32 lanes) or an in-word shift (half < 32) — the SWAR form of
# "move the top half of the lanes under the bottom half".
# ---------------------------------------------------------------------------
def _reduce_tree_words(words, width: int, K: int):
    """Run the log-tree on row-aligned words (width, ..., wpr).

    Returns (words (width+steps, ..., 1), cycles).  Lane positions within
    each P-bit row segment hold partial sums; after the tree each row's sum
    sits at its segment's bit 0."""
    P, wpr, r = _row_layout(K)
    traced = _is_traced(words)
    xp = jnp if traced else np
    cycles = 0
    w, m = width, P
    seg = P if P < _WORD else _WORD
    while m > 1:
        half = m // 2
        if half >= _WORD:
            hw = half // _WORD
            lo, hi = words[..., :hw], words[..., hw:]
        else:
            pat = (1 << half) - 1
            keep = 0
            for j in range(_WORD // seg):
                keep |= pat << (j * seg)
            keep = np.uint32(keep)
            lo = words & keep
            hi = (words >> xp.uint32(half)) & keep
        words = _add_words(lo, hi, out_bits=w + 1)
        cycles += move_cycles(w) + add_cycles(w)
        w += 1
        m = half
    return words, cycles


def _rows_result_bits(words, K: int):
    """Extract each row's post-tree result bit: (w, ..., 1) words -> (w, n_rows)
    {0,1} values (still word-space arithmetic, no plane tensors)."""
    P, wpr, r = _row_layout(K)
    traced = _is_traced(words)
    xp = jnp if traced else np
    t = words[..., 0]  # (w, n_row_words)
    if r == 1:
        return (t & 1).astype(xp.uint32)
    offs = (xp.arange(r, dtype=xp.uint32) * xp.uint32(P))
    bits = (t[..., None] >> offs) & 1  # (w, n_row_words, r)
    return bits.reshape(t.shape[:-1] + (-1,)).astype(xp.uint32)


def bitserial_reduce(planes, out_bits: int | None = None):
    """Sum across the *last* axis (bit lines) via the log-tree of §III-D.

    Each step moves the top half of the lanes under the bottom half and adds
    with one extra bit of width.  Returns (planes, cycles) with lane axis
    reduced to 1.  PackedPlanes stay packed: row-aligned inputs reduce on
    their words directly; flat inputs pay one :func:`shuffle_to_rows` lane
    shuffle first (a transient bit-grid gather — cheap, but row-aligned
    producers skip it entirely).  Integer value planes are never
    reconstructed mid-chain.
    """
    packed_in = isinstance(planes, PackedPlanes)
    if packed_in:
        pp = planes
    else:
        pp = pack_lanes(planes, row_align=True)
    k = pp.lane_shape[-1] if pp.lane_shape else 1
    width = pp.n_planes
    other = tuple(pp.lane_shape[:-1])
    out_shape = other + (1,)
    traced = _is_traced(pp.words)
    if k <= 1:
        # the K == 1 row layout degenerates to flat packing of the rows
        out = PackedPlanes(pp.words, out_shape, 0)
        cycles = 0
    else:
        rows = shuffle_to_rows(pp)
        tree, cycles = _reduce_tree_words(
            rows.words.reshape((width, -1, max(_row_layout(k)[1], 1))), width, k)
        bits = _rows_result_bits(tree, k)  # (w', n_rows_padded)
        n_rows = int(np.prod(other)) if other else 1
        bits = bits[:, :n_rows]
        out = pack_lanes(bits.astype(jnp.uint8 if traced else np.uint8).reshape(
            (bits.shape[0],) + out_shape))
    # sanity: cycle formula matches the closed form
    assert cycles == reduce_cycles(k, width), (cycles, reduce_cycles(k, width))
    if out_bits is not None:
        out = PackedPlanes(
            (_zext_jnp if traced else _zext_np)(out.words, out_bits),
            out.lane_shape, out.row_lanes)
    if packed_in:
        return out, cycles
    return unpack_lanes(out), cycles


# ---------------------------------------------------------------------------
# Min/max reduction (§IV-D): the dynamic-range scalars of the requantization
# step, computed inside the array.  Same row-aligned halving walk as the sum
# tree, but each step is subtract + tag-masked selective copy instead of a
# widening add, so the width never grows.
# ---------------------------------------------------------------------------
def _minmax_tree_words(words, width: int, K: int):
    """Run the min/max log tree on row-aligned words ``(width, ..., wpr)``.

    Returns ``(min_words, max_words, cycles)``; after the tree each row's
    min/max sits at its segment's lane 0.  The host keeps two word grids
    (min candidates, max candidates), but they model *disjoint bit-line
    groups advancing in lockstep*: the per-step charge is one subtract +
    one tag-masked copy + a tag load (see :func:`minmax_cycles`)."""
    P, wpr, r = _row_layout(K)
    traced = _is_traced(words)
    xp = jnp if traced else np
    seg = P if P < _WORD else _WORD

    def halves(w, half):
        if half >= _WORD:
            hw = half // _WORD
            return w[..., :hw], w[..., hw:]
        pat = (1 << half) - 1
        keep = 0
        for j in range(_WORD // seg):
            keep |= pat << (j * seg)
        keep = np.uint32(keep)
        return w & keep, (w >> xp.uint32(half)) & keep

    mn = mx = words
    cycles = 0
    m = P
    while m > 1:
        half = m // 2
        lo, hi = halves(mx, half)
        lo_lt = _add_words(lo, hi, out_bits=width + 1, invert_b=True,
                           carry_one=True)[-1]  # sign of lo - hi
        mx = _select_words(lo, hi, lo_lt)
        lo, hi = halves(mn, half)
        hi_lt = _add_words(hi, lo, out_bits=width + 1, invert_b=True,
                           carry_one=True)[-1]  # sign of hi - lo
        mn = _select_words(lo, hi, hi_lt)
        cycles += add_cycles(width) + (width + 1) + 1
        m = half
    return mn, mx, cycles


def bitserial_minmax(planes):
    """Per-row min AND max over the *last* lane axis (§IV-D dynamic range).

    The in-cache half of the quantization step: a log tree of subtract +
    tag-masked selective copies run entirely in packed word space, so only
    the two per-row scalars ever leave the array.  Accepts raw planes or
    :class:`PackedPlanes` (row-aligned inputs walk their words directly,
    flat inputs pay one :func:`shuffle_to_rows`).  Returns
    ``((min, max), cycles)`` with the lane axis reduced to 1; the
    step-summed cycles are asserted against :func:`minmax_cycles`.

    Padding caveat: zero-padded lanes (flat packing, or rows whose length
    is not the power-of-two row width) fold a 0 into the tree.  Callers
    needing exact minima over arbitrary data must pre-pad rows to the next
    power of two with copies of a real lane — core/nc_layers.nc_minmax
    does exactly that (and handles two's-complement sign biasing)."""
    packed_in = isinstance(planes, PackedPlanes)
    pp = planes if packed_in else pack_lanes(planes, row_align=True)
    k = pp.lane_shape[-1] if pp.lane_shape else 1
    width = pp.n_planes
    other = tuple(pp.lane_shape[:-1])
    out_shape = other + (1,)
    traced = _is_traced(pp.words)
    if k <= 1:
        # the K == 1 row layout degenerates to flat packing of the rows
        out_mn = PackedPlanes(pp.words, out_shape, 0)
        out_mx = out_mn
        cycles = 0
    else:
        rows = shuffle_to_rows(pp)
        wpr = max(_row_layout(k)[1], 1)
        mnw, mxw, cycles = _minmax_tree_words(
            rows.words.reshape((width, -1, wpr)), width, k)
        n_rows = int(np.prod(other)) if other else 1
        dt = jnp.uint8 if traced else np.uint8

        def emit(w):
            bits = _rows_result_bits(w, k)[:, :n_rows]
            return pack_lanes(bits.astype(dt).reshape((width,) + out_shape))

        out_mn, out_mx = emit(mnw), emit(mxw)
    assert cycles == minmax_cycles(k, width), (cycles, minmax_cycles(k, width))
    if packed_in:
        return (out_mn, out_mx), cycles
    return (unpack_lanes(out_mn), unpack_lanes(out_mx)), cycles


# ---------------------------------------------------------------------------
# Fused packed dot (MAC + log-tree) over row-aligned word grids — the layer
# tiler's engine entry.  Bucketed jit cache for repeated tile shapes.
# ---------------------------------------------------------------------------
def bucket_words(n: int, minimum: int = 8) -> int:
    """Pad a word/row count up to its power-of-two bucket so repeated tile
    shapes share one compiled engine executable."""
    return max(_next_pow2(max(n, 1)), minimum)


_ENGINE_CACHE: dict[tuple, object] = {}


def engine_cache_info() -> dict:
    """Bucketed-jit compilation cache: entries keyed by
    (n_bits_x, n_bits_w, acc_bits, K) with jit-internal shape caches.

    ``compiled`` counts executables via the jitted function's private
    ``_cache_size`` and is best-effort: it reads 0 if a future JAX drops
    that attribute (``entries`` is always exact)."""
    return {
        "entries": len(_ENGINE_CACHE),
        "keys": sorted(_ENGINE_CACHE),
        "compiled": sum(getattr(f, "_cache_size", lambda: 0)()
                        for f in _ENGINE_CACHE.values()),
    }


def engine_cache_clear() -> None:
    _ENGINE_CACHE.clear()


def _dot_words_impl(xw, ww, *, K: int, acc_bits: int):
    """Shared host/traced packed-dot body (see :func:`packed_dot_words`)."""
    traced = _is_traced(xw, ww)
    nx, nw = xw.shape[0], ww.shape[0]
    prod = _mul_words(xw, ww)  # (nx+nw, *grid, wpr_or_rowwords)
    acc = (_zext_jnp if traced else _zext_np)(prod, acc_bits)
    P, wpr, r = _row_layout(K)
    # P >= 32: last axis is the words-per-row; P < 32: every axis is grid
    # (each word already holds 32/P whole rows).
    grid = acc.shape[1:-1] if r == 1 else acc.shape[1:]
    tree, _ = _reduce_tree_words(acc.reshape((acc_bits, -1, wpr)),
                                 acc_bits, K)
    bits = _rows_result_bits(tree, K)  # (w', flat_rows)
    w_out = bits.shape[0]
    xp = jnp if traced else np
    # NOTE: without jax_enable_x64 the traced decode saturates at int32 —
    # exact for any realistic row sum (uint8 operands need K > 33k to reach
    # 2^31); the host path is always exact int64.
    dt = np.int64
    if traced and not jax.config.jax_enable_x64:
        dt = jnp.int32
    weights = xp.ones((w_out,), dt) << xp.arange(w_out, dtype=dt)
    vals = (bits.astype(dt) * weights[:, None]).sum(axis=0)
    if r == 1:
        return vals.reshape(grid)
    return vals.reshape(grid[:-1] + (grid[-1] * r,))


def _dot_words_decoded(xw, ww, *, K: int, acc_bits: int):
    """Bucketed-jit engine body: decode the packed row grids to integer
    lanes and dot them with one fused multiply-sum.

    Bit-exact with the scanned bit-serial walk (:func:`_dot_words_impl`)
    — padding lanes decode to zero and contribute nothing — but lowers to
    vectorized integer XLA ops instead of a sequential scan, so one
    compiled executable per bucket actually amortizes on batch sweeps.
    The structural bit-serial emulation stays on the host path; modeled
    cycles are charged by the caller's unchanged formula either way."""
    P, wpr, r = _row_layout(K)

    def decode(w):
        n = w.shape[0]
        bits = _unpack_bits32_jnp(w)  # (n, *grid[, wpr], 32)
        weights = (jnp.int32(1) << jnp.arange(n, dtype=jnp.int32)).reshape(
            (n,) + (1,) * (bits.ndim - 1))
        return (bits.astype(jnp.int32) * weights).sum(axis=0)

    prod = decode(xw) * decode(ww)  # broadcast over the grid axes
    if r == 1:
        return prod.sum(axis=(-1, -2))  # (wpr, 32) lanes cover one row
    pr = prod.reshape(prod.shape[:-1] + (r, P))  # 32 = r rows x P lanes
    s = pr.sum(axis=-1)
    return s.reshape(prod.shape[:-2] + (prod.shape[-2] * r,))


def packed_dot_words(xw, ww, *, K: int, acc_bits: int,
                     engine: str | None = "host",
                     materialize: bool = True):
    """Fused row-aligned dot: ``sum_k x[row, k] * w[row, k]`` per row.

    ``xw``/``ww`` are word arrays of shape ``(n_planes, *grid, row_words)``
    whose grid axes broadcast against each other (so a window row packed
    once is shared by every filter, and vice versa).  ``row_words`` covers
    rows of ``K`` lanes padded to ``P = next_pow2(K)`` (``P < 32``: the
    last grid axis counts words of ``32/P`` rows each, and the result
    expands it back to rows).

    Returns ``(values int64, cycles_per_row)`` where cycles follow the
    unchanged per-dot formula :func:`dot_cycles` — one MAC into an
    ``acc_bits`` partial sum plus the §III-D log tree.  Cycles are
    charged HERE, before dispatch, so no backend can perturb the cycle
    model (they re-time execution only).

    ``engine`` names a registered backend (core/backends.py): ``"host"``
    is this module's exact numpy walk, ``"jit"`` the bucketed compiled
    decoded-lane kernel (one executable per (planes, acc, K) bucket —
    callers pad their tile grids to :func:`bucket_words` sizes so ragged
    tails replay the cached executable; :func:`engine_cache_info` reports
    the cache), ``"pallas-interpret"`` the byte-packed Pallas bit-serial
    GEMM.  ``engine=None`` resolves through the ``NC_BACKEND``
    environment variable (default host); an unknown name raises a
    :class:`ValueError` listing the registered backends.

    ``materialize=False`` skips the blocking device->host copy on the jit
    path and returns the dispatched device array instead: XLA's
    asynchronous dispatch lets the caller keep packing the NEXT tile's
    operands while this tile computes — the §IV-E double-buffered engine
    in core/nc_layers.py defers ``np.asarray`` by one tile.  Values are
    identical either way; synchronous backends only change WHEN the copy
    happens, never what it holds.
    """
    from repro.core import backends as _backends

    if engine is None:
        engine = _backends.default_backend()
    backend = _backends.get_backend(engine)
    n_bits = max(xw.shape[0], ww.shape[0])
    cycles = dot_cycles(K, n_bits, acc_bits)
    vals = backend.dot_words(xw, ww, K=K, acc_bits=acc_bits,
                             materialize=materialize)
    return vals, cycles


def _resize_planes(planes, n: int):
    if planes.shape[0] == n:
        return planes
    if planes.shape[0] > n:
        return planes[:n]
    pad = [(0, n - planes.shape[0])] + [(0, 0)] * (planes.ndim - 1)
    return (jnp if _is_traced(planes) else np).pad(planes, pad)


# ---------------------------------------------------------------------------
# Predicated ops (tag-latch) — ReLU / max / selective copy (§IV-D).
# ---------------------------------------------------------------------------
def selective_copy(dst, src, mask):
    """Copy ``src`` planes over ``dst`` where ``mask`` (per bit line) is 1.

    Cycles: one per bit (tag-enabled write-back), plus 1 to load the tag.
    """
    pd, packed_d = _coerce(dst)
    ps, _ = _coerce(src)
    pd, ps = _align_pair(pd, ps)
    n = max(pd.n_planes, ps.n_planes)
    tag = _pack_mask(mask, like=pd)
    out = _select_words(pd.words, ps.words, tag)
    return _emit(out, pd.lane_shape, packed_d, pd.row_lanes), n + 1


def bitserial_relu(x):
    """Two's-complement ReLU: zero lanes whose sign plane is set (§IV-D)."""
    px, packed_x = _coerce(x)
    sign = px.words[-1]
    out = px.words & ~sign
    return _emit(out, px.lane_shape, packed_x, px.row_lanes), px.n_planes + 1


def bitserial_max(a, b):
    """Element-wise max of two unsigned plane tensors via subtract + masked
    copy (§IV-D max pooling)."""
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    pa, pb = _align_pair(pa, pb)
    n = max(pa.n_planes, pb.n_planes)
    diff = _add_words(pa.words, pb.words, out_bits=n + 1,
                      invert_b=True, carry_one=True)
    a_lt_b = diff[-1]  # sign of a-b drives the tag latch
    out = _select_words(pa.words, pb.words, a_lt_b)
    return _emit(out, pa.lane_shape, packed_a or packed_b,
                 pa.row_lanes), add_cycles(n) + n + 1


# ---------------------------------------------------------------------------
# Convenience: quantized dot product exactly as an array column computes it.
# ---------------------------------------------------------------------------
def bitserial_dot(x, w, n_bits: int = 8, acc_bits: int = 24):
    """Per-lane dot product: lanes hold channels, reduce at the end.

    ``x``/``w``: unsigned integer tensors of shape [..., K].  Emulates the
    paper's conv inner loop: K tag-predicated MACs into a ``acc_bits``-wide
    partial sum per lane, then a log-tree reduction over lanes.
    Returns (value, cycles) — value is the exact integer dot product.
    """
    xp = bitplane_pack(x, n_bits)
    wp = bitplane_pack(w, n_bits)
    zeros = jnp.zeros if _is_traced(x, w) else np.zeros
    acc = zeros((acc_bits,) + tuple(x.shape), np.uint8)
    cycles = 0
    acc, c = bitserial_mac(acc, xp, wp)
    cycles += c
    red, c = bitserial_reduce(acc)
    cycles += c
    return bitplane_unpack(red)[..., 0], cycles


@dataclasses.dataclass
class OpCycles:
    """Cycle-cost card for one 8-bit MAC pipeline, used by the simulator.

    ``mac8`` is the paper's measured per-MAC constant (236 cycles for layer
    Conv2D_2b: includes multiply, accumulate into the 24-bit partial sum, tag
    loads and scratch moves).  First-principles floor is mul(8)+add(24) = 127;
    the remainder is per-MAC orchestration overhead, which we keep as a
    calibrated constant so the simulator reproduces the paper's tables.
    """

    bits: int = 8
    acc_bits: int = 24
    mac8: int = 236

    @property
    def mac_floor(self) -> int:
        return mul_cycles(self.bits) + add_cycles(self.acc_bits)

    @property
    def mac_overhead(self) -> int:
        return self.mac8 - self.mac_floor
