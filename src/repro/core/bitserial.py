"""Bit-serial in-SRAM arithmetic — functional, bit-exact emulation.

This module is the paper's §III (Neural Cache Arithmetic) as executable JAX.
Data lives in the *transposed* layout: an unsigned n-bit tensor becomes n
binary *planes* (LSB first).  Plane axis == word-line axis; every other axis
is a bit line.  All element lanes advance in lockstep, exactly like the
SRAM array: one bit-slice per cycle, carry/tag held in per-bit-line latches.

Packed bit-lane engine
----------------------
Every operation runs on a **word-packed** representation
(:class:`PackedPlanes`): 32 element lanes are packed into one ``uint32``
word, so a single bitwise AND/XOR/OR advances 32 lanes at once — the
software analogue of the SRAM array clocking thousands of bit lines per
cycle (and of Xcel-RAM's word-parallel bitwise reorganization).  The
layout is::

    words[p, w]  bit l  ==  plane p of lane (w * 32 + l)

with lanes flattened C-order from ``lane_shape`` and zero-padded up to a
multiple of 32.  Because the full adder, tag predication and selective
copy are pure bitwise ops, lanes never interact across bit positions:
carries propagate across *planes* (held in a packed carry word), never
across lanes, so padding lanes stay zero and results are bit-exact with
the per-lane reference.

The engine has two dispatch modes for the same packed algorithm:

* **concrete operands** (the emulation/test/bench path) run the
  bit-position loops directly on host ``numpy`` words — thousands of
  32-lane bitwise ops cost microseconds and nothing is ever compiled;
* **traced operands** (inside ``jax.jit``) run the same loops under
  ``lax.scan``, so traces stay O(1) in both lane count and bit width and
  the ops compile cleanly into larger jitted pipelines.

Cycle-model invariants (unchanged by packing — the packed engine models
the *same* hardware, it is only a faster emulation):

    add        : n + 1                     (§III-B)
    multiply   : n^2 + 5n - 2              (§III-C)
    divide     : 1.5 n^2 + 5.5 n           (§III-C)
    reduction  : log2(k) x (move + widening add)   (§III-D)

Every operation still returns ``(result_planes, cycles)`` with these
formulas, and :func:`bitserial_reduce` keeps asserting its step-summed
cycles against the closed form.  The public API is unchanged: ops accept
either raw ``{0,1}`` plane tensors (``(n_bits, *lanes)`` uint8) or
:class:`PackedPlanes`, and return the representation they were given.

The emulation is *bit-exact* against integer arithmetic
(tests/test_bitserial.py sweeps this); the cycle counts feed
core/simulator.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackedPlanes",
    "pack_lanes",
    "unpack_lanes",
    "bitplane_pack",
    "bitplane_unpack",
    "add_cycles",
    "mul_cycles",
    "div_cycles",
    "reduce_cycles",
    "bitserial_add",
    "bitserial_sub",
    "bitserial_multiply",
    "bitserial_mac",
    "bitserial_reduce",
    "selective_copy",
    "bitserial_relu",
    "bitserial_max",
]

_PLANE_DTYPE = jnp.uint8
_WORD = 32
_FULL_WORD = np.uint32(0xFFFFFFFF)


def _is_traced(*xs) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in xs)


# ---------------------------------------------------------------------------
# Transposed (bit-plane) layout — the software analogue of the paper's TMU.
# ---------------------------------------------------------------------------
def bitplane_pack(x, n_bits: int):
    """Pack an unsigned integer tensor into ``n_bits`` binary planes (LSB first).

    Returns shape ``(n_bits, *x.shape)`` with values in {0, 1}.  This is the
    paper's transpose layout: plane index == word line, remaining axes == bit
    lines.
    """
    if _is_traced(x):
        x = x.astype(jnp.uint32)
        shifts = jnp.arange(n_bits, dtype=jnp.uint32)
        planes = (x[None, ...] >> shifts.reshape((n_bits,) + (1,) * x.ndim)) & 1
        return planes.astype(_PLANE_DTYPE)
    x = np.asarray(x).astype(np.uint32)
    shifts = np.arange(n_bits, dtype=np.uint32).reshape((n_bits,) + (1,) * x.ndim)
    return ((x[None, ...] >> shifts) & 1).astype(np.uint8)


def bitplane_unpack(planes, signed: bool = False):
    """Inverse of :func:`bitplane_pack`.  ``signed`` interprets the planes as
    two's complement of width ``planes.shape[0]``."""
    if isinstance(planes, PackedPlanes):
        planes = unpack_lanes(planes)
    n = planes.shape[0]
    if _is_traced(planes):
        weights = (jnp.uint32(1) << jnp.arange(n, dtype=jnp.uint32)).reshape(
            (n,) + (1,) * (planes.ndim - 1)
        )
        val = jnp.sum(planes.astype(jnp.uint32) * weights, axis=0).astype(jnp.int64)
        if signed:
            val = jnp.where(planes[-1].astype(bool), val - (1 << n), val)
        return val
    p = np.asarray(planes, np.uint64)
    weights = (np.uint64(1) << np.arange(n, dtype=np.uint64)).reshape(
        (n,) + (1,) * (p.ndim - 1)
    )
    val = (p * weights).sum(axis=0).astype(np.int64)
    if signed:
        val = np.where(p[-1].astype(bool), val - (1 << n), val)
    return val


# ---------------------------------------------------------------------------
# Packed bit-lane container: 32 lanes per uint32 word.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackedPlanes:
    """Word-packed bit planes: ``words[p, w]`` bit ``l`` is plane ``p`` of
    lane ``w * 32 + l`` (lanes flattened C-order from ``lane_shape``,
    zero-padded to a multiple of 32)."""

    words: jax.Array  # (n_planes, n_words) uint32
    lane_shape: tuple[int, ...]

    @property
    def n_planes(self) -> int:
        return self.words.shape[0]

    @property
    def n_lanes(self) -> int:
        return int(np.prod(self.lane_shape)) if self.lane_shape else 1

    @property
    def n_words(self) -> int:
        return self.words.shape[1]

    def __getitem__(self, idx) -> "PackedPlanes":
        """Plane-axis slicing (lane layout is preserved)."""
        if not isinstance(idx, slice):
            raise TypeError("PackedPlanes supports plane-axis slices only")
        return PackedPlanes(self.words[idx], self.lane_shape)


jax.tree_util.register_dataclass(
    PackedPlanes, data_fields=["words"], meta_fields=["lane_shape"]
)


def pack_lanes(planes) -> PackedPlanes:
    """Raw ``{0,1}`` planes ``(n, *lanes)`` -> :class:`PackedPlanes`."""
    n = planes.shape[0]
    lane_shape = tuple(planes.shape[1:])
    if _is_traced(planes):
        flat = planes.reshape(n, -1).astype(jnp.uint32)
        n_lanes = flat.shape[1]
        n_words = max(-(-n_lanes // _WORD), 1)
        pad = n_words * _WORD - n_lanes
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        shifts = jnp.arange(_WORD, dtype=jnp.uint32)
        words = (flat.reshape(n, n_words, _WORD) << shifts).sum(axis=-1)
        return PackedPlanes(words.astype(jnp.uint32), lane_shape)
    flat = np.asarray(planes).astype(np.uint32).reshape(n, -1)
    n_lanes = flat.shape[1]
    n_words = max(-(-n_lanes // _WORD), 1)
    pad = n_words * _WORD - n_lanes
    if pad:
        flat = np.pad(flat, ((0, 0), (0, pad)))
    shifts = np.arange(_WORD, dtype=np.uint32)
    words = np.bitwise_or.reduce(flat.reshape(n, n_words, _WORD) << shifts,
                                 axis=-1)
    return PackedPlanes(words.astype(np.uint32), lane_shape)


def unpack_lanes(pp: PackedPlanes):
    """:class:`PackedPlanes` -> raw ``{0,1}`` planes ``(n, *lanes)`` uint8."""
    n, n_words = pp.words.shape
    if _is_traced(pp.words):
        shifts = jnp.arange(_WORD, dtype=jnp.uint32)
        bits = (pp.words[..., None] >> shifts) & jnp.uint32(1)
        flat = bits.reshape(n, n_words * _WORD)[:, : pp.n_lanes]
        return flat.reshape((n,) + pp.lane_shape).astype(_PLANE_DTYPE)
    shifts = np.arange(_WORD, dtype=np.uint32)
    bits = (np.asarray(pp.words)[..., None] >> shifts) & np.uint32(1)
    flat = bits.reshape(n, n_words * _WORD)[:, : pp.n_lanes]
    return flat.reshape((n,) + pp.lane_shape).astype(np.uint8)


def _coerce(x) -> tuple[PackedPlanes, bool]:
    if isinstance(x, PackedPlanes):
        return x, True
    return pack_lanes(x), False


def _emit(words, lane_shape: tuple[int, ...], packed: bool):
    pp = PackedPlanes(words, lane_shape)
    return pp if packed else unpack_lanes(pp)


def _pack_mask(mask):
    """Per-lane predicate -> packed tag word row (n_words,) uint32."""
    if isinstance(mask, PackedPlanes):
        return mask.words[0]
    if _is_traced(mask):
        return pack_lanes(mask.astype(_PLANE_DTYPE)[None]).words[0]
    return pack_lanes(np.asarray(mask, np.uint8)[None]).words[0]


# ---------------------------------------------------------------------------
# Cycle formulas (paper §III).
# ---------------------------------------------------------------------------
def add_cycles(n: int) -> int:
    return n + 1


def mul_cycles(n: int) -> int:
    return n * n + 5 * n - 2


def div_cycles(n: int) -> float:
    return 1.5 * n * n + 5.5 * n


def move_cycles(n: int) -> int:
    # Word-line move: read + write-back per bit; sense-amp cycling folds this
    # to ~1 cycle/bit in column-multiplexed arrays (§III-D, [18]).
    return n


def reduce_cycles(k: int, width: int) -> int:
    """Cycles to reduce ``k`` elements of ``width`` bits to one sum in-array."""
    cyc = 0
    w = width
    steps = int(np.ceil(np.log2(max(k, 1))))
    for _ in range(steps):
        cyc += move_cycles(w) + add_cycles(w)
        w += 1
    return cyc


# ---------------------------------------------------------------------------
# The column peripheral, word-packed: full adder + carry latch + tag latch,
# one bit-slice per cycle.  One uint32 word advances 32 lanes per bitwise op.
# Concrete operands run numpy loops (microseconds, nothing compiled); traced
# operands run the identical recurrence under lax.scan (O(1) trace size).
# ---------------------------------------------------------------------------
def _word_full_adder(a, b, c):
    s = a ^ b ^ c
    carry = (a & b) | ((a ^ b) & c)
    return s, carry


def _zext_np(w: np.ndarray, n: int) -> np.ndarray:
    if w.shape[0] == n:
        return w
    if w.shape[0] > n:
        return w[:n]
    out = np.zeros((n,) + w.shape[1:], np.uint32)
    out[: w.shape[0]] = w
    return out


def _zext_jnp(w, n: int):
    if w.shape[0] == n:
        return w
    if w.shape[0] > n:
        return w[:n]
    pad = [(0, n - w.shape[0])] + [(0, 0)] * (w.ndim - 1)
    return jnp.pad(w, pad)


def _add_words(aw, bw, *, out_bits: int, invert_b: bool = False,
               carry_one: bool = False):
    """Packed ripple add over ``out_bits`` planes.

    ``invert_b``/``carry_one`` give two's-complement subtraction for free —
    complement planes come from BLB, carry latch preset to 1 (§III-B).
    """
    if _is_traced(aw, bw):
        a = _zext_jnp(jnp.asarray(aw), out_bits)
        b = _zext_jnp(jnp.asarray(bw), out_bits)
        if invert_b:
            b = ~b
        init = jnp.full(a.shape[1:], _FULL_WORD if carry_one else 0, jnp.uint32)

        def step(carry, planes):
            s, carry = _word_full_adder(planes[0], planes[1], carry)
            return carry, s

        _, out = jax.lax.scan(step, init, (a, b))
        return out
    a = _zext_np(np.asarray(aw), out_bits)
    b = _zext_np(np.asarray(bw), out_bits)
    if invert_b:
        b = ~b
    carry = np.full(a.shape[1:], _FULL_WORD if carry_one else 0, np.uint32)
    out = np.empty_like(a)
    for i in range(out_bits):
        out[i], carry = _word_full_adder(a[i], b[i], carry)
    return out


def _mul_words(aw, bw):
    """Packed tag-predicated shifted-add multiply (§III-C).

    One step per multiplier plane: full-add the (plane-shifted) multiplicand
    into the product under that plane's tag word.
    """
    na, nb = aw.shape[0], bw.shape[0]
    total = na + nb
    if _is_traced(aw, bw):
        apad = _zext_jnp(jnp.asarray(aw), total)
        bw = jnp.asarray(bw)
        # plane-shifted copies of the multiplicand: roll is exact because
        # the top nb planes of apad are zero.
        shifted = jnp.stack([jnp.roll(apad, j, axis=0) for j in range(nb)])

        def step(prod, tj):
            tag, sh = tj

            def astep(carry, planes):
                s, carry = _word_full_adder(planes[0], planes[1], carry)
                return carry, s

            _, summed = jax.lax.scan(astep, jnp.zeros_like(tag), (prod, sh))
            return (tag & summed) | (~tag & prod), None

        prod, _ = jax.lax.scan(step, jnp.zeros_like(apad), (bw, shifted))
        return prod
    apad = _zext_np(np.asarray(aw), total)
    bw = np.asarray(bw)
    prod = np.zeros_like(apad)
    for j in range(nb):
        tag = bw[j]
        ntag = ~tag
        shifted = np.roll(apad, j, axis=0)
        carry = np.zeros_like(tag)
        for i in range(total):
            s, carry = _word_full_adder(prod[i], shifted[i], carry)
            prod[i] = (tag & s) | (ntag & prod[i])
    return prod


def _select_words(dst, src, tag):
    """Tag-predicated copy: dst where tag bit is 0, src where it is 1."""
    if _is_traced(dst, src, tag):
        src = _zext_jnp(jnp.asarray(src), dst.shape[0])
        return (tag & src) | (~tag & dst)
    src = _zext_np(np.asarray(src), dst.shape[0])
    return (tag & src) | (~tag & np.asarray(dst))


def bitserial_add(a, b, out_bits: int | None = None):
    """Element-wise sum of two plane tensors.  Returns (planes, cycles)."""
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    n = max(pa.n_planes, pb.n_planes)
    out_bits = out_bits if out_bits is not None else n + 1
    ow = _add_words(pa.words, pb.words, out_bits=out_bits)
    return _emit(ow, pa.lane_shape, packed_a or packed_b), add_cycles(n)


def bitserial_sub(a, b, out_bits: int | None = None):
    """a - b in two's complement (width = max width + 1 by default).

    Implemented the SRAM way: complement planes of ``b`` are read from BLB
    (free), carry latch preset to 1.  Returns (planes, cycles); MSB of the
    result is the sign — it drives the tag latch for max/ReLU predication.
    """
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    n = max(pa.n_planes, pb.n_planes)
    out_bits = out_bits if out_bits is not None else n + 1
    ow = _add_words(pa.words, pb.words, out_bits=out_bits,
                    invert_b=True, carry_one=True)
    return _emit(ow, pa.lane_shape, packed_a or packed_b), add_cycles(n)


def bitserial_multiply(a, b):
    """Element-wise product via tag-predicated shifted adds (§III-C).

    ``a`` is the multiplicand, ``b`` the multiplier; product has
    ``a_bits + b_bits`` planes.  Cycle count is the paper's n^2+5n-2 with
    n = max(a_bits, b_bits).
    """
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    ow = _mul_words(pa.words, pb.words)
    n = max(pa.n_planes, pb.n_planes)
    return _emit(ow, pa.lane_shape, packed_a or packed_b), mul_cycles(n)


def bitserial_mac(acc, a, b):
    """acc += a * b.  Returns (planes, cycles) with acc width preserved."""
    pacc, packed_acc = _coerce(acc)
    pa, _ = _coerce(a)
    pb, _ = _coerce(b)
    prod = _mul_words(pa.words, pb.words)
    n_mul = max(pa.n_planes, pb.n_planes)
    n_add = max(pacc.n_planes, prod.shape[0])
    out = _add_words(pacc.words, prod, out_bits=pacc.n_planes)
    cycles = mul_cycles(n_mul) + add_cycles(n_add)
    return _emit(out, pacc.lane_shape, packed_acc), cycles


# ---------------------------------------------------------------------------
# Reduction (§III-D): log-tree over the last lane axis.  The reduce axis is
# packed row-aligned (padded to a power of two) so each halving step is
# either a word-slice (half >= 32 lanes) or an in-word shift (half < 32) —
# the SWAR form of "move the top half of the lanes under the bottom half".
# ---------------------------------------------------------------------------
def _reduce_add_words(lo, hi):
    """Widening packed add for one tree step: width w -> w + 1."""
    w = lo.shape[0]
    return _add_words(lo, hi, out_bits=w + 1)


def _pack_rows(planes3, P: int):
    """(w, B, P) {0,1} planes -> (w, B, n_words) with the reduce axis packed
    row-aligned: P >= 32 gives P/32 words/row, P < 32 one word holding P bits."""
    w, B, _ = planes3.shape
    g = min(P, _WORD)
    n_words = max(P // _WORD, 1)
    if _is_traced(planes3):
        x = planes3.astype(jnp.uint32).reshape(w, B, n_words, g)
        shifts = jnp.arange(g, dtype=jnp.uint32)
        return (x << shifts).sum(axis=-1).astype(jnp.uint32)
    x = np.asarray(planes3).astype(np.uint32).reshape(w, B, n_words, g)
    shifts = np.arange(g, dtype=np.uint32)
    return np.bitwise_or.reduce(x << shifts, axis=-1)


def bitserial_reduce(planes, out_bits: int | None = None):
    """Sum across the *last* axis (bit lines) via the log-tree of §III-D.

    Each step moves the top half of the lanes under the bottom half and adds
    with one extra bit of width.  Returns (planes, cycles) with lane axis
    reduced to 1.
    """
    packed_in = isinstance(planes, PackedPlanes)
    raw = unpack_lanes(planes) if packed_in else planes
    traced = _is_traced(raw)
    xp = jnp if traced else np
    k = raw.shape[-1]
    width = raw.shape[0]
    other = tuple(raw.shape[1:-1])
    cycles = 0
    if k <= 1:
        cur = raw
    else:
        steps = int(np.ceil(np.log2(k)))
        P = 1 << steps
        pad = [(0, 0)] * (raw.ndim - 1) + [(0, P - k)]
        B = int(np.prod(other)) if other else 1
        words = _pack_rows(xp.pad(raw, pad).reshape(width, B, P), P)
        w, m = width, P
        while m > 1:
            half = m // 2
            if half >= _WORD:
                hw = half // _WORD
                lo, hi = words[..., :hw], words[..., hw:]
            else:
                keep = np.uint32((1 << half) - 1)
                lo = words & keep
                hi = (words >> np.uint32(half)) & keep
            words = _reduce_add_words(lo, hi)
            cycles += move_cycles(w) + add_cycles(w)
            w += 1
            m = half
        # one lane left: bit 0 of the single word per row
        cur = (words[..., 0] & 1).astype(
            _PLANE_DTYPE if traced else np.uint8).reshape((w,) + other + (1,))
    if out_bits is not None:
        cur = _resize_planes(cur, out_bits)
    # sanity: cycle formula matches the closed form
    assert cycles == reduce_cycles(k, width), (cycles, reduce_cycles(k, width))
    if packed_in:
        return pack_lanes(cur), cycles
    return cur, cycles


def _resize_planes(planes, n: int):
    if planes.shape[0] == n:
        return planes
    if planes.shape[0] > n:
        return planes[:n]
    pad = [(0, n - planes.shape[0])] + [(0, 0)] * (planes.ndim - 1)
    return (jnp if _is_traced(planes) else np).pad(planes, pad)


# ---------------------------------------------------------------------------
# Predicated ops (tag-latch) — ReLU / max / selective copy (§IV-D).
# ---------------------------------------------------------------------------
def selective_copy(dst, src, mask):
    """Copy ``src`` planes over ``dst`` where ``mask`` (per bit line) is 1.

    Cycles: one per bit (tag-enabled write-back), plus 1 to load the tag.
    """
    pd, packed_d = _coerce(dst)
    ps, _ = _coerce(src)
    n = max(pd.n_planes, ps.n_planes)
    tag = _pack_mask(mask)
    out = _select_words(pd.words, ps.words, tag)
    return _emit(out, pd.lane_shape, packed_d), n + 1


def bitserial_relu(x):
    """Two's-complement ReLU: zero lanes whose sign plane is set (§IV-D)."""
    px, packed_x = _coerce(x)
    sign = px.words[-1]
    out = px.words & ~sign
    return _emit(out, px.lane_shape, packed_x), px.n_planes + 1


def bitserial_max(a, b):
    """Element-wise max of two unsigned plane tensors via subtract + masked
    copy (§IV-D max pooling)."""
    pa, packed_a = _coerce(a)
    pb, packed_b = _coerce(b)
    n = max(pa.n_planes, pb.n_planes)
    diff = _add_words(pa.words, pb.words, out_bits=n + 1,
                      invert_b=True, carry_one=True)
    a_lt_b = diff[-1]  # sign of a-b drives the tag latch
    out = _select_words(pa.words, pb.words, a_lt_b)
    return _emit(out, pa.lane_shape, packed_a or packed_b), add_cycles(n) + n + 1


# ---------------------------------------------------------------------------
# Convenience: quantized dot product exactly as an array column computes it.
# ---------------------------------------------------------------------------
def bitserial_dot(x, w, n_bits: int = 8, acc_bits: int = 24):
    """Per-lane dot product: lanes hold channels, reduce at the end.

    ``x``/``w``: unsigned integer tensors of shape [..., K].  Emulates the
    paper's conv inner loop: K tag-predicated MACs into a ``acc_bits``-wide
    partial sum per lane, then a log-tree reduction over lanes.
    Returns (value, cycles) — value is the exact integer dot product.
    """
    xp = bitplane_pack(x, n_bits)
    wp = bitplane_pack(w, n_bits)
    zeros = jnp.zeros if _is_traced(x, w) else np.zeros
    acc = zeros((acc_bits,) + tuple(x.shape), np.uint8)
    cycles = 0
    acc, c = bitserial_mac(acc, xp, wp)
    cycles += c
    red, c = bitserial_reduce(acc)
    cycles += c
    return bitplane_unpack(red)[..., 0], cycles


@dataclasses.dataclass
class OpCycles:
    """Cycle-cost card for one 8-bit MAC pipeline, used by the simulator.

    ``mac8`` is the paper's measured per-MAC constant (236 cycles for layer
    Conv2D_2b: includes multiply, accumulate into the 24-bit partial sum, tag
    loads and scratch moves).  First-principles floor is mul(8)+add(24) = 127;
    the remainder is per-MAC orchestration overhead, which we keep as a
    calibrated constant so the simulator reproduces the paper's tables.
    """

    bits: int = 8
    acc_bits: int = 24
    mac8: int = 236

    @property
    def mac_floor(self) -> int:
        return mul_cycles(self.bits) + add_cycles(self.acc_bits)

    @property
    def mac_overhead(self) -> int:
        return self.mac8 - self.mac_floor
