"""Bit-serial in-SRAM arithmetic — functional, bit-exact emulation.

This module is the paper's §III (Neural Cache Arithmetic) as executable JAX.
Data lives in the *transposed* layout: an unsigned n-bit tensor becomes n
binary *planes* (LSB first).  Plane axis == word-line axis; every other axis
is a bit line.  All element lanes advance in lockstep, exactly like the
SRAM array: one bit-slice per cycle, carry/tag held in per-bit-line latches.

Every operation returns ``(result_planes, cycles)`` where ``cycles`` follows
the paper's published formulas:

    add        : n + 1                     (§III-B)
    multiply   : n^2 + 5n - 2              (§III-C)
    divide     : 1.5 n^2 + 5.5 n           (§III-C)
    reduction  : log2(k) x (move + widening add)   (§III-D)

The emulation is *bit-exact* against integer arithmetic (tests/test_bitserial.py
sweeps this with hypothesis); the cycle counts feed core/simulator.py.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "bitplane_pack",
    "bitplane_unpack",
    "add_cycles",
    "mul_cycles",
    "div_cycles",
    "reduce_cycles",
    "bitserial_add",
    "bitserial_sub",
    "bitserial_multiply",
    "bitserial_mac",
    "bitserial_reduce",
    "selective_copy",
    "bitserial_relu",
    "bitserial_max",
]

_PLANE_DTYPE = jnp.uint8


# ---------------------------------------------------------------------------
# Transposed (bit-plane) layout — the software analogue of the paper's TMU.
# ---------------------------------------------------------------------------
def bitplane_pack(x: jax.Array, n_bits: int) -> jax.Array:
    """Pack an unsigned integer tensor into ``n_bits`` binary planes (LSB first).

    Returns shape ``(n_bits, *x.shape)`` with values in {0, 1}.  This is the
    paper's transpose layout: plane index == word line, remaining axes == bit
    lines.
    """
    x = x.astype(jnp.uint32)
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    planes = (x[None, ...] >> shifts.reshape((n_bits,) + (1,) * x.ndim)) & 1
    return planes.astype(_PLANE_DTYPE)


def bitplane_unpack(planes: jax.Array, signed: bool = False) -> jax.Array:
    """Inverse of :func:`bitplane_pack`.  ``signed`` interprets the planes as
    two's complement of width ``planes.shape[0]``."""
    n = planes.shape[0]
    weights = (jnp.uint32(1) << jnp.arange(n, dtype=jnp.uint32)).reshape(
        (n,) + (1,) * (planes.ndim - 1)
    )
    val = jnp.sum(planes.astype(jnp.uint32) * weights, axis=0).astype(jnp.int64)
    if signed:
        val = jnp.where(planes[-1].astype(bool), val - (1 << n), val)
    return val


# ---------------------------------------------------------------------------
# Cycle formulas (paper §III).
# ---------------------------------------------------------------------------
def add_cycles(n: int) -> int:
    return n + 1


def mul_cycles(n: int) -> int:
    return n * n + 5 * n - 2


def div_cycles(n: int) -> float:
    return 1.5 * n * n + 5.5 * n


def move_cycles(n: int) -> int:
    # Word-line move: read + write-back per bit; sense-amp cycling folds this
    # to ~1 cycle/bit in column-multiplexed arrays (§III-D, [18]).
    return n


def reduce_cycles(k: int, width: int) -> int:
    """Cycles to reduce ``k`` elements of ``width`` bits to one sum in-array."""
    cyc = 0
    w = width
    steps = int(np.ceil(np.log2(max(k, 1))))
    for _ in range(steps):
        cyc += move_cycles(w) + add_cycles(w)
        w += 1
    return cyc


# ---------------------------------------------------------------------------
# The column peripheral: full adder + carry latch + tag latch, one bit-slice
# per cycle.  Python loops are over *bits* (static, <=64) — element lanes are
# fully vectorized, mirroring the massively-parallel bit lines.
# ---------------------------------------------------------------------------
def _full_adder(a, b, c):
    s = a ^ b ^ c
    carry = (a & b) | ((a ^ b) & c)
    return s, carry


def _plane(x: jax.Array, i: int, shape, like) -> jax.Array:
    if i < x.shape[0]:
        return x[i]
    return jnp.zeros(shape, _PLANE_DTYPE)


def bitserial_add(a: jax.Array, b: jax.Array, out_bits: int | None = None):
    """Element-wise sum of two plane tensors.  Returns (planes, cycles)."""
    n = max(a.shape[0], b.shape[0])
    out_bits = out_bits if out_bits is not None else n + 1
    lane_shape = a.shape[1:]
    carry = jnp.zeros(lane_shape, _PLANE_DTYPE)
    out = []
    for i in range(out_bits):
        ai = _plane(a, i, lane_shape, a)
        bi = _plane(b, i, lane_shape, b)
        s, carry = _full_adder(ai, bi, carry)
        out.append(s)
    return jnp.stack(out), add_cycles(n)


def bitserial_sub(a: jax.Array, b: jax.Array, out_bits: int | None = None):
    """a - b in two's complement (width = max width + 1 by default).

    Implemented the SRAM way: complement planes of ``b`` are read from BLB
    (free), carry latch preset to 1.  Returns (planes, cycles); MSB of the
    result is the sign — it drives the tag latch for max/ReLU predication.
    """
    n = max(a.shape[0], b.shape[0])
    out_bits = out_bits if out_bits is not None else n + 1
    lane_shape = a.shape[1:]
    carry = jnp.ones(lane_shape, _PLANE_DTYPE)
    out = []
    for i in range(out_bits):
        ai = _plane(a, i, lane_shape, a)
        bi = _plane(b, i, lane_shape, b) ^ 1
        s, carry = _full_adder(ai, bi, carry)
        out.append(s)
    return jnp.stack(out), add_cycles(n)


def bitserial_multiply(a: jax.Array, b: jax.Array):
    """Element-wise product via tag-predicated shifted adds (§III-C).

    ``a`` is the multiplicand, ``b`` the multiplier; product has
    ``a_bits + b_bits`` planes.  Cycle count is the paper's n^2+5n-2 with
    n = max(a_bits, b_bits).
    """
    na, nb = a.shape[0], b.shape[0]
    lane_shape = a.shape[1:]
    prod = [jnp.zeros(lane_shape, _PLANE_DTYPE) for _ in range(na + nb)]
    for j in range(nb):
        tag = b[j]  # load multiplier bit into the tag latch
        carry = jnp.zeros(lane_shape, _PLANE_DTYPE)
        for i in range(na):
            s, carry = _full_adder(prod[j + i], a[i], carry)
            prod[j + i] = jnp.where(tag.astype(bool), s, prod[j + i])
        # carry lands on a fresh (still-zero under this tag) plane
        prod[j + na] = jnp.where(tag.astype(bool), carry, prod[j + na])
    n = max(na, nb)
    return jnp.stack(prod), mul_cycles(n)


def bitserial_mac(acc: jax.Array, a: jax.Array, b: jax.Array):
    """acc += a * b.  Returns (planes, cycles) with acc width preserved."""
    prod, c_mul = bitserial_multiply(a, b)
    out, c_add = bitserial_add(acc, prod, out_bits=acc.shape[0])
    return out, c_mul + c_add


def bitserial_reduce(planes: jax.Array, out_bits: int | None = None):
    """Sum across the *last* axis (bit lines) via the log-tree of §III-D.

    Each step moves the top half of the lanes under the bottom half and adds
    with one extra bit of width.  Returns (planes, cycles) with lane axis
    reduced to 1.
    """
    k = planes.shape[-1]
    width = planes.shape[0]
    cycles = 0
    cur = planes
    while cur.shape[-1] > 1:
        m = cur.shape[-1]
        half = (m + 1) // 2
        lo = cur[..., :half]
        hi = cur[..., half:]
        if hi.shape[-1] < half:  # pad odd lane counts with zero lines
            pad = [(0, 0)] * (hi.ndim - 1) + [(0, half - hi.shape[-1])]
            hi = jnp.pad(hi, pad)
        w = cur.shape[0]
        cur, _ = bitserial_add(lo, hi, out_bits=w + 1)
        cycles += move_cycles(w) + add_cycles(w)
    if out_bits is not None:
        cur = _resize_planes(cur, out_bits)
    # sanity: cycle formula matches the closed form
    assert cycles == reduce_cycles(k, width), (cycles, reduce_cycles(k, width))
    return cur, cycles


def _resize_planes(planes: jax.Array, n: int) -> jax.Array:
    if planes.shape[0] == n:
        return planes
    if planes.shape[0] > n:
        return planes[:n]
    pad = [(0, n - planes.shape[0])] + [(0, 0)] * (planes.ndim - 1)
    return jnp.pad(planes, pad)


# ---------------------------------------------------------------------------
# Predicated ops (tag-latch) — ReLU / max / selective copy (§IV-D).
# ---------------------------------------------------------------------------
def selective_copy(dst: jax.Array, src: jax.Array, mask: jax.Array):
    """Copy ``src`` planes over ``dst`` where ``mask`` (per bit line) is 1.

    Cycles: one per bit (tag-enabled write-back), plus 1 to load the tag.
    """
    n = max(dst.shape[0], src.shape[0])
    src = _resize_planes(src, dst.shape[0])
    out = jnp.where(mask.astype(bool)[None, ...], src, dst)
    return out, n + 1


def bitserial_relu(x: jax.Array):
    """Two's-complement ReLU: zero lanes whose sign plane is set (§IV-D)."""
    sign = x[-1]
    zero = jnp.zeros_like(x)
    out, cyc = selective_copy(x, zero, sign)
    return out, cyc


def bitserial_max(a: jax.Array, b: jax.Array):
    """Element-wise max of two unsigned plane tensors via subtract + masked
    copy (§IV-D max pooling)."""
    diff, c_sub = bitserial_sub(a, b)
    a_lt_b = diff[-1]  # sign of a-b
    out, c_cp = selective_copy(a, b, a_lt_b)
    return out, c_sub + c_cp


# ---------------------------------------------------------------------------
# Convenience: quantized dot product exactly as an array column computes it.
# ---------------------------------------------------------------------------
def bitserial_dot(x: jax.Array, w: jax.Array, n_bits: int = 8, acc_bits: int = 24):
    """Per-lane dot product: lanes hold channels, reduce at the end.

    ``x``/``w``: unsigned integer tensors of shape [..., K].  Emulates the
    paper's conv inner loop: K tag-predicated MACs into a ``acc_bits``-wide
    partial sum per lane, then a log-tree reduction over lanes.
    Returns (value, cycles) — value is the exact integer dot product.
    """
    xp = bitplane_pack(x, n_bits)
    wp = bitplane_pack(w, n_bits)
    acc = jnp.zeros((acc_bits,) + x.shape, _PLANE_DTYPE)
    cycles = 0
    acc, c = bitserial_mac(acc, xp, wp)
    cycles += c
    red, c = bitserial_reduce(acc)
    cycles += c
    return bitplane_unpack(red)[..., 0], cycles


@dataclasses.dataclass
class OpCycles:
    """Cycle-cost card for one 8-bit MAC pipeline, used by the simulator.

    ``mac8`` is the paper's measured per-MAC constant (236 cycles for layer
    Conv2D_2b: includes multiply, accumulate into the 24-bit partial sum, tag
    loads and scratch moves).  First-principles floor is mul(8)+add(24) = 127;
    the remainder is per-MAC orchestration overhead, which we keep as a
    calibrated constant so the simulator reproduces the paper's tables.
    """

    bits: int = 8
    acc_bits: int = 24
    mac8: int = 236

    @property
    def mac_floor(self) -> int:
        return mul_cycles(self.bits) + add_cycles(self.acc_bits)

    @property
    def mac_overhead(self) -> int:
        return self.mac8 - self.mac_floor
