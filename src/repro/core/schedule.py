"""Batched slice-scheduler: ONE plan object from mapper to packed engine to
serving.

The paper's headline throughput comes from *batch scheduling*, not raw MACs:
filters stay resident in the compute ways while a batch of images streams
through the reserved I/O way (§VI-C), and even the quantization min/max
reduction stays in-cache (§IV-D).  This module turns the mapper's layout
(core/mapper.py) plus a batch size into an explicit, shared execution plan:

* :class:`SlicePlan` — one layer's plan.  Field ↔ paper-section map:

  ===================  =====================================================
  field                paper
  ===================  =====================================================
  ``mapped``           §IV-A/B filter splitting/packing/replication — the
                       residency layout (filters per array, parallel convs)
  ``filter_bytes``     §VI-C: filter bytes loaded ONCE per layer per batch
                       (filters are resident while the batch streams)
  ``serial_passes``    §IV-B serialized passes per image
  ``total_passes``     §IV-E layer-serial batching: passes × batch
  ``tile_rows`` /      packed-engine batch tiling: (image, pixel) rows ×
  ``tile_filters``     filters per engine tile, bounded by the cache
                       geometry's bit lines (``geom.compute_slots``)
  ``batch_tile``       whole images folded into one MAC+reduce tile
  ``spill_to_dram``    §IV-E: batch-wide outputs that outgrow the reserved
                       I/O way round-trip DRAM (the simulator's batching
                       model, now decided in one place)
  ``quant_passes``     §IV-D lockstep fixed-point requant passes per image
  ``minmax_cycles``    §IV-D in-cache min/max log tree per image (the two
                       dynamic-range scalars are all that leaves the cache)
  ===================  =====================================================

* :class:`NetworkSchedule` — the per-layer plans for a whole network at one
  batch size, with the aggregate residency/spill accounting.

Sparsity-aware scheduling (occupancy metadata + skip credits)
-------------------------------------------------------------
Value sparsity is a first-class *input* to the plan, not an opportunistic
engine trick.  A :class:`LayerOccupancy` carries what the pack-time scan
(:func:`bitserial.filter_occupancy`, run over the quantized filter rows)
detected, plus a ReLU-chain activation-sparsity estimate threaded from the
model definition (models/inception.py):

* ``zero_filters`` — filters whose every quantized weight equals the zero
  point.  Their dequantized value is exactly 0, so their whole serialized
  passes carry no information: :func:`plan_layer` re-runs the mapper's ONE
  serialization rule (``mapper.serial_passes_for``) over the *live* conv
  count and records the difference as ``SlicePlan.skipped_passes`` — the
  skipped-pass cycle credit the simulator prices (per-pass cycles x
  skipped passes, exactly) and the packed engine executes (the pruned pass
  list: zero-filter outputs are filled from the affine identity
  ``zw * sum(x)``, bit-identical to computing them).  Pruned filters are
  also not loaded: ``filter_bytes`` shrinks to the live set (§VI-C
  residency of an EIE-style pruned network).
* ``dead_planes`` — filter bit planes with no set bit; the host multiply
  elides those shifted-add steps (bitserial ``SKIP_STATS.planes_skipped``)
  with results unchanged.  Advisory for the model: per-plane elision never
  changes modeled cycles (the SRAM clocks every bit-slice of the passes it
  *does* run).
* ``activation_sparsity`` — the estimated fraction of exactly-zero input
  activations (ReLU chains make post-activation zeros exact in the uint8
  resident format).  An estimate can never earn an exact cycle credit, so
  it stays advisory: it sizes the EIE-style zero-operand word elision the
  host engine already performs and is reported alongside the measured
  zero-lane counts.

Only the deterministic filter occupancy changes numbers, and only when
present: ``occupancy=None`` (or zero detected sparsity) plans are
field-for-field identical to dense plans, and the simulator's dense
outputs stay bit-identical.  ``stream_batch_limit`` is intentionally
pruning-independent (activations stream at full width either way) —
until compression (PR 8) opts the plan into the tighter staging
accounting that lets shrinking residency raise the ceiling (see
``NetworkSchedule.stream_batch_limit``).

Consumers (the "one source of truth" contract):

* core/nc_layers.py tiles its packed MAC+reduce work with the plan's
  ``tile_rows``/``tile_filters`` (batch folded into the packed lane axis)
  and executes only the plan's live filter columns,
* core/simulator.py prices the SAME plan instead of re-deriving residency,
  so modeled and emulated cycles agree on the layout by construction
  (skipped-pass credits included),
* models/inception.py executes the schedule end to end (``nc_forward``),
* launch/serve.py admits request batches sized to the schedule, and
* core/slo.py predicts per-batch serving latency from it (the SLO
  admission policy's control input; ``stream_batch_limit`` is its hard
  batch cap).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core import bitserial as bs
from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import (LayerSpec, MappedLayer, check_wordline_budget,
                               compressed_filter_bytes, map_layer,
                               pass_filter_bytes, serial_passes_for)

__all__ = ["LayerOccupancy", "PassStage", "SlicePlan", "NetworkSchedule",
           "conv_tiles", "plan_layer", "plan_network", "prune_occupancy"]

ACC_BITS = 32  # reserved-way staging width of a conv partial sum


def conv_tiles(E: int, F: int, M: int, K: int,
               geom: CacheGeometry = XEON_E5_35MB,
               batch: int = 1,
               tile_pixels: int | None = None,
               tile_filters: int | None = None) -> tuple[int, int]:
    """Tile sizes for the packed engine: (rows, filters) per tile.

    A row is one (image, output pixel) pair — the batch is folded into the
    row axis, so one MAC+reduce tile serves rows from several images when
    they fit.  A tile's bit-line count (rows × P padded lanes × filters)
    is bounded by the cache's compute slots; whole-image row tiles are
    preferred.  ``tile_pixels``/``tile_filters`` are caller overrides
    (clamped to the actual work)."""
    R = batch * E * F
    P = bs._row_layout(K)[0]
    cap = max(geom.compute_slots, P)
    # clamp caller-supplied sizes first so the derived dimension is sized
    # for the effective tile, not an oversized request
    if tile_pixels is not None:
        tile_pixels = min(tile_pixels, R)
    if tile_filters is not None:
        tile_filters = min(tile_filters, M)
    if tile_pixels is None and tile_filters is None:
        if P * R * M <= cap:
            return R, M
        tf = cap // (P * R)
        if tf >= 1:
            return R, int(tf)
        return max(1, cap // P), 1
    if tile_filters is None:
        tile_filters = max(1, min(M, cap // (P * tile_pixels)))
    if tile_pixels is None:
        tile_pixels = max(1, min(R, cap // (P * tile_filters)))
    return min(tile_pixels, R), min(tile_filters, M)


@dataclasses.dataclass(frozen=True)
class LayerOccupancy:
    """Per-layer value-sparsity metadata (see the module docstring).

    ``zero_filters`` holds the sorted indices of filters whose every
    quantized weight equals the zero point — the deterministic sparsity
    that earns skipped-pass credits.  ``dead_planes``/``plane_bits`` and
    ``activation_sparsity`` are advisory (engine-side elision and
    reporting only)."""

    total_filters: int
    zero_filters: tuple[int, ...] = ()
    plane_bits: int = 8
    dead_planes: int = 0
    activation_sparsity: float = 0.0  # est. zero fraction of INPUT lanes
    # MEASURED live output lanes per image (PR 8 warmup re-planning):
    # None = unmeasured, the estimate above stays advisory.  When set, the
    # §IV-D requant pass count shrinks to the live output set — zero output
    # lanes requantize to the analytically-known zero point, the same
    # affine-identity argument that lets zero-filter passes skip.
    live_outputs: int | None = None

    def __post_init__(self):
        zf = tuple(sorted(int(i) for i in set(self.zero_filters)))
        object.__setattr__(self, "zero_filters", zf)
        if zf and not (0 <= zf[0] and zf[-1] < self.total_filters):
            raise ValueError(
                f"zero filter indices {zf[0]}..{zf[-1]} out of range for "
                f"{self.total_filters} filters")

    @property
    def n_zero(self) -> int:
        return len(self.zero_filters)

    @property
    def n_live(self) -> int:
        return self.total_filters - self.n_zero

    @property
    def zero_fraction(self) -> float:
        return self.n_zero / max(self.total_filters, 1)

    @classmethod
    def from_filter_rows(cls, rows, n_bits: int, zero_point: int = 0,
                         activation_sparsity: float = 0.0) -> "LayerOccupancy":
        """Build from quantized filter rows ``(M, K)`` via the pack-time
        scan (:func:`bitserial.filter_occupancy`)."""
        rows = np.asarray(rows)
        zero_mask, plane_live = bs.filter_occupancy(rows, n_bits, zero_point)
        return cls(
            total_filters=int(rows.shape[0]),
            zero_filters=tuple(int(i) for i in np.flatnonzero(zero_mask)),
            plane_bits=int(n_bits),
            dead_planes=int((~plane_live).sum()),
            activation_sparsity=float(activation_sparsity),
        )


@dataclasses.dataclass(frozen=True)
class PassStage:
    """One serialized pass split into its explicit (load, compute) stages.

    ``load_bytes`` is the slice of the layer's filter columns streamed into
    the reserved I/O way for THIS pass; ``overlapped`` marks loads that
    stream while the PREVIOUS pass's MAC+reduce runs in the compute ways
    (§IV-E double buffering).  The first stage's load is the prologue — it
    has no predecessor to hide under, so it is never overlapped.  Quant
    passes and the min/max reduction are not stages: they stay on the
    serial tail (§IV-D lockstep needs the full output set staged)."""

    index: int  # serialized pass index per image, 0-based
    load_bytes: int  # filter bytes streamed for this pass's columns
    overlapped: bool  # load hidden under pass index-1's MAC+reduce


@dataclasses.dataclass(frozen=True)
class SlicePlan:
    """One layer's execution plan (see the module docstring field map).

    Invariants (asserted by tests/test_schedule.py and
    tests/test_sparsity.py — discoverable here so you don't have to read
    them):

    * **Credit exactness** — the simulator prices ``skipped_passes`` as
      an exact per-pass credit: for any geometry and batch,
      ``dense.total_cycles - sparse.total_cycles ==
      sparse.skip_credit_cycles`` holds to the cycle
      (``simulator.modeled_layer_cycles``), because occupancy never
      changes the mapped layout — only the executed pass count.
    * **Dense bit-identity** — a plan built with ``occupancy=None`` (or
      with zero detected sparsity) is field-for-field identical to the
      dense plan, and every consumer's outputs (engine logits, simulator
      numbers) are bit-identical to pre-sparsity behavior.
    * ``executed_passes == serial_passes - skipped_passes`` is what the
      engine runs per image; pruned filters also leave ``filter_bytes``
      (the §VI-C residency of the live set).
    * The tile bound ``row_bits * tile_rows * tile_filters <=
      geom.compute_slots`` always holds (batch folded into the row
      axis)."""

    spec: LayerSpec
    mapped: MappedLayer
    batch: int
    # packed-engine tiling (consumed by core/nc_layers.py)
    K: int  # reduce lanes per dot row (R*S*C; window elems for pools)
    row_bits: int  # P = next_pow2(K): padded bit positions per row
    tile_rows: int  # (image, pixel) rows per engine tile
    tile_filters: int
    batch_tile: int  # whole images folded into one engine tile
    tiles: int  # engine tiles covering the whole batch
    # residency / movement (§IV-A/B, §VI-C)
    filter_bytes: int  # loaded once per layer per BATCH (filters resident)
    input_bytes_per_image: int
    output_bytes_per_image: int
    serial_passes: int  # per image (mapper §IV-B)
    total_passes: int  # serial_passes * batch (§IV-E layer-serial)
    spill_to_dram: bool  # batch outputs overflow the reserved I/O way
    spill_bytes_per_image: int  # dump + reload when spilling
    # §IV-D in-cache quantization
    quant_passes: int  # lockstep requant passes per image
    minmax_cycles: int  # in-cache min/max log tree per image
    # value sparsity (see "Sparsity-aware scheduling" in the module docs);
    # occupancy=None <=> dense plan, numbers above untouched
    occupancy: LayerOccupancy | None = None
    skipped_passes: int = 0  # serialized passes dropped (zero filters), /image
    # §IV-E double buffering (see PassStage); overlap=False plans and their
    # consumers are bit-identical to the strictly serial PR 3/4 behavior
    filter_bytes_per_pass: int = 0  # ONE pass's filter columns (live set)
    overlap: bool = False  # pass k+1's load streams under pass k's compute
    # PR 7 integrity: ABFT checksum columns verified after every pass's
    # MAC+reduce; integrity=False plans and their consumers are
    # bit-identical to the unchecked behavior (same invariant idiom as
    # occupancy/overlap above)
    integrity: bool = False  # verify checksum columns after each pass
    # slices quarantined by repeated integrity failures: the pass list is
    # re-serialized over the surviving slices (the fault path's analogue of
    # the pruned-pass machinery); () <=> full slice pool, numbers untouched
    quarantined_slices: tuple[int, ...] = ()
    # PR 8 compressed residency: filters stored CSR-style per bit plane
    # (bitserial.CompressedPlanes) — ``filter_bytes`` above is then the
    # compressed footprint (mapper.compressed_filter_bytes over the live
    # set) and ``dense_filter_bytes`` keeps the uncompressed residency the
    # simulator's exact credit is measured against.  compressed=False plans
    # and their consumers are bit-identical to the uncompressed behavior
    # (same invariant idiom as occupancy/overlap/integrity above).
    compressed: bool = False
    dense_filter_bytes: int = 0  # uncompressed live-set residency (credit ref)
    # PR 10 backend pin: the registered execution backend
    # (core/backends.py) the engine must run this plan's tiles through;
    # None leaves the choice to the call site / NC_BACKEND environment.
    # Backends re-time execution only — every field above, and every
    # modeled cycle derived from them, is backend-independent.
    backend: str | None = None

    @property
    def is_compute(self) -> bool:
        return self.spec.kind in ("conv", "fc")

    @property
    def executed_passes(self) -> int:
        """Serialized passes the engine actually runs per image: the dense
        §IV-B count minus the skipped-pass credit."""
        return self.serial_passes - self.skipped_passes

    @property
    def residency_credit_bytes(self) -> int:
        """Filter bytes compression keeps out of the §VI-C per-batch load:
        uncompressed live-set residency minus the compressed footprint.
        The simulator prices exactly this at filter bandwidth (and the
        credit can be slightly negative for a dense, unpruned layer —
        the CSR index is honest overhead)."""
        return (self.dense_filter_bytes - self.filter_bytes
                if self.compressed else 0)

    def pass_stages(self) -> tuple[PassStage, ...]:
        """The layer's serialized passes as explicit (load, compute) stages
        — one :class:`PassStage` per executed pass, loads chunked by the
        mapper's ONE streaming rule (``mapper.pass_filter_bytes``) so they
        sum to ``filter_bytes`` exactly.  Stage 0 is the un-hideable
        prologue; stages 1+ are overlapped iff the plan decided overlap is
        legal.  Pool layers (no filters, no passes to buffer) have no
        stages."""
        if not self.is_compute:
            return ()
        chunk = self.filter_bytes_per_pass
        stages = []
        for k in range(self.executed_passes):
            load = max(0, min(chunk, self.filter_bytes - k * chunk))
            stages.append(PassStage(index=k, load_bytes=load,
                                    overlapped=self.overlap and k > 0))
        return tuple(stages)


def plan_layer(spec: LayerSpec,
               geom: CacheGeometry = XEON_E5_35MB,
               batch: int = 1,
               *,
               tile_pixels: int | None = None,
               tile_filters: int | None = None,
               occupancy: LayerOccupancy | None = None,
               overlap: bool = False,
               integrity: bool = False,
               quarantined_slices: Sequence[int] = (),
               compressed: bool = False,
               backend: str | None = None) -> SlicePlan:
    """Map one layer (§IV-A/B) and schedule it for ``batch`` images.

    ``occupancy`` makes value sparsity an input to the plan: passes whose
    filters are all zero are dropped (``skipped_passes``, priced as an
    exact cycle credit by the simulator) and pruned filters are not loaded
    (``filter_bytes`` shrinks to the live set).  ``occupancy=None`` plans
    are field-for-field identical to the dense plan.

    ``overlap=True`` *requests* §IV-E double buffering: stream pass k+1's
    filter columns into the reserved I/O way while pass k's MAC+reduce
    runs.  The per-layer decision (``SlicePlan.overlap``) grants it only
    when it is legal — the layer is multi-pass compute with filters to
    load, and ONE pass's columns (``mapper.pass_filter_bytes`` over the
    live pass sequence) fit the I/O way's output half alongside the staged
    per-image outputs.  The headroom reuses the §IV-E spill accounting:
    spilling layers stage outputs in DRAM, so the full output half is
    prefetch headroom; non-spilling layers keep outputs staged and the
    prefetch buffer gets what is left.  Quant passes and min/max always
    stay on the serial tail.

    Invariants the tests pin down (tests/test_sparsity.py):

    * the skipped-pass count is *monotone* in sparsity — more zero
      filters never skip fewer passes — and comes from re-running the
      mapper's ONE serialization rule (``serial_passes_for``) over the
      live conv count, never from ad-hoc arithmetic here,
    * an occupancy whose ``total_filters`` disagrees with the spec
      raises (over-claiming sparsity is an error, not an optimization),
    * zero detected sparsity (``occupancy`` with no zero filters) plans
      structurally equal to ``occupancy=None``.

    ``integrity=True`` appends ABFT checksum columns to each pass's packed
    filter block, verified after its MAC+reduce (the fault path of
    ``core/faults.py``); the simulator prices the verification as an exact
    additive term and ``integrity=False`` plans are field-for-field
    identical to unchecked ones.  ``quarantined_slices`` removes slices
    lost to repeated integrity failures from the §IV-B replication pool:
    the SAME serialization rule re-runs over the surviving parallelism, so
    pass counts (and their pricing) grow honestly while the layout stays
    the mapper's.

    ``compressed=True`` stores the live filter set CSR-style per bit plane
    (PR 8): ``filter_bytes`` becomes the compressed footprint —
    ``mapper.compressed_filter_bytes`` over the live-set residency, live
    bit planes only plus the per-plane live-column index — and
    ``dense_filter_bytes`` records the uncompressed residency so the
    simulator can price the delta as an exact additive credit.
    ``filter_bytes_per_pass`` (and with it the §IV-E overlap headroom
    check) derives from the compressed bytes through the SAME
    ``mapper.pass_filter_bytes`` rule, so streaming, overlap legality and
    pricing all shrink consistently.  ``compressed=False`` plans are
    field-for-field identical to uncompressed ones.

    ``backend`` pins the execution backend (PR 10): a name from the
    registry in ``core/backends.py`` (validated here — an unknown name
    raises listing the registered set) that the packed engine must run
    this plan's tiles through.  Like every other plan decision it rides
    the plan to the call site: ``nc_conv2d``/``nc_fc`` adopt it when no
    explicit ``engine=`` is given, and an explicit engine that
    contradicts it raises.  Backends never change a plan's numbers —
    every other field is backend-independent."""
    if backend is not None:
        from repro.core import backends as _backends
        backend = _backends.get_backend(backend,
                                        source="plan_layer(backend=)").name
    mapped = map_layer(spec, geom)
    E = F = spec.E
    skipped = 0
    quarantined = tuple(sorted(set(int(s) for s in quarantined_slices)))
    parallel = mapped.parallel_convs
    base_serial = mapped.serial_passes
    if quarantined and spec.kind in ("conv", "fc"):
        if not all(0 <= s < geom.n_slices for s in quarantined):
            raise ValueError(
                f"{spec.name}: quarantined slices {quarantined} out of range "
                f"for {geom.n_slices}-slice geometry")
        # §IV-B replication is uniform across slices, so losing a slice
        # scales the parallel conv pool proportionally; the surviving pool
        # feeds the mapper's ONE serialization rule
        live_slices = max(geom.n_slices - len(quarantined), 1)
        parallel = max(1, mapped.parallel_convs * live_slices
                       // geom.n_slices)
        base_serial = serial_passes_for(spec.conv_count, parallel) or 1
    if spec.kind in ("conv", "fc"):
        check_wordline_budget(mapped, geom)
        K = spec.R * spec.S * spec.C
        tr, tf = conv_tiles(E, F, spec.M, K, geom, batch,
                            tile_pixels, tile_filters)
        pixels = max(E * F, 1)
        batch_tile = max(1, min(batch, tr // pixels))
        tiles = (math.ceil(batch * pixels / tr)
                 * math.ceil(spec.M / max(tf, 1)))
        filter_bytes = spec.filter_bytes
        quant_passes = math.ceil(spec.output_bytes / geom.compute_slots)
        minmax = bs.minmax_cycles(spec.output_bytes, ACC_BITS)
        if occupancy is not None:
            if occupancy.total_filters != spec.M:
                raise ValueError(
                    f"{spec.name}: occupancy covers {occupancy.total_filters} "
                    f"filters, layer has {spec.M}")
            # the mapper's ONE serialization rule over the LIVE conv count:
            # zero filters contribute no serialized work (their outputs are
            # the analytically-known affine constant)
            live_passes = serial_passes_for(
                occupancy.n_live * E * F, parallel)
            skipped = base_serial - live_passes
            filter_bytes = spec.R * spec.S * spec.C * occupancy.n_live
            if occupancy.live_outputs is not None:
                # warmup-measured live output lanes (PR 8): the §IV-D
                # lockstep requant runs over the live set only — zero
                # lanes fill with the analytically-known zero point
                live_out = max(0, min(int(occupancy.live_outputs),
                                      spec.output_bytes))
                quant_passes = math.ceil(live_out / geom.compute_slots)
    else:  # pooling: no filters, no requantization — comparisons in place
        K = spec.filter_elems
        tr, tf = batch * E * F, 1
        batch_tile = batch
        tiles = 1
        filter_bytes = 0
        quant_passes = 0
        minmax = 0
    compressed = bool(compressed) and spec.kind in ("conv", "fc")
    dense_resident = filter_bytes if compressed else 0
    if compressed:
        # CSR bit-plane residency (PR 8): the ONE compressed-residency
        # rule — everything downstream (per-pass streaming, overlap
        # headroom, the simulator's credit) derives from this footprint
        plane_bits = occupancy.plane_bits if occupancy is not None else 8
        live_planes = (plane_bits - occupancy.dead_planes
                       if occupancy is not None else plane_bits)
        filter_bytes = compressed_filter_bytes(
            dense_resident, spec.M, plane_bits, live_planes)
    # §IV-E: a layer's batch-wide output set must stay staged until the next
    # layer consumes it; the reserved way holds inputs + outputs, so a layer
    # spills once its per-image output exceeds a quarter of the I/O way.
    cap = geom.io_way_bytes / 2
    spill = spec.output_bytes > cap / 2
    # §IV-E double buffering: one pass's filter columns must fit the output
    # half of the reserved way next to whatever outputs stay staged there
    # (spilled outputs live in DRAM and free the whole half for prefetch)
    executed = base_serial - skipped
    fb_per_pass = pass_filter_bytes(filter_bytes, executed)
    headroom = cap - (0 if spill else spec.output_bytes)
    ov = (overlap and spec.kind in ("conv", "fc") and executed > 1
          and filter_bytes > 0 and fb_per_pass <= headroom)
    return SlicePlan(
        spec=spec, mapped=mapped, batch=batch,
        K=K, row_bits=bs._row_layout(K)[0],
        tile_rows=tr, tile_filters=tf, batch_tile=batch_tile, tiles=tiles,
        filter_bytes=filter_bytes,
        input_bytes_per_image=spec.input_bytes,
        output_bytes_per_image=spec.output_bytes,
        serial_passes=base_serial,
        total_passes=base_serial * batch,
        spill_to_dram=spill,
        spill_bytes_per_image=2 * spec.output_bytes if spill else 0,
        quant_passes=quant_passes,
        minmax_cycles=minmax,
        occupancy=occupancy,
        skipped_passes=skipped,
        filter_bytes_per_pass=fb_per_pass,
        overlap=ov,
        integrity=bool(integrity) and spec.kind in ("conv", "fc"),
        quarantined_slices=quarantined,
        compressed=compressed,
        dense_filter_bytes=dense_resident,
        backend=backend,
    )


@dataclasses.dataclass(frozen=True)
class NetworkSchedule:
    """Per-layer :class:`SlicePlan` list for one network at one batch size.

    The ONE plan object every consumer shares: the packed engine executes
    it, the simulator prices it (``simulate_network(schedule)``), the
    serving engine admits batches against it, and the SLO latency model
    (core/slo.py) predicts per-batch latency from it.  Asserted
    invariants: ``filter_bytes_loaded`` is independent of ``batch``
    (§VI-C residency — filters load once per layer per batch), and
    ``simulate_network`` consuming a schedule reproduces the spec-planned
    numbers to 1e-12 (tests/test_schedule.py)."""

    layers: tuple[SlicePlan, ...]
    geom: CacheGeometry
    batch: int
    overlap: bool = False  # §IV-E double buffering requested for the net
    integrity: bool = False  # PR 7 checksum verification requested
    compressed: bool = False  # PR 8 CSR bit-plane filter residency
    backend: str | None = None  # PR 10 execution backend pin (registry name)

    def plan(self, name: str) -> SlicePlan:
        for p in self.layers:
            if p.spec.name == name:
                return p
        raise KeyError(name)

    @property
    def filter_bytes_loaded(self) -> int:
        """Filter bytes loaded per batch — each layer's filters load ONCE
        and stay resident while the whole batch streams (§VI-C), so this
        is independent of ``batch``."""
        return sum(p.filter_bytes for p in self.layers)

    @property
    def spill_bytes_per_image(self) -> int:
        return sum(p.spill_bytes_per_image for p in self.layers)

    @property
    def total_passes(self) -> int:
        return sum(p.total_passes for p in self.layers)

    @property
    def skipped_passes(self) -> int:
        """Per-image serialized passes dropped by value sparsity, summed
        over layers (the network's skipped-pass credit)."""
        return sum(p.skipped_passes for p in self.layers)

    @property
    def overlapped_layers(self) -> int:
        """Layers whose per-pass filter loads stream under the previous
        pass's MAC+reduce (granted §IV-E double buffering)."""
        return sum(1 for p in self.layers if p.overlap)

    @property
    def residency_credit_bytes(self) -> int:
        """Filter bytes per batch that compression keeps off the load
        (dense live-set residency minus the compressed footprint, summed
        over layers); 0 for uncompressed schedules."""
        return sum(p.residency_credit_bytes for p in self.layers)

    @property
    def stream_batch_limit(self) -> int:
        """Images the reserved I/O way can stage at once for the widest
        layer (inputs + outputs share the way) — the §VI-C streaming
        bound; batches beyond it spill (see ``spill_to_dram``).  For
        uncompressed plans this is by construction independent of pruning:
        activations stream at full width whether or not filters are zero
        (asserted by tests/test_sparsity.py — a fully pruned network
        streams no deeper than a dense one).

        Compressed plans (PR 8) may additionally adopt the tighter
        per-layer staging accounting the compressed pipeline enables: a
        spilling layer's outputs round-trip DRAM (already priced per image
        via ``spill_bytes_per_image``) rather than staying staged, so they
        stop occupying the way, and the per-pass compressed filter chunk
        (``filter_bytes_per_pass``, the §IV-E streaming unit) is staged
        alongside the activations instead.  The runtime picks, PER LAYER,
        whichever discipline is narrower — legacy streaming is always
        still available — so compression never LOWERS the ceiling, and
        raises it where staged outputs (not filters) were the bottleneck
        (the full-network stem, today's limit-1 layers, goes 1 -> 2 at
        50% pruning; benchmarks/sched_breakdown.py gates this).
        Shrinking residency shrinks the packed width, so the limit is
        monotone non-decreasing in pruning (asserted by the
        tests/test_sparsity.py property sweep).  This is also the hard
        admission cap of the SLO serving policy (core/slo.py): admitted
        batches never exceed it."""
        def _width(p: SlicePlan) -> int:
            legacy = p.input_bytes_per_image + p.output_bytes_per_image
            if not self.compressed:
                return legacy
            packed = (p.input_bytes_per_image
                      + (0 if p.spill_to_dram else p.output_bytes_per_image)
                      + p.filter_bytes_per_pass)
            return min(legacy, packed)

        widest = max(_width(p) for p in self.layers)
        return max(1, self.geom.io_way_bytes // widest)


def plan_network(specs: Sequence[LayerSpec] | Iterable[LayerSpec],
                 geom: CacheGeometry = XEON_E5_35MB,
                 batch: int = 1,
                 occupancy: Mapping[str, LayerOccupancy] | None = None,
                 overlap: bool = False,
                 integrity: bool = False,
                 quarantined_slices: Sequence[int] = (),
                 compressed: bool = False,
                 backend: str | None = None,
                 ) -> NetworkSchedule:
    """Plan a network.  ``occupancy`` maps layer names to their
    :class:`LayerOccupancy` (layers absent from the map plan dense);
    ``overlap`` requests §IV-E double buffering for every layer (granted
    per layer by :func:`plan_layer`'s legality rule); ``integrity``
    requests PR 7 checksum verification for every compute layer;
    ``quarantined_slices`` re-serializes every layer over the surviving
    slice pool, and ``compressed`` stores every compute layer's filters
    CSR-style per bit plane (PR 8 — residency, streaming and the
    batch ceiling all shrink/raise together).  ``backend`` pins every
    layer's execution backend to one registered name (PR 10,
    core/backends.py) — a pure config change: consumers adopt
    ``schedule.backend`` with zero call-site edits."""
    occupancy = occupancy or {}
    if backend is not None:
        from repro.core import backends as _backends
        backend = _backends.get_backend(backend,
                                        source="plan_network(backend=)").name
    return NetworkSchedule(
        tuple(plan_layer(s, geom, batch, occupancy=occupancy.get(s.name),
                         overlap=overlap, integrity=integrity,
                         quarantined_slices=quarantined_slices,
                         compressed=compressed, backend=backend)
              for s in specs), geom, batch, overlap, bool(integrity),
        bool(compressed), backend)


def prune_occupancy(specs: Iterable[LayerSpec], fraction: float = 0.5,
                    plane_bits: int = 8) -> dict[str, LayerOccupancy]:
    """Spec-driven fixed pruning: mark the LAST ``round(M * fraction)``
    filters of every conv/fc layer as zero.

    The deterministic counterpart of actually zeroing weights
    (models/inception.prune_wpack uses the same last-k rule, so a plan
    built here matches the engine's pack-time detection on the pruned
    weights).  Used by the golden cycle-model regression and the
    dense-vs-sparse benchmarks — no weight tensors needed: skipped-pass
    credits depend only on the zero-filter COUNT."""
    occ = {}
    for s in specs:
        if s.kind not in ("conv", "fc"):
            continue
        k = int(round(s.M * fraction))
        occ[s.name] = LayerOccupancy(
            total_filters=s.M, zero_filters=tuple(range(s.M - k, s.M)),
            plane_bits=plane_bits)
    return occ
