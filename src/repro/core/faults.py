"""Deterministic fault injection for the bit-serial emulation (PR 7).

Neural Cache computes by activating two word lines against sense-amp
margins — the realistic failure modes are transient bit-flips in the
packed SRAM residency, stuck-at word lines, and whole-pass compute
corruption.  This module injects exactly those faults into the packed
word engine, deterministically:

* :class:`FaultProfile` — frozen, seed-threaded description of the fault
  environment (rates per fault class, stuck slices, stall injection).
* :class:`FaultState` — live injection state scoped by :func:`inject`.
  Every random draw is derived from ``(seed, class, layer, pass)`` via a
  CRC-keyed per-site generator, so the SAME seed produces the SAME
  faults regardless of execution order, retries, or batch size — the
  property the determinism tests assert.
* Transient classes (filter/activation flips, compute corruption,
  stalls) fire at most ONCE per (class, layer, pass) site: the first
  attempt at the site is corrupted, re-executions are clean, so bounded
  retry always recovers.  Stuck-at faults persist until the slice is
  quarantined (:meth:`FaultState.quarantine`), which is what drives the
  engine's re-plan path through ``schedule.plan_layer``.

Injection targets only *live* lanes (lanes whose clean operands can
change the output — the caller passes them), so every injected fault is
output-changing by construction and the integrity layer's "zero silent
corruption" guarantee is testable as an exact equality: corrupted
attempts == detected mismatches.  A flip confined to a dead/padding
lane would be output-invariant — harmless by definition — and is never
counted as an injection.

Faults corrupt *copies* of the packed operands handed to one pass; the
clean residency caches are never mutated, mirroring ECC-style recovery
where the checkpointed state survives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import zlib
from typing import Iterator, Optional

import numpy as np

__all__ = [
    "FaultProfile",
    "FaultState",
    "IntegrityError",
    "inject",
    "active",
    "COVERED_CLASSES",
]

# fault classes the integrity layer detects with certainty (stalls only
# perturb wall time — there is nothing to "detect")
COVERED_CLASSES = ("filter_flip", "act_flip", "compute", "stuck")

_WORD_MASK = np.uint32(0xFFFFFFFF)


class IntegrityError(RuntimeError):
    """A pass failed verification beyond the retry + quarantine budget."""

    def __init__(self, layer: str, pass_index: int, attempts: int):
        super().__init__(
            f"integrity failure in layer {layer!r}, pass {pass_index}: "
            f"still corrupt after {attempts} attempts and slice quarantine")
        self.layer = layer
        self.pass_index = pass_index
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Seed-threaded fault environment.  Rates are per (layer, pass) site
    probabilities; ``stuck_slices`` lists slice ids whose resident filter
    words are persistently corrupted until quarantined."""

    seed: int = 0
    filter_flip_rate: float = 0.0   # transient bit-flip in packed filter words
    act_flip_rate: float = 0.0      # transient bit-flip in packed window words
    compute_rate: float = 0.0       # whole-pass compute corruption
    stall_rate: float = 0.0         # per-pass latency stall probability
    stall_s: float = 0.0            # injected stall duration (seconds)
    stuck_slices: tuple = ()        # slice ids with stuck-at word lines
    n_slices: int = 14              # slice pool the pass->slice map hashes over
    max_retries: int = 3            # bounded re-execution budget per pass

    def __post_init__(self):
        for f in ("filter_flip_rate", "act_flip_rate", "compute_rate",
                  "stall_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f}={v} outside [0, 1]")
        stuck = tuple(sorted(set(int(s) for s in self.stuck_slices)))
        object.__setattr__(self, "stuck_slices", stuck)
        if any(s < 0 or s >= self.n_slices for s in stuck):
            raise ValueError(f"stuck slice out of range: {stuck}")
        if len(stuck) >= self.n_slices:
            raise ValueError("every slice stuck: nothing could ever verify")

    @classmethod
    def parse(cls, spec: str) -> "FaultProfile":
        """Parse a CLI spec like ``seed=7,filter=0.05,act=0.01,compute=0.01,
        stuck=2+5,stall=0.1:0.002``.  ``stuck`` takes ``+``-separated slice
        ids; ``stall`` takes ``rate`` or ``rate:seconds``."""
        kw: dict = {}
        alias = {"filter": "filter_flip_rate", "act": "act_flip_rate",
                 "compute": "compute_rate"}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"bad fault-profile field {part!r} "
                                 f"(expected key=value)")
            key, val = part.split("=", 1)
            key = key.strip()
            if key in ("seed", "n_slices", "max_retries"):
                kw[key] = int(val)
            elif key in alias:
                kw[alias[key]] = float(val)
            elif key == "stuck":
                kw["stuck_slices"] = tuple(
                    int(s) for s in val.split("+") if s)
            elif key == "stall":
                rate, _, dur = val.partition(":")
                kw["stall_rate"] = float(rate)
                kw["stall_s"] = float(dur) if dur else 0.001
            else:
                raise ValueError(f"unknown fault-profile key {key!r}")
        return cls(**kw)

    @property
    def any_faults(self) -> bool:
        return bool(self.filter_flip_rate or self.act_flip_rate
                    or self.compute_rate or self.stall_rate
                    or self.stuck_slices)


def _site_key(*parts) -> int:
    return zlib.crc32(":".join(str(p) for p in parts).encode())


class FaultState:
    """Live injection state for one :func:`inject` scope.

    Counters (all observable via :meth:`stats`):
      * ``injected`` — fault events applied (each is output-changing),
      * ``corrupt_attempts`` — pass executions that ran with >=1 event,
      * ``detected`` — verification mismatches the integrity layer caught
        (zero silent corruption <=> corrupt_attempts == detected when the
        integrity layer is on),
      * ``reexecuted`` — bounded pass re-executions,
      * ``stalls`` / ``stall_s_total`` — injected latency events.
    """

    def __init__(self, profile: FaultProfile):
        self.profile = profile
        self.quarantined: set = set()
        self.events: list = []
        self.injected = 0
        self.corrupt_attempts = 0
        self.detected = 0
        self.reexecuted = 0
        self.stalls = 0
        self.stall_s_total = 0.0
        self._fired: set = set()

    # -- deterministic randomness ------------------------------------------
    def _site_rng(self, cls: str, layer: str, pass_index: int):
        return np.random.default_rng(
            (int(self.profile.seed) << 32) ^ _site_key(cls, layer, pass_index))

    def _transient(self, cls: str, rate: float, layer: str,
                   pass_index: int) -> Optional[np.random.Generator]:
        """One-shot site draw: returns a site rng when the transient fault
        fires (first execution of the site only), else None."""
        if rate <= 0.0:
            return None
        site = (cls, layer, pass_index)
        if site in self._fired:
            return None
        rng = self._site_rng(cls, layer, pass_index)
        if rng.random() >= rate:
            return None
        self._fired.add(site)
        return rng

    # -- pass -> slice map --------------------------------------------------
    def live_slices(self) -> list:
        return [s for s in range(self.profile.n_slices)
                if s not in self.quarantined]

    def slice_for(self, layer: str, pass_index: int) -> Optional[int]:
        """Deterministic pass->slice residency map over live slices; shifts
        when a slice is quarantined (the re-planned pass list lands on the
        surviving slices)."""
        live = self.live_slices()
        if not live:
            return None
        return live[_site_key("slice", layer, pass_index) % len(live)]

    def quarantine(self, sid: int) -> None:
        if sid not in self.quarantined:
            self.quarantined.add(int(sid))
            self.events.append(("quarantine", "", int(sid), 0, 0, 0))

    # -- corruption ---------------------------------------------------------
    def _log(self, cls: str, layer: str, pass_index: int, *detail) -> None:
        d = tuple(int(x) for x in detail) + (0,) * (3 - len(detail))
        self.events.append((cls, layer, int(pass_index)) + d)
        self.injected += 1

    def corrupt_filter_words(self, ww: np.ndarray, layer: str,
                             pass_index: int, *, lanes: np.ndarray,
                             filters: int, P: int, r: int) -> np.ndarray:
        """Return ``ww`` or a corrupted copy.  ``lanes`` are the live lane
        indices (clean window sums nonzero over the rows sharing bit slot 0
        when r > 1) and ``filters`` bounds the live filter rows (jit tiles
        pad with dead filters), so any flip here changes the pass's output.
        Grid layout mirrors ``bitserial._pack_w_rows``: (n, M, 1,
        words_per_row) when r == 1 else (n, M, 1) with r replicas of P
        lanes per word."""
        if lanes.size == 0 or filters <= 0:
            return ww
        out = ww
        n_planes = ww.shape[0]
        n_filters = min(int(filters), ww.shape[1])

        rng = self._transient("filter_flip", self.profile.filter_flip_rate,
                              layer, pass_index)
        if rng is not None:
            k = int(lanes[rng.integers(lanes.size)])
            m = int(rng.integers(n_filters))
            p = int(rng.integers(n_planes))
            out = out.copy()
            if r == 1:
                out[p, m, 0, k // 32] ^= np.uint32(1 << (k % 32))
            else:
                out[p, m, 0] ^= np.uint32(1 << k)  # replica 0 of lane k
            self._log("filter_flip", layer, pass_index, p, m, k)

        sid = self.slice_for(layer, pass_index)
        if sid is not None and sid in self.profile.stuck_slices:
            hit = self._stuck_hit(out, lanes, n_filters, r,
                                  layer, pass_index)
            if hit is not None:
                p, m, k = hit
                if out is ww:
                    out = out.copy()
                if r == 1:
                    out[p, m, 0, k // 32] |= _WORD_MASK
                else:
                    out[p, m, 0] |= _WORD_MASK
                self._log("stuck", layer, pass_index, p, m, k)
        return out

    def _stuck_hit(self, ww: np.ndarray, lanes: np.ndarray, n_filters: int,
                   r: int, layer: str, pass_index: int):
        """Find a (plane, filter, lane) whose bit is 0 at a live lane, so
        the monotone whole-word stuck-at 1 provably changes the output.
        Deterministic per site; None when every live bit is already set."""
        rng = self._site_rng("stuck_pos", layer, pass_index)
        n_planes = ww.shape[0]
        order_k = rng.permutation(lanes.size)
        for ki in order_k[:8]:
            k = int(lanes[ki])
            for m in rng.permutation(n_filters)[:4]:
                for p in range(n_planes):
                    if r == 1:
                        word = int(ww[p, m, 0, k // 32])
                        bit = 1 << (k % 32)
                    else:
                        word = int(ww[p, m, 0])
                        bit = 1 << k
                    if not word & bit:
                        return p, int(m), k
        return None

    def corrupt_act_words(self, xw: np.ndarray, layer: str, pass_index: int,
                          *, lanes: np.ndarray, rows: int, P: int,
                          r: int) -> np.ndarray:
        """Transient bit-flip in the packed activation (window) words.
        ``lanes`` are lanes where some live filter is nonzero, so the flip
        changes that filter's output for the flipped row.  Grid layout
        mirrors ``bitserial._pack_x_rows``: (n, 1, T, words_per_row) when
        r == 1 else (n, 1, ceil(T / r)) with r rows x P lanes per word."""
        rng = self._transient("act_flip", self.profile.act_flip_rate,
                              layer, pass_index)
        if rng is None or lanes.size == 0 or rows <= 0:
            return xw
        k = int(lanes[rng.integers(lanes.size)])
        t = int(rng.integers(rows))
        p = int(rng.integers(xw.shape[0]))
        out = xw.copy()
        if r == 1:
            out[p, 0, t, k // 32] ^= np.uint32(1 << (k % 32))
        else:
            out[p, 0, t // r] ^= np.uint32(1 << ((t % r) * P + k))
        self._log("act_flip", layer, pass_index, p, t, k)
        return out

    def corrupt_values(self, vals: np.ndarray, layer: str, pass_index: int,
                       *, filters: int, rows: int) -> np.ndarray:
        """Whole-pass compute corruption: a nonzero additive error on one
        (filter, row) output of the pass — the sense-amp margin failure the
        checksums exist to catch."""
        rng = self._transient("compute", self.profile.compute_rate,
                              layer, pass_index)
        if rng is None or filters <= 0 or rows <= 0:
            return vals
        m = int(rng.integers(filters))
        t = int(rng.integers(rows))
        delta = int(rng.integers(1, 1 << 16))
        out = np.array(vals, dtype=np.int64, copy=True)
        out[m, t] += delta
        self._log("compute", layer, pass_index, m, t, delta)
        return out

    def maybe_stall(self, layer: str, pass_index: int) -> float:
        """Injectable per-pass latency stall (sleeps ``stall_s``)."""
        rng = self._transient("stall", self.profile.stall_rate,
                              layer, pass_index)
        if rng is None:
            return 0.0
        self.stalls += 1
        self.stall_s_total += self.profile.stall_s
        self.events.append(("stall", layer, int(pass_index), 0, 0, 0))
        if self.profile.stall_s > 0:
            time.sleep(self.profile.stall_s)
        return self.profile.stall_s

    # -- bookkeeping --------------------------------------------------------
    def note_corrupt_attempt(self) -> None:
        self.corrupt_attempts += 1

    def note_detected(self) -> None:
        self.detected += 1

    def note_reexecution(self) -> None:
        self.reexecuted += 1

    def stats(self) -> dict:
        return {
            "seed": self.profile.seed,
            "injected": self.injected,
            "corrupt_attempts": self.corrupt_attempts,
            "detected": self.detected,
            "reexecuted": self.reexecuted,
            "stalls": self.stalls,
            "stall_s_total": self.stall_s_total,
            "quarantined_slices": tuple(sorted(self.quarantined)),
            "events": len(self.events),
        }


_ACTIVE: Optional[FaultState] = None


@contextlib.contextmanager
def inject(profile: FaultProfile) -> Iterator[FaultState]:
    """Scope a :class:`FaultState` over the enclosed execution.  Nests by
    shadowing (inner scope wins); always restores on exit so test isolation
    never leaks an active fault environment."""
    global _ACTIVE
    prev = _ACTIVE
    state = FaultState(profile)
    _ACTIVE = state
    try:
        yield state
    finally:
        _ACTIVE = prev


def active() -> Optional[FaultState]:
    """The innermost active :class:`FaultState`, or None."""
    return _ACTIVE
