"""Atomic, async, *elastic* checkpointing.

  * **Atomic**: writes go to ``step_N.tmp-<nonce>/`` and are renamed to
    ``step_N/`` only after fsync — a preempted save never corrupts the
    latest checkpoint, restart picks up the newest complete directory.
  * **Async**: ``AsyncCheckpointer`` snapshots arrays to host memory on the
    training thread (cheap) and does serialization/IO on a worker thread,
    overlapping with the next training steps; ``wait()`` joins before the
    next save or at exit.
  * **Elastic**: arrays are stored as full *logical* tensors plus the tree
    structure — nothing about the mesh is persisted, so a checkpoint taken
    on (16, 16) restores onto (2, 16, 16) or a single CPU by resharding on
    load (``jax.device_put`` against the new sharding tree).  This is what
    lets the fleet resume after losing a pod.

Format: one ``.npz`` per pytree (params / opt_state / extras) + a JSON
manifest with the step, tree structure and leaf dtypes.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_STEP_RE = re.compile(r"^step_(\d+)$")


# ---------------------------------------------------------------------------
# pytree <-> flat dict of arrays
# ---------------------------------------------------------------------------
def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], str]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = {f"leaf_{i}": np.asarray(jax.device_get(x))
            for i, x in enumerate(leaves)}
    return flat, str(treedef)


def _save_tree(path: pathlib.Path, name: str, tree: Any) -> dict:
    flat, treedef = _flatten(tree)
    np.savez(path / f"{name}.npz", **flat)
    return {"treedef": treedef, "n_leaves": len(flat)}


def _load_tree(path: pathlib.Path, name: str, like: Any,
               shardings: Any = None) -> Any:
    with np.load(path / f"{name}.npz") as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    like_leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(like_leaves):
        raise ValueError(
            f"checkpoint {name}: {len(leaves)} leaves, expected "
            f"{len(like_leaves)} — structure changed?")
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        out = [jax.device_put(a.astype(l.dtype), s)
               for a, l, s in zip(leaves, like_leaves, sh_leaves)]
    else:
        out = [jax.numpy.asarray(a.astype(l.dtype)) for a, l in
               zip(leaves, like_leaves)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def save_checkpoint(ckpt_dir: str | os.PathLike, step: int,
                    trees: dict[str, Any], extras: dict | None = None) -> str:
    """Write ``trees`` (name -> pytree) atomically; returns the final path."""
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step}"
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=f"step_{step}.tmp-", dir=root))
    try:
        manifest = {"step": step, "trees": {}, "extras": extras or {}}
        for name, tree in trees.items():
            manifest["trees"][name] = _save_tree(tmp, name, tree)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return str(final)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [int(m.group(1)) for p in root.iterdir()
             if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | os.PathLike, likes: dict[str, Any],
                       step: int | None = None,
                       shardings: dict[str, Any] | None = None):
    """Restore trees by name; reshards onto ``shardings`` if given.

    Returns (step, {name: tree}, extras) or (None, None, None) when no
    complete checkpoint exists (fresh start).
    """
    root = pathlib.Path(ckpt_dir)
    step = latest_step(root) if step is None else step
    if step is None:
        return None, None, None
    path = root / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    out = {}
    for name, like in likes.items():
        sh = (shardings or {}).get(name)
        out[name] = _load_tree(path, name, like, sh)
    return step, out, manifest.get("extras", {})


class AsyncCheckpointer:
    """Snapshot on the caller thread, serialize+write on a worker thread."""

    def __init__(self, ckpt_dir: str | os.PathLike, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, trees: dict[str, Any],
             extras: dict | None = None) -> None:
        self.wait()
        # snapshot to host memory NOW (donated buffers may be reused next step)
        host_trees = {name: jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                         tree)
                      for name, tree in trees.items()}

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_trees, extras)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for p in self.ckpt_dir.iterdir()
            if (m := _STEP_RE.match(p.name)))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s}", ignore_errors=True)
