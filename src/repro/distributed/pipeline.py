"""GPipe-style pipeline parallelism over a ``stage`` mesh axis (optional).

The stack-of-layers representation makes PP a reshape: stacked layer params
``[L, ...]`` regroup to ``[S, L/S, ...]`` and the per-stage sub-stack scans
locally.  The schedule below is the classic GPipe fill/drain over
microbatches, expressed with ``shard_map`` + ``ppermute``:

  tick t: stage s computes microbatch (t - s) if 0 <= t - s < M, then
  passes its activation to stage s+1.  M + S - 1 ticks total; bubble
  fraction (S-1)/(M+S-1) — reported by :func:`bubble_fraction`.

Off by default: the production mesh spends its axes on (pod, data, model);
PP earns its keep only when a model's layers exceed one pod's HBM even
fully sharded, or to cut cross-pod collective traffic (stage boundaries
are point-to-point, not all-reduce).  The unit test runs S=2 on 2 host
devices and checks bit-exactness against the unpipelined stack.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["gpipe_apply", "bubble_fraction", "split_stages"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def split_stages(stacked_params, n_stages: int):
    """[L, ...] leaves -> [S, L/S, ...] (the PP regrouping)."""

    def leaf(x):
        L = x.shape[0]
        assert L % n_stages == 0, f"{L} layers % {n_stages} stages"
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(leaf, stacked_params)


def gpipe_apply(stage_fn: Callable, params_staged, x_mb, mesh: Mesh,
                axis: str = "stage"):
    """Run the GPipe schedule.

    stage_fn(stage_params, x) -> y       (one stage's local layer scan)
    params_staged: leaves [S, ...] sharded P(axis, ...)
    x_mb: [M, mb, ...] microbatched input (replicated across stages)
    Returns [M, mb, ...] outputs of the last stage.
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = x_mb.shape[0]
    n_ticks = M + S - 1

    def per_stage(params_local, x_all):
        # params_local: [1, ...] (this stage's slice); x_all: [M, mb, ...]
        params_local = jax.tree.map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(axis)

        def tick(carry, t):
            inbuf, outs = carry
            mb = jnp.clip(t - sid, 0, M - 1)
            first = jax.lax.dynamic_index_in_dim(x_all, jnp.clip(t, 0, M - 1),
                                                 axis=0, keepdims=False)
            myin = jnp.where(sid == 0, first, inbuf)
            active = (t - sid >= 0) & (t - sid < M)
            y = stage_fn(params_local, myin)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage writes its finished microbatch
            outs = jax.lax.cond(
                active & (sid == S - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, mb, axis=0),
                lambda o: o,
                outs)
            # hand activation to the next stage (ring permute, last->0 unused)
            nxt = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        inbuf0 = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)
        (_, outs), _ = jax.lax.scan(tick, (inbuf0, outs0),
                                    jnp.arange(n_ticks))
        # every stage holds `outs`, only the last stage's is real: share it
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), params_staged)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_staged, x_mb)
