"""Three-term roofline model from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` on the host backend reports *per-device*
flops/bytes (the SPMD-partitioned program), so we form each term as
per-device quantity / per-chip rate — algebraically identical to the
formulas above with chips multiplied through both numerator and
denominator.  Hardware constants are TPU v5e.

MODEL_FLOPS uses the standard 6*N*D training rule (N = params, D = tokens;
forward-only steps use 2*N*D) with N = active params for MoE.  The ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is useful —
remat recompute, dispatch einsums and attention (not counted in 6ND) push
it below 1.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.hlo_analysis import CollectiveStats

__all__ = ["HardwareSpec", "TPU_V5E", "RooflineReport", "roofline",
           "model_flops"]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    hbm_bytes: float         # capacity per chip


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6*N_active*D for train, 2*N_active*D for forward-only steps.

    Decode steps process one token per sequence (D = global_batch).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one new token per seq


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw (per-device) measurements
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_wire_bytes_per_device: float
    # the three terms, in seconds
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_total: float
    useful_flops_ratio: float
    peak_memory_per_device: float | None = None

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step that is the compute term — how close the
        step is to being MXU-bound (1.0 = perfectly compute-limited)."""
        t = self.bound_time
        return self.t_compute / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["bound_time_s"] = self.bound_time
        d["roofline_fraction"] = self.roofline_fraction
        return d


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             cost: dict, coll: CollectiveStats, cfg: ModelConfig,
             spec: ShapeSpec, hw: HardwareSpec = TPU_V5E,
             peak_memory: float | None = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.total_wire_bytes)

    t_c = flops / hw.peak_flops
    t_m = nbytes / hw.hbm_bw
    t_n = cbytes / hw.ici_bw

    dominant = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_n)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(cfg, spec)
    ratio = mf / (flops * chips) if flops > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=nbytes,
        collective_wire_bytes_per_device=cbytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_n,
        dominant=dominant,
        model_flops_total=mf,
        useful_flops_ratio=ratio,
        peak_memory_per_device=peak_memory,
    )
