from repro.distributed.sharding import (
    make_param_shardings,
    make_batch_sharding,
    make_cache_shardings,
    spec_for_param,
    ShardingReport,
)
from repro.distributed.hlo_analysis import collective_bytes, CollectiveStats
from repro.distributed.roofline import roofline, RooflineReport, TPU_V5E
