"""Sharding rules: named-parameter paths -> mesh PartitionSpecs.

The rules implement the distribution design of DESIGN.md §6:

  * TP   — output-feature / expert / vocab / head dims on the ``model`` axis,
  * FSDP — the complementary weight dim on the ``data`` axis (ZeRO-3 via GSPMD),
  * DP   — batch over ``("pod", "data")``; the ``pod`` axis replicates params
           (hierarchical scheme: FSDP inside a pod, plain DP across pods, so
           the slow inter-pod links carry only gradient all-reduces),
  * EP   — the stacked expert axis of MoE weights on ``model``,
  * SP   — long-context KV/state caches sharded on the sequence dim.

Every rule is divisibility-checked.  A dim that does not divide its mesh axis
falls back to replication and the fallback is recorded in the
:class:`ShardingReport` (e.g. qwen2-7b: 28 heads % 16 != 0 -> attention
runs FSDP-sharded while its MLP is TP-sharded).

GSPMD treats these specs as layout constraints, not as a rewrite of the
program: any spec is semantically correct, the compiler inserts the
collectives implied by the layout.  The rules below therefore only encode
the *performance* intent; correctness is the compiler's job.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

__all__ = [
    "spec_for_param",
    "make_param_shardings",
    "make_batch_sharding",
    "make_cache_shardings",
    "current_abstract_mesh",
    "ShardingReport",
]


def current_abstract_mesh():
    """The mesh installed by set_mesh / ``with mesh:`` at trace time, or
    None.  ``jax.sharding.get_abstract_mesh`` where it exists; older JAX
    exposes the same context via ``thread_resources.env.physical_mesh``."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib  # pre-get_abstract_mesh releases
    phys = getattr(_mesh_lib.thread_resources.env, "physical_mesh", None)
    if phys is None or phys.empty:
        return None
    return phys.abstract_mesh


@dataclasses.dataclass
class ShardingReport:
    """Record of which rules fired and which fell back to replication."""

    assigned: dict[str, str] = dataclasses.field(default_factory=dict)
    fallbacks: list[str] = dataclasses.field(default_factory=list)

    def note(self, path: str, spec: P) -> None:
        self.assigned[path] = str(spec)

    def fallback(self, path: str, dim: int, size: int, axis: str, n: int) -> None:
        self.fallbacks.append(
            f"{path}: dim {dim} ({size}) % mesh[{axis}]={n} != 0 -> replicated"
        )


def _axis_size(mesh: Mesh, axis: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)


def _fits(size: int, mesh: Mesh, axis: str) -> bool:
    n = _axis_size(mesh, axis)
    return n > 1 and size % n == 0


def _maybe(size: int, mesh: Mesh, axis: str, path: str, dim: int,
           report: ShardingReport | None):
    """axis if divisible else None (+ report the fallback)."""
    if _fits(size, mesh, axis):
        return axis
    if report is not None and _axis_size(mesh, axis) > 1:
        report.fallback(path, dim, size, axis, _axis_size(mesh, axis))
    return None


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# per-parameter rules
# ---------------------------------------------------------------------------
def spec_for_param(path: str, shape: tuple[int, ...], cfg: ModelConfig,
                   mesh: Mesh, report: ShardingReport | None = None) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    ``path`` is '/'-joined (e.g. ``stages/0/attn/wq``).  Leading stacked-layer
    axes (from the scan representation) are never sharded.
    """
    name = path.rsplit("/", 1)[-1]
    parent = path.rsplit("/", 2)[-2] if "/" in path else ""
    nd = len(shape)

    def m(i: int, axis: str):
        return _maybe(shape[i], mesh, axis, path, i, report)

    # ---- embeddings / head -------------------------------------------------
    if name == "embed":  # (V, d): vocab->model (TP), d->data (FSDP)
        return P(m(0, "model"), m(1, "data"))
    if name == "head":  # (d, V)
        return P(m(0, "data"), m(1, "model"))

    # ---- MoE ---------------------------------------------------------------
    if parent == "moe":
        if name == "router":  # (L, d, E): E stays whole (routing is local)
            return P(*([None] * (nd - 2)), m(nd - 2, "data"), None)
        if name in ("wi", "wg"):  # (L, E, d, ff): EP on experts, FSDP on d
            return P(*([None] * (nd - 3)), m(nd - 3, "model"), m(nd - 2, "data"), None)
        if name == "wo":  # (L, E, ff, d)
            return P(*([None] * (nd - 3)), m(nd - 3, "model"), None, m(nd - 1, "data"))

    # ---- attention ---------------------------------------------------------
    if parent == "attn":
        if name in ("wq", "wk", "wv"):  # (L, d, H*hd): heads->model, d->data
            return P(*([None] * (nd - 2)), m(nd - 2, "data"), m(nd - 1, "model"))
        if name == "wo":  # (L, H*hd, d)
            return P(*([None] * (nd - 2)), m(nd - 2, "model"), m(nd - 1, "data"))
        if name in ("bq", "bk", "bv"):  # (L, H*hd)
            return P(*([None] * (nd - 1)), m(nd - 1, "model"))

    # ---- dense MLP (also arctic's dense residual) --------------------------
    if parent in ("mlp", "dense_mlp"):
        if name in ("wi", "wg"):  # (L, d, ff)
            return P(*([None] * (nd - 2)), m(nd - 2, "data"), m(nd - 1, "model"))
        if name == "wo":  # (L, ff, d)
            return P(*([None] * (nd - 2)), m(nd - 2, "model"), m(nd - 1, "data"))

    # ---- SSM (Mamba-2) ------------------------------------------------------
    if parent == "ssm":
        if name == "in_proj":  # (L, d, 2di+2N+nh)
            return P(*([None] * (nd - 2)), m(nd - 2, "data"), m(nd - 1, "model"))
        if name == "out_proj":  # (L, di, d)
            return P(*([None] * (nd - 2)), m(nd - 2, "model"), m(nd - 1, "data"))
        if name in ("conv_w", "conv_b", "norm_w"):  # channel dim last
            return P(*([None] * (nd - 1)), m(nd - 1, "model"))

    # ---- everything else (norms, scalars, A_log, D, dt_bias, betas) --------
    return P(*([None] * nd))


def make_param_shardings(cfg: ModelConfig, mesh: Mesh, params: Any,
                         report: ShardingReport | None = None):
    """Tree of NamedShardings matching ``params`` (arrays or ShapeDtypeStructs)."""

    def leaf(path, x):
        p = _path_str(path)
        spec = spec_for_param(p, tuple(x.shape), cfg, mesh, report)
        if report is not None:
            report.note(p, spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params)


# ---------------------------------------------------------------------------
# parallelism plan + batch / cache shardings
# ---------------------------------------------------------------------------
def plan_parallelism(cfg: ModelConfig) -> str:
    """Per-arch parallelism mode over the fixed (pod, data, model) mesh.

      tp   — >=20B dense: activations replicated over ``model``; ff/head/vocab
             dims TP-sharded (the model axis earns its keep in the GEMMs).
      ep   — MoE: experts on ``model``, batch ALSO on ``model`` (each chip
             holds a token group and an expert shard; dispatch is the
             all-to-all class GShard expects).
      fsdp — small dense/SSM: batch over every axis; weights stay sharded
             (ZeRO-3) and are all-gathered per layer inside the scan.  TP for
             a 1-7B model would replicate activations 16x for GEMMs too small
             to care — measured as the 526 GB/device temp pathology in the
             first olmo dry-run (EXPERIMENTS.md §Perf).
    """
    if cfg.is_moe:
        return "ep"
    return "tp" if cfg.param_count() >= 20e9 else "fsdp"


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _batch_spec(batch: int, mesh: Mesh, report: ShardingReport | None,
                what: str, mode: str = "tp") -> Any:
    """First candidate axis-tuple (by preference) that divides ``batch``."""
    has_pod = "pod" in mesh.axis_names
    if mode in ("fsdp", "ep"):
        cands = [("pod", "data", "model"), ("pod", "data"),
                 ("data", "model"), ("data",)]
    else:
        cands = [("pod", "data"), ("data",)]
    if not has_pod:
        cands = [tuple(a for a in c if a != "pod") for c in cands]
        cands = [c for i, c in enumerate(cands) if c and c not in cands[:i]]
    for axes in cands:
        total = 1
        for a in axes:
            total *= _axis_size(mesh, a)
        if batch % total == 0:
            if report is not None and axes != cands[0]:
                report.fallbacks.append(
                    f"{what}: batch {batch} %% {cands[0]} != 0 -> {axes}")
            return axes if len(axes) > 1 else axes[0]
    if report is not None:
        report.fallback(what, 0, batch, "data", _axis_size(mesh, "data"))
    return None


def make_batch_sharding(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec,
                        report: ShardingReport | None = None) -> NamedSharding:
    """Sharding for a [global_batch, seq] token (or label) array."""
    mode = plan_parallelism(cfg)
    b = _batch_spec(shape.global_batch, mesh, report, f"batch[{shape.name}]",
                    mode)
    if b is None and shape.global_batch == 1 and shape.kind != "decode":
        # batch of one -> shard the *sequence* (SP); decode steps carry a
        # [B, 1] token whose length-1 seq dim cannot shard.
        seq_ax = "data" if _fits(shape.seq_len, mesh, "data") else None
        return NamedSharding(mesh, P(None, seq_ax))
    return NamedSharding(mesh, P(b, None))


def make_cache_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec,
                         caches: Any,
                         report: ShardingReport | None = None):
    """Decode caches: batch -> ('pod','data'), heads/state -> 'model'.

    KV caches are [L, B, Hkv, W, hd]; SSM state is [L, B, nh, hd, N] and the
    conv state [L, B, K, C].  For batch-1 long-context decode the KV length
    dim W is sharded instead (sequence parallelism over the cache).
    """
    mode = plan_parallelism(cfg)
    b = _batch_spec(shape.global_batch, mesh, report, f"cache[{shape.name}]",
                    mode)
    used = set(b) if isinstance(b, tuple) else ({b} if b else set())

    def free(axis: str) -> bool:
        return axis not in used

    def leaf(path, x):
        p = _path_str(path)
        nd = len(x.shape)
        spec = [None] * nd
        # layout convention: axis 0 = stacked layers, axis 1 = batch
        if nd >= 2:
            spec[1] = b
        name = p.rsplit("/", 1)[-1]
        if name in ("k", "v", "ks", "vs") and nd == 5:  # [L,B,Hkv,W,hd|1]
            if free("model") and _fits(x.shape[2], mesh, "model"):
                spec[2] = "model"
            else:
                # kv heads don't divide TP -> shard the cache *length* (SP):
                # a 32k x batch-128 KV cache replicated 16x would blow HBM.
                ax3 = []
                if free("model") and _fits(x.shape[3], mesh, "model"):
                    ax3.append("model")
                if b is None and _fits(x.shape[3] // (ax3 and
                        _axis_size(mesh, "model") or 1), mesh, "data"):
                    ax3.append("data")  # batch-1 long-context decode
                spec[3] = tuple(ax3) if len(ax3) > 1 else (ax3[0] if ax3 else None)
        elif name == "ssm" and nd == 5:  # SSM state [L,B,nh,P,N]
            if free("model"):
                spec[2] = _maybe(x.shape[2], mesh, "model", p, 2, report)
        elif name == "conv" and nd == 4:  # [L,B,K,C]
            if free("model"):
                spec[3] = _maybe(x.shape[3], mesh, "model", p, 3, report)
        if report is not None:
            report.note(p, P(*spec))
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, caches)
