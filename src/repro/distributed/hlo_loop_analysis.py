"""Loop-aware cost analysis over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a while-loop
body (every ``jax.lax.scan``: our layer stacks, flash-attention tiles,
microbatch accumulation, loss chunking) is charged a single iteration.  For
an 80-layer scanned transformer that under-counts FLOPs by ~80x, which
would silently inflate every roofline fraction we report.

This module re-derives FLOPs / bytes / collective traffic from the HLO text
itself, multiplying each computation by the product of enclosing loop trip
counts:

  * computations are parsed into instruction tables (name -> shape),
  * ``while`` ops contribute edges (body, cond) x trip-count; trip count is
    recovered from the loop condition's ``compare(..., constant(N))``,
  * ``fusion``/``call``/conditional branches contribute edges x 1,
  * per instruction: dots count 2*prod(result)*prod(contracting dims);
    elementwise/reduce ops count prod(result); collective ops contribute
    ring wire bytes exactly as hlo_analysis.py,
  * bytes = operands + result per instruction (HloCostAnalysis convention).

Validated against ``compiled.cost_analysis()`` on loop-free programs in
tests/test_hlo_analysis.py (dots match exactly; total flops within a few
percent on elementwise-heavy graphs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

from repro.distributed.hlo_analysis import DTYPE_BYTES, _wire_factor

__all__ = ["analyze_hlo", "LoopAwareCost"]

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_CFG = re.compile(r"known_trip_count[^0-9]*\"?(\d+)\"?")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\](?:\{[^}]*\})?")
_OPCODE = re.compile(r"^\s*([a-z][a-z0-9\-]*)\(")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                    r"\{?%?([\w\.\-,%\s]+)\}?")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND = re.compile(r"%([\w\.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-even",
    "and", "or", "xor", "not", "select", "compare", "clamp", "sign",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_REDUCE_LIKE = {"reduce", "reduce-window"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}
_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "broadcast", "reshape", "transpose", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "iota", "convert", "rng",
    "gather", "scatter", "reverse", "after-all", "custom-call",
    "partition-id", "replica-id", "reduce-precision", "while", "fusion",
    "call", "conditional", "sort", "map", "rng-bit-generator",
    "opt-barrier", "domain", "copy-start", "copy-done",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) of a possibly-tuple HLO type string."""
    elems = nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES.get(dt, 4)
    return elems, nbytes


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    type_str: str
    rhs: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list = dataclasses.field(default_factory=list)
    shapes: dict = dataclasses.field(default_factory=dict)

    def fusion_byte_profile(self):
        """(per-param-index byte charge or None=full, root_charge or None).

        A fusion reads each operand either wholesale (elementwise use) or
        through internal slice/gather ops (charge the slice, not the
        operand — a scanned layer reads ONE layer's slice of the stacked
        weights/caches, not the whole stack), and writes either its full
        root or, for DUS-rooted update fusions, just the update slice.
        """
        param_of = {}
        for ins in self.instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.rhs)
                if m:
                    param_of[ins.name] = int(m.group(1))
        sliced: dict[int, float] = {}
        whole: set[int] = set()
        root_charge = None
        for ins in self.instrs:
            ops_names = []
            paren = ins.rhs.split("(", 1)
            if len(paren) > 1:
                ops_names = _OPERAND.findall(paren[1].split(")")[0])
            if ins.opcode in ("dynamic-slice", "gather", "slice"):
                _, rb = _shape_elems_bytes(ins.type_str)
                for i, on in enumerate(ops_names):
                    if on in param_of and i == 0:  # the sliced operand
                        pi = param_of[on]
                        sliced[pi] = sliced.get(pi, 0.0) + 2.0 * rb
                    # index operands: negligible
                continue
            if ins.opcode == "dynamic-update-slice":
                # operand 0 (the big buffer) is aliased, charge update x2
                if ops_names and ops_names[0] in param_of:
                    pi = param_of[ops_names[0]]
                    ub = 0
                    if len(ops_names) > 1 and ops_names[1] in self.shapes:
                        _, ub = _shape_elems_bytes(self.shapes[ops_names[1]])
                    sliced[pi] = sliced.get(pi, 0.0) + 2.0 * ub
                    root_charge = 0.0  # result aliases the input buffer
                for on in ops_names[1:]:
                    if on in param_of:
                        whole.add(param_of[on])
                continue
            for on in ops_names:
                if on in param_of:
                    whole.add(param_of[on])
        charges = {}
        for pi, b in sliced.items():
            if pi not in whole:
                charges[pi] = b
        return charges, root_charge


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs begins with the result type, then opcode(...)
        type_end = 0
        sm = _SHAPE.match(rhs) or re.match(r"^\(([^)]|\([^)]*\))*\)", rhs)
        if rhs.startswith("("):  # tuple type: find matching paren
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        type_end = i + 1
                        break
        elif sm:
            type_end = sm.end()
        type_str = rhs[:type_end]
        rest = rhs[type_end:].strip()
        om = _OPCODE.match(rest)
        opcode = om.group(1) if om else rest.split("(")[0].strip()
        ins = _Instr(name, opcode, type_str, rest)
        cur.instrs.append(ins)
        cur.shapes[name] = type_str
    comps["__entry__"] = comps.get(entry) or next(iter(comps.values()))
    return comps


def _trip_count(cond: _Comp) -> int:
    """Loop bound from the condition computation: the largest integer
    constant compared against (jax scans count 0..N-1)."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = _CONST_INT.search(ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, shapes: dict) -> float:
    out_elems, _ = _shape_elems_bytes(ins.type_str)
    k = 1
    dm = _DIMS_ATTR.search(ins.rhs)
    operands = _OPERAND.findall(ins.rhs.split("(", 1)[1].split(")")[0])
    if dm and operands:
        lhs = shapes.get(operands[0])
        if lhs:
            sh = _SHAPE.search(lhs)
            if sh:
                dims = [int(d) for d in sh.group(2).split(",") if d]
                for ci in dm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _operand_shapes(ins: _Instr, shapes: dict) -> list[int]:
    """Byte sizes of an instruction's operands (in order)."""
    paren = ins.rhs.split("(", 1)
    if len(paren) < 2:
        return []
    args = paren[1].split(")")[0]
    out = []
    for oname in _OPERAND.findall(args):
        if oname in shapes:
            _, ob = _shape_elems_bytes(shapes[oname])
            out.append(ob)
    return out


@dataclasses.dataclass
class LoopAwareCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_ops: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_ops": dict(self.collective_ops),
            "loops": list(self.loops),
        }


def _group_size(rhs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rhs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def analyze_hlo(text: str) -> LoopAwareCost:
    comps = _parse(text)
    out = LoopAwareCost()
    entry = comps["__entry__"]

    _NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "while", "call", "conditional"}

    def visit(comp: _Comp, mult: float, seen: tuple,
              count_bytes: bool = True) -> None:
        if comp.name in seen:  # defensive: HLO call graphs are acyclic
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.rhs)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
                body = comps.get(bm.group(1)) if bm else None
                cond = comps.get(cm.group(1)) if cm else None
                # XLA annotates exact trip counts in backend_config; the
                # condition-constant scan is the fallback.
                tm = _TRIP_CFG.search(ins.rhs)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(cond) if cond else 1
                out.loops.append({"while": ins.name, "trips": trips,
                                  "scope": comp.name})
                if body:
                    visit(body, mult * trips, seen + (comp.name,),
                          count_bytes)
                continue
            if op == "fusion":
                # HloCostAnalysis convention: a fusion's bytes are its own
                # operands+result; internal flops count, internal bytes don't.
                am = re.search(r"calls=%?([\w\.\-]+)", ins.rhs)
                if am and am.group(1) in comps:
                    visit(comps[am.group(1)], mult, seen + (comp.name,),
                          count_bytes=False)
            elif op == "call":
                am = re.search(r"to_apply=%?([\w\.\-]+)", ins.rhs)
                if am and am.group(1) in comps:
                    visit(comps[am.group(1)], mult, seen + (comp.name,),
                          count_bytes)
            elif op == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                if bm:
                    for b in bm.group(1).replace("%", "").split(","):
                        b = b.strip()
                        if b in comps:
                            visit(comps[b], mult, seen + (comp.name,),
                                  count_bytes)
            # reduce/sort/scatter to_apply bodies are scalar lambdas: skipped.

            # --- flops ------------------------------------------------------
            if op == "dot":
                out.flops += mult * _dot_flops(ins, comp.shapes)
            elif op in _ELEMENTWISE or op in _REDUCE_LIKE:
                elems, _ = _shape_elems_bytes(ins.type_str)
                out.flops += mult * elems

            # --- bytes ------------------------------------------------------
            if count_bytes and op not in _NO_BYTES:
                _, rbytes = _shape_elems_bytes(ins.type_str)
                if op == "fusion":
                    am = re.search(r"calls=%?([\w\.\-]+)", ins.rhs)
                    called = comps.get(am.group(1)) if am else None
                    ops_ = _operand_shapes(ins, comp.shapes)
                    if called is not None:
                        charges, root_charge = called.fusion_byte_profile()
                        byt = sum(charges.get(i, full)
                                  for i, full in enumerate(ops_))
                        byt += rbytes if root_charge is None else root_charge
                    else:
                        byt = rbytes + sum(ops_)
                    out.bytes_accessed += mult * byt
                elif op in ("dynamic-update-slice", "scatter"):
                    # in-place write: traffic = the update slice (read +
                    # write), NOT the full aliased buffer.  Charging the
                    # whole KV cache for a one-token decode write inflated
                    # the memory term ~400x before this rule.
                    ops_ = _operand_shapes(ins, comp.shapes)
                    ub = ops_[1] if len(ops_) > 1 else rbytes
                    out.bytes_accessed += mult * 2 * ub
                elif op in ("gather", "dynamic-slice", "slice"):
                    # reads only the gathered/sliced elements, not the
                    # whole operand table.
                    out.bytes_accessed += mult * 2 * rbytes
                elif op == "convert":
                    # bf16<->f32 normalization is an XLA:CPU artifact (TPU
                    # is native-bf16 and fuses converts); skip.
                    pass
                else:
                    obytes = sum(_operand_shapes(ins, comp.shapes))
                    out.bytes_accessed += mult * (rbytes + obytes)

            # --- collectives --------------------------------------------------
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                _, rbytes = _shape_elems_bytes(ins.type_str)
                if op.endswith("-start") and ins.type_str.startswith("("):
                    rbytes //= 2  # async tuple repeats operand+result
                g = _group_size(ins.rhs)
                out.collective_wire_bytes += (
                    mult * rbytes * _wire_factor(base, g))
                out.collective_ops[base] = (
                    out.collective_ops.get(base, 0) + mult)

    visit(entry, 1.0, ())
    return out
