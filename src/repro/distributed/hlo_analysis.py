"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
bytes, so we scan the optimized HLO module for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their operand
sizes.  The compiled module is the *per-device* program after SPMD
partitioning, so the sums are bytes-per-device; the roofline's collective
term divides total traffic by (chips x link_bw), which algebraically equals
per-device bytes / link_bw — see roofline.py.

Each collective kind has a wire-traffic multiplier under a bidirectional-
ring schedule on ``n`` participants (ICI is a torus; ring per dimension):

    all-gather       input is 1/n of the result: moves (n-1)/n of output bytes
    reduce-scatter   (n-1)/n of input bytes
    all-reduce       RS + AG = 2(n-1)/n of input bytes
    all-to-all       (n-1)/n of input bytes cross links
    collective-permute  1x operand bytes

The multiplier's group size is read from the op's replica_groups when
present.  We report both raw operand bytes (for audit) and wire bytes (for
the roofline term).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

__all__ = ["collective_bytes", "xla_cost_analysis", "CollectiveStats",
           "DTYPE_BYTES"]


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a dict.

    JAX has flip-flopped between returning a dict and a one-element list of
    dicts (one per computation) across releases; accept both."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[16,1024,512]{2,1,0} all-gather(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_ALT_RE.search(line)  # replica_groups=[g,n]<=...
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2  # unknown -> conservative minimum group


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "collective-permute":
        return 1.0
    return (n - 1) / n  # all-gather / reduce-scatter / all-to-all


@dataclasses.dataclass
class CollectiveStats:
    ops: dict[str, int] = dataclasses.field(default_factory=dict)
    operand_bytes: dict[str, int] = dataclasses.field(default_factory=dict)
    wire_bytes: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def add(self, kind: str, nbytes: int, group: int) -> None:
        self.ops[kind] = self.ops.get(kind, 0) + 1
        self.operand_bytes[kind] = self.operand_bytes.get(kind, 0) + nbytes
        self.wire_bytes[kind] = (
            self.wire_bytes.get(kind, 0.0) + nbytes * _wire_factor(kind, group)
        )

    def as_dict(self) -> dict:
        return {
            "ops": dict(self.ops),
            "operand_bytes": dict(self.operand_bytes),
            "wire_bytes": {k: round(v) for k, v in self.wire_bytes.items()},
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": round(self.total_wire_bytes),
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum collective result sizes (per device) from optimized HLO text.

    ``-start`` variants are counted; matching ``-done`` ops are skipped so
    async pairs are not double counted.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        stripped = line.lstrip()
        if not any(c in stripped for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            stats.add(kind, _shape_bytes(dtype, dims), _group_size(stripped))
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            inner, kind = m.group(1), m.group(2)
            nbytes = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(inner)
            )
            # async-start tuples repeat operand+result; result is half
            if kind != "all-to-all":
                nbytes //= 2
            stats.add(kind, nbytes, _group_size(stripped))
    return stats
