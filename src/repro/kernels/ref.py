"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` is the mathematical definition the kernel must match —
tests/test_kernels.py sweeps shapes/dtypes and asserts allclose (exact for
integer paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "quant_matmul_ref",
    "pack_bitplanes",
    "pack_bitplanes_bytes",
    "unpack_bitplanes_bytes",
    "pack_activation_nibbles",
    "unpack_activation_nibbles",
    "bitserial_matmul_ref",
    "flash_attention_ref",
]


def quant_matmul_ref(
    x_q: jax.Array,  # [M, K] int8
    w_q: jax.Array,  # [K, N] int8
    x_scale: jax.Array | float = 1.0,  # scalar
    w_scale: jax.Array | None = None,  # [N] or scalar
    bias: jax.Array | None = None,  # [N] f32
) -> jax.Array:
    """W8A8 GEMM: int32 accumulate, per-channel dequant epilogue -> f32."""
    acc = jnp.dot(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * jnp.asarray(x_scale, jnp.float32)
    if w_scale is not None:
        out = out * jnp.asarray(w_scale, jnp.float32)[None, :]
    if bias is not None:
        out = out + bias[None, :]
    return out


def pack_bitplanes(w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """int8 weights -> [n_bits, K, N] {0,1} planes, two's complement
    (MSB plane carries weight -2^(n-1)).  The TPU analogue of the paper's
    transposed (bit-line) layout: serial over planes, parallel over the tile.
    """
    w = w_q.astype(jnp.int32) & ((1 << n_bits) - 1)
    shifts = jnp.arange(n_bits, dtype=jnp.int32).reshape((n_bits,) + (1,) * w_q.ndim)
    return ((w[None] >> shifts) & 1).astype(jnp.int8)


def pack_bitplanes_bytes(w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """int8 weights -> [K, N] uint8 *byte-packed* planes: bit ``b`` of each
    byte is plane ``b`` (two's complement over ``n_bits``).

    This is the dense storage format for the bit-serial Pallas kernel: one
    byte carries all (up to 8) planes of an element, so the kernel streams
    8x less VMEM traffic than the unpacked [n_bits, K, N] int8 layout and
    unpacks planes with a shift+mask per MXU pass, in-kernel.
    """
    assert 1 <= n_bits <= 8, n_bits
    return (w_q.astype(jnp.int32) & ((1 << n_bits) - 1)).astype(jnp.uint8)


def unpack_bitplanes_bytes(packed: jax.Array, n_bits: int = 8) -> jax.Array:
    """[K, N] uint8 byte-packed -> [n_bits, K, N] {0,1} int8 planes
    (inverse of :func:`pack_bitplanes_bytes`; oracle/XLA-path format)."""
    return pack_bitplanes(packed.astype(jnp.int32), n_bits)


def pack_activation_nibbles(x_q: jax.Array) -> jax.Array:
    """int8 4-bit activations [M, K] -> [M, ceil(K/2)] uint8: two elements
    per byte, even element in the low nibble (two's complement over 4 bits).

    Byte-packing extended to the *activation* operand (W4A4): the kernel
    streams half the activation bytes and recovers each element in-kernel
    with a shift/mask + sign-extend, paying two half-K MXU passes per
    weight plane — same MACs, half the VMEM traffic on both operands.
    """
    if x_q.shape[-1] % 2:
        x_q = jnp.pad(x_q, ((0, 0), (0, 1)))
    lo = x_q[:, 0::2].astype(jnp.int32) & 0xF
    hi = x_q[:, 1::2].astype(jnp.int32) & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_activation_nibbles(packed: jax.Array, K: int) -> jax.Array:
    """Inverse of :func:`pack_activation_nibbles` (oracle path): [M, K2]
    uint8 -> [M, K] int8 with 4-bit sign extension."""
    b = packed.astype(jnp.int32)
    even = ((b & 0xF) ^ 8) - 8
    odd = ((b >> 4) ^ 8) - 8
    full = jnp.stack([even, odd], axis=-1).reshape(b.shape[0], -1)
    return full[:, :K].astype(jnp.int8)


def plane_weights(n_bits: int) -> jax.Array:
    """Per-plane scale: [1, 2, 4, ..., -2^(n-1)] (two's complement)."""
    w = 2 ** jnp.arange(n_bits, dtype=jnp.int32)
    return w.at[n_bits - 1].set(-(2 ** (n_bits - 1)))


def bitserial_matmul_ref(
    x_q: jax.Array,  # [M, K] int8 activations
    planes: jax.Array,  # [n_bits, K, N] {0,1} int8
    x_scale: jax.Array | float = 1.0,
    w_scale: jax.Array | None = None,  # [N] or scalar
) -> jax.Array:
    """Bit-serial GEMM: out = sum_b weight_b * (x @ plane_b), dequantized.

    Bit-exact with quant_matmul_ref when planes = pack_bitplanes(w_q).
    """
    n_bits = planes.shape[0]
    pw = plane_weights(n_bits)
    acc = jnp.zeros((x_q.shape[0], planes.shape[2]), jnp.int32)
    for b in range(n_bits):
        part = jnp.dot(
            x_q.astype(jnp.int32), planes[b].astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )
        acc = acc + pw[b] * part
    out = acc.astype(jnp.float32) * jnp.asarray(x_scale, jnp.float32)
    if w_scale is not None:
        out = out * jnp.asarray(w_scale, jnp.float32)[None, :]
    return out


def flash_attention_ref(
    q: jax.Array,  # [B, H, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,  # [B, Hkv, Tk, D]
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """GQA attention oracle (naive, materializes scores)."""
    B, H, Tq, D = q.shape
    Hkv = k.shape[1]
    groups = H // Hkv
    qg = q.reshape(B, Hkv, groups, Tq, D)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / jnp.sqrt(D).astype(q.dtype)
    qpos = jnp.arange(Tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Tq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
    return out.reshape(B, H, Tq, D)
