"""Flash attention Pallas kernel (GQA, causal) — TPU target.

VMEM-tiled online-softmax attention: grid (B*H, Tq/bq, Tk/bk) with the KV
axis innermost ("arbitrary" = sequential), so the (bq, bk) score tile, the
running max/sum and the output accumulator all live in VMEM scratch and the
O(T^2) score matrix never exists in HBM — the same "keep partials next to
the compute" discipline the paper applies to SRAM bit lines.

GQA is handled in the index maps: query head h reads KV head h // G, so KV
tiles are fetched once per group from HBM (the MXU sees the dense [bq, bk]
tiles regardless).

Tile defaults: bq=bk=256, D<=256 keeps the working set
(q 256xD + k/v 2x256xD + scores 256x256x4 + acc 256xDx4) under ~1 MB —
far inside the ~16 MB/core VMEM, dims aligned to the 128-lane MXU.

Validated against ref.flash_attention_ref with interpret=True in
tests/test_kernels_flash.py (shape/dtype sweeps).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _compiler_params(**kw):
    """TPU compiler params across JAX releases (CompilerParams was renamed
    from TPUCompilerParams); fail with a nameable error if both are gone."""
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported JAX version")
    return cls(**kw)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, n_k: int, bq: int, bk: int, causal: bool, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [bq, D]
    k = k_ref[0].astype(jnp.float32)  # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        i = pl.program_id(1)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    c = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * c + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = (acc_ref[...] * c[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(j == n_k - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,  # [B, H, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, H, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)
    n_q, n_k = Tq // bq, Tk // bk
    scale = 1.0 / math.sqrt(D)

    qf = q.reshape(B * H, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)

    def kv_index(bh, i, j):
        return (bh // H) * Hkv + (bh % H) // G, j, 0

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, D)
