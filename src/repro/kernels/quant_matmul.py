"""Fused W8A8 GEMM Pallas kernel — the production path of the paper's
quantized pipeline on TPU.

The Neural-Cache insight "never move operands out of the array between
multiply, accumulate and requantize" maps to: int8 x int8 -> int32 MACs on
the MXU with the dequant/bias epilogue fused in VMEM, so the accumulator
never round-trips HBM.

Grid: (M/bm, N/bn, K/bk), K innermost; int32 accumulator lives in a VMEM
scratch tile, epilogue fires on the last K step.  Tile defaults keep the
working set (x 128x512 + w 512x128 + acc 128x128x4B = 192 KB) well inside
the ~16 MB/core VMEM while aligning both MXU dims to 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(x_ref, w_ref, xs_ref, ws_ref, bias_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...].astype(jnp.float32)
        out = out * xs_ref[0] * ws_ref[...][None, :]
        out = out + bias_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def quant_matmul(
    x_q: jax.Array,  # [M, K] int8
    w_q: jax.Array,  # [K, N] int8
    x_scale: jax.Array,  # scalar f32
    w_scale: jax.Array,  # [N] f32 (per-channel)
    bias: jax.Array | None = None,  # [N] f32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)

    pad_m, pad_n, pad_k = (-M) % bm, (-N) % bn, (-K) % bk
    if pad_m or pad_k:
        x_q = jnp.pad(x_q, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w_q = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    if pad_n:
        w_scale = jnp.pad(w_scale, (0, pad_n))
        bias = jnp.pad(bias, (0, pad_n))
    x_scale = jnp.reshape(jnp.asarray(x_scale, jnp.float32), (1,))

    Mp, Kp = x_q.shape
    Np = w_q.shape[1]
    n_k = Kp // bk
    grid = (Mp // bm, Np // bn, n_k)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1,), lambda m, n, k: (0,)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale, bias)
    return out[:M, :N]
