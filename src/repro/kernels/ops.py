"""Public jit'd wrappers around the Pallas kernels.

``use_pallas`` policy: on TPU backends the compiled kernels run natively;
elsewhere (this CPU container) they run in interpret mode for correctness,
and callers that are on the hot path (models, serving) use the XLA fallback
(`*_xla`) which lowers to plain dot — numerically identical, fast on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitserial_matmul import bitserial_matmul as _bitserial_pallas
from repro.kernels.bitserial_matmul import bitserial_matmul_a4 as _bitserial_a4_pallas
from repro.kernels.quant_matmul import quant_matmul as _quant_pallas

__all__ = [
    "on_tpu",
    "quant_matmul",
    "bitserial_matmul",
    "bitserial_matmul_a4",
    "bitserial_matmul_exact",
    "pack_weights",
    "pack_activations",
    "quant_matmul_xla",
    "flash_attention",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_weights(w_q: jax.Array, n_bits: int = 8) -> jax.Array:
    """Weight-load-time transpose to bit-plane layout (the TMU step).

    Returns the dense **byte-packed** format ([K, N] uint8, bit b == plane
    b): 8x smaller than the unpacked [n_bits, K, N] plane stack, unpacked
    per tile in-kernel.  Pass the same ``n_bits`` to
    :func:`bitserial_matmul` (the MSB plane carries the -2^(n-1) weight)."""
    return ref.pack_bitplanes_bytes(w_q, n_bits)


@functools.partial(jax.jit, static_argnames=("prefer_pallas",))
def quant_matmul(x_q, w_q, x_scale, w_scale, bias=None, *, prefer_pallas: bool = False):
    """W8A8 GEMM with fused dequant epilogue."""
    if prefer_pallas or on_tpu():
        return _quant_pallas(x_q, w_q, x_scale, w_scale, bias,
                             interpret=not on_tpu())
    return ref.quant_matmul_ref(x_q, w_q, x_scale, w_scale, bias)


quant_matmul_xla = jax.jit(ref.quant_matmul_ref)


@functools.partial(jax.jit, static_argnames=("causal", "prefer_pallas"))
def flash_attention(q, k, v, *, causal: bool = True,
                    prefer_pallas: bool = False):
    """Tiled attention: Pallas kernel on TPU (VMEM online softmax), the
    naive oracle elsewhere (models/layers.py keeps its own scan-based
    fallback for the banded/cached paths)."""
    from repro.kernels.flash_attention import flash_attention as _fa
    if prefer_pallas or on_tpu():
        return _fa(q, k, v, causal=causal, interpret=not on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)


def pack_activations(x_q: jax.Array) -> jax.Array:
    """Nibble-pack 4-bit activations (2 elements/byte) for the W4A4 kernel
    — the activation-side counterpart of :func:`pack_weights`."""
    return ref.pack_activation_nibbles(x_q)


@functools.partial(jax.jit, static_argnames=("k", "prefer_pallas"))
def bitserial_matmul_a4(x_packed, planes, x_scale, w_scale, *, k: int,
                        prefer_pallas: bool = False):
    """W4A4 GEMM: nibble-packed activations x byte-packed 4-bit weight
    planes; 2 MXU passes per plane (half-K each), half the operand bytes.
    ``k`` is the unpacked inner dimension."""
    if prefer_pallas or on_tpu():
        return _bitserial_a4_pallas(x_packed, planes, x_scale, w_scale,
                                    n_bits=4, interpret=not on_tpu())
    x_q = ref.unpack_activation_nibbles(x_packed, k)
    return ref.bitserial_matmul_ref(
        x_q, ref.unpack_bitplanes_bytes(planes, 4), x_scale, w_scale)


def bitserial_matmul_exact(x_q, planes, *, n_bits: int,
                           w4a4: bool = False):
    """Exact unsigned-integer bit-serial GEMM through the Pallas kernel —
    the backend-registry entry point (``core/backends.py``
    ``pallas-interpret``).

    Unsigned plane weights (MSB carries +2^(n-1), matching the packed
    word engine's operand convention), no dequant epilogue: the int32
    accumulator comes back verbatim (``out_dtype=int32`` skips the lossy
    float32 round-trip), so results are byte-comparable against the host
    reference.  ``w4a4=True`` takes nibble-packed activations
    (:func:`pack_activations`) through the half-K W4A4 kernel.  Runs the
    Pallas interpreter off-TPU and the compiled kernel on TPU — real-TPU
    lowering is this same entry with :func:`on_tpu` flipping
    ``interpret`` off."""
    interp = not on_tpu()
    if w4a4:
        return _bitserial_a4_pallas(x_q, planes, 1.0, 1.0, n_bits=n_bits,
                                    out_dtype=jnp.int32, signed=False,
                                    interpret=interp)
    return _bitserial_pallas(x_q, planes, 1.0, 1.0, n_bits=n_bits,
                             out_dtype=jnp.int32, signed=False,
                             interpret=interp)


@functools.partial(jax.jit, static_argnames=("n_bits", "prefer_pallas"))
def bitserial_matmul(x_q, planes, x_scale, w_scale, *, n_bits: int | None = None,
                     prefer_pallas: bool = False):
    """Bit-serial (plane-decomposed) GEMM; cost scales with the plane count.

    ``planes`` is either the byte-packed [K, N] uint8 format from
    :func:`pack_weights` (pass its ``n_bits``) or the legacy unpacked
    [n_bits, K, N] {0,1} stack (``n_bits`` inferred)."""
    if planes.ndim == 3:
        n_bits = planes.shape[0]
        unpacked = planes
    else:
        n_bits = 8 if n_bits is None else n_bits
        unpacked = ref.unpack_bitplanes_bytes(planes, n_bits)
    if prefer_pallas or on_tpu():
        return _bitserial_pallas(x_q, planes, x_scale, w_scale, n_bits=n_bits,
                                 interpret=not on_tpu())
    return ref.bitserial_matmul_ref(x_q, unpacked, x_scale, w_scale)
