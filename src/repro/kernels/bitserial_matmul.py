"""Bit-serial GEMM Pallas kernel — the paper's compute model on the MXU.

The SRAM array processes one *bit* of every lane per cycle; the TPU analogue
processes one *bit-plane* of the weight tensor per MXU pass:

    out = sum_b  w_b * (x @ plane_b),   w_b = 2^b (MSB plane: -2^(n-1))

Properties carried over from the paper:
  * latency proportional to weight precision (planes are a static unroll:
    4-bit weights cost half the MXU passes of 8-bit),
  * transposed layout: planes are packed once at weight-load time
    (ref.pack_bitplanes_bytes == the TMU gateway).  Storage is
    **byte-packed**: one uint8 carries all n_bits planes of an element
    (bit b == plane b), so a (bk, bn) tile moves 8x less VMEM traffic
    than the unpacked [n_bits, bk, bn] layout; each MXU pass recovers its
    plane in-kernel with a shift+mask (a VPU-cheap op on the int32 tile),
  * beyond-paper: *zero-plane skipping* — a per-(plane, K-block, N-block)
    occupancy mask predicates all-zero plane-blocks off with @pl.when,
    exploiting bit-level sparsity the SRAM substrate cannot (it must clock
    every bit-slice).  Pass ``plane_mask`` precomputed at weight-load time
    (plane_block_mask over the unpacked planes); otherwise it is derived
    from the byte-packed tensor on every call, which transiently
    materializes the full [n_bits, K, N] plane stack.

Grid: (M/bm, N/bn, K/bk) with K innermost; planes of one (bk, bn) tile are
looped inside the kernel body (static python loop -> fully unrolled MXU
passes over the VMEM-resident byte tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import (pack_bitplanes_bytes, plane_weights,
                               unpack_bitplanes_bytes)

# W4A4 (bitserial_matmul_a4): byte-packing extends to the *activation*
# operand — two 4-bit elements per byte (ref.pack_activation_nibbles), so
# both operand tiles move half the VMEM bytes.  Each weight plane then
# costs two MXU passes over half-K (even nibbles @ even plane rows + odd @
# odd): identical MAC count per plane, so total HLO FLOPs still scale with
# the plane count (4-bit ~ 0.5x of the 8-bit kernel; asserted in
# tests/test_kernels.py).

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _plane_w(n_bits: int, signed: bool) -> jax.Array:
    """Per-plane scales: two's complement (MSB negative) for the signed
    quantized-GEMM convention, plain powers of two for the UNSIGNED
    operands of the packed word engine (core/backends.py adapter)."""
    if signed:
        return plane_weights(n_bits)
    return 2 ** jnp.arange(n_bits, dtype=jnp.int32)


def _store_out(o_ref, acc_ref, xs_ref, ws_ref):
    """Scale/dequant epilogue.  Integer out dtypes take the EXACT int32
    accumulator (scales must be 1 — the backend-registry conformance
    path, where a float32 round-trip would lose bits above 2^24)."""
    if jnp.issubdtype(o_ref.dtype, jnp.integer):
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)
    else:
        out = acc_ref[...].astype(jnp.float32)
        out = out * xs_ref[0] * ws_ref[...][None, :]
        o_ref[...] = out.astype(o_ref.dtype)


def _kernel(x_ref, p_ref, mask_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int,
            n_bits: int, signed: bool = True):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pw = _plane_w(n_bits, signed)
    packed = p_ref[...].astype(jnp.int32)  # (bk, bn) bytes: all planes
    for b in range(n_bits):  # bit-serial: one plane per MXU pass
        @pl.when(mask_ref[b, 0, 0] != 0)  # zero-plane skip (beyond-paper)
        def _plane(b=b):
            plane = (packed >> b) & 1  # in-kernel unpack: shift+mask
            part = jnp.dot(
                x_ref[...].astype(jnp.int32), plane,
                preferred_element_type=jnp.int32,
            )
            acc_ref[...] += pw[b] * part

    @pl.when(k == n_k - 1)
    def _epilogue():
        _store_out(o_ref, acc_ref, xs_ref, ws_ref)


def _kernel_a4(x_ref, p_ref, mask_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
               n_k: int, n_bits: int, signed: bool = True):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pw = _plane_w(n_bits, signed)
    xb = x_ref[...].astype(jnp.int32)  # (bm, bk2) bytes: 2 elements each
    if signed:
        xe = ((xb & 0xF) ^ 8) - 8  # in-kernel unpack + 4-bit sign extend
        xo = ((xb >> 4) ^ 8) - 8
    else:
        xe = xb & 0xF  # unsigned nibbles: plain shift+mask unpack
        xo = xb >> 4
    packed = p_ref[...].astype(jnp.int32)  # (2*bk2, bn) bytes: all planes
    we = packed[0::2]  # even K rows pair with the low nibbles
    wo = packed[1::2]
    for b in range(n_bits):  # bit-serial: two half-K MXU passes per plane
        @pl.when(mask_ref[b, 0, 0] != 0)  # zero-plane skip (beyond-paper)
        def _plane(b=b):
            part = jnp.dot(xe, (we >> b) & 1,
                           preferred_element_type=jnp.int32)
            part += jnp.dot(xo, (wo >> b) & 1,
                            preferred_element_type=jnp.int32)
            acc_ref[...] += pw[b] * part

    @pl.when(k == n_k - 1)
    def _epilogue():
        _store_out(o_ref, acc_ref, xs_ref, ws_ref)


def plane_block_mask(planes: jax.Array, bk: int, bn: int) -> jax.Array:
    """[n_bits, K/bk, N/bn] int8 occupancy of each plane tile — compute
    once at weight-load time and pass as ``plane_mask``.

    ``planes`` is the unpacked [n_bits, K, N] {0,1} layout (use
    ref.unpack_bitplanes_bytes first when starting from byte-packed)."""
    n_bits, K, N = planes.shape
    p = planes.reshape(n_bits, K // bk, bk, N // bn, bn)
    return (p.sum(axis=(2, 4)) > 0).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("n_bits", "bm", "bn", "bk", "out_dtype",
                              "interpret", "signed")
)
def bitserial_matmul(
    x_q: jax.Array,  # [M, K] int8 activations
    planes: jax.Array,  # [K, N] uint8 byte-packed, or [n_bits, K, N] {0,1}
    x_scale: jax.Array,  # scalar f32
    w_scale: jax.Array,  # [N] f32
    plane_mask: jax.Array | None = None,  # [n_bits, K/bk, N/bn] int8
    *,
    n_bits: int | None = None,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=jnp.float32,
    interpret: bool = True,
    signed: bool = True,  # False: unsigned planes (MSB weight +2^(n-1))
) -> jax.Array:
    if planes.ndim == 3:  # legacy unpacked planes: re-pack to bytes
        n_bits = planes.shape[0]
        packed = pack_bitplanes_bytes(
            jnp.sum(planes.astype(jnp.int32)
                    << jnp.arange(n_bits, dtype=jnp.int32)[:, None, None],
                    axis=0), n_bits)
    else:
        n_bits = 8 if n_bits is None else n_bits
        packed = planes.astype(jnp.uint8)
    K, N = packed.shape
    M = x_q.shape[0]
    assert x_q.shape[1] == K
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)

    pad_m, pad_n, pad_k = (-M) % bm, (-N) % bn, (-K) % bk
    if pad_m or pad_k:
        x_q = jnp.pad(x_q, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        packed = jnp.pad(packed, ((0, pad_k), (0, pad_n)))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    if pad_n:
        w_scale = jnp.pad(w_scale, (0, pad_n))
    x_scale = jnp.reshape(jnp.asarray(x_scale, jnp.float32), (1,))

    Mp, Kp = x_q.shape
    Np = packed.shape[1]
    n_k = Kp // bk
    grid = (Mp // bm, Np // bn, n_k)
    if plane_mask is not None:
        assert plane_mask.shape == (n_bits, Kp // bk, Np // bn), plane_mask.shape
        mask = plane_mask
    else:
        mask = plane_block_mask(unpack_bitplanes_bytes(packed, n_bits), bk, bn)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, n_bits=n_bits, signed=signed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((n_bits, 1, 1), lambda m, n, k: (0, k, n)),
            pl.BlockSpec((1,), lambda m, n, k: (0,)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, packed, mask, x_scale, w_scale)
    return out[:M, :N]


@functools.partial(
    jax.jit, static_argnames=("n_bits", "bm", "bn", "bk2", "out_dtype",
                              "interpret", "signed")
)
def bitserial_matmul_a4(
    x_packed: jax.Array,  # [M, ceil(K/2)] uint8 nibble-packed activations
    planes: jax.Array,  # [K, N] uint8 byte-packed weight planes
    x_scale: jax.Array,  # scalar f32
    w_scale: jax.Array,  # [N] f32
    plane_mask: jax.Array | None = None,  # [n_bits, K/(2*bk2), N/bn] int8
    *,
    n_bits: int = 4,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk2: int = DEFAULT_BK // 2,
    out_dtype=jnp.float32,
    interpret: bool = True,
    signed: bool = True,  # False: unsigned nibbles + unsigned plane weights
) -> jax.Array:
    """W4A4 bit-serial GEMM with byte-packed *activations* and weights.

    ``x_packed`` comes from ref.pack_activation_nibbles (2 elements/byte);
    ``planes`` from ref.pack_bitplanes_bytes.  Each of the ``n_bits`` weight
    planes costs two MXU passes over half of K (even/odd nibble streams),
    so FLOPs scale with the plane count while both operand tiles move half
    the VMEM bytes of the W8A8 byte-packed kernel.
    """
    M, K2 = x_packed.shape
    K, N = planes.shape
    if K < 2 * K2:  # odd-K weights: pad the dangling row (nibble is zero)
        planes = jnp.pad(planes, ((0, 2 * K2 - K), (0, 0)))
    bm, bn, bk2 = min(bm, M), min(bn, N), min(bk2, K2)

    pad_m, pad_n, pad_k2 = (-M) % bm, (-N) % bn, (-K2) % bk2
    if pad_m or pad_k2:
        x_packed = jnp.pad(x_packed, ((0, pad_m), (0, pad_k2)))
    if pad_k2 or pad_n:
        planes = jnp.pad(planes, ((0, 2 * pad_k2), (0, pad_n)))
    w_scale = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32), (N,))
    if pad_n:
        w_scale = jnp.pad(w_scale, (0, pad_n))
    x_scale = jnp.reshape(jnp.asarray(x_scale, jnp.float32), (1,))

    Mp, K2p = x_packed.shape
    Np = planes.shape[1]
    n_k = K2p // bk2
    grid = (Mp // bm, Np // bn, n_k)
    if plane_mask is not None:
        assert plane_mask.shape == (n_bits, n_k, Np // bn), plane_mask.shape
        mask = plane_mask
    else:
        mask = plane_block_mask(unpack_bitplanes_bytes(planes, n_bits),
                                2 * bk2, bn)

    out = pl.pallas_call(
        functools.partial(_kernel_a4, n_k=n_k, n_bits=n_bits, signed=signed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk2), lambda m, n, k: (m, k)),
            pl.BlockSpec((2 * bk2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((n_bits, 1, 1), lambda m, n, k: (0, k, n)),
            pl.BlockSpec((1,), lambda m, n, k: (0,)),
            pl.BlockSpec((bn,), lambda m, n, k: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_packed, planes, mask, x_scale, w_scale)
    return out[:M, :N]
