from repro.data.synthetic import SyntheticLMDataset, DataIterator
