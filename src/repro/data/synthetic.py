"""Deterministic synthetic LM data pipeline — shard-aware, checkpointable.

Design constraints (the same ones a production loader must satisfy):

  * **Deterministic**: batch ``i`` is a pure function of (seed, i) — restart
    at step N reproduces the exact stream, on any host topology.
  * **Shard-aware**: each data-parallel host materializes only its slice of
    the global batch (``host_id``/``num_hosts``); the full array is formed
    with ``jax.make_array_from_process_local_data`` on multi-host, or
    directly on one host.
  * **Checkpointable**: iterator state is one integer (``next_index``);
    it rides inside the training checkpoint, so resume never replays or
    skips a batch.

The token stream is a mixture of Zipf-distributed unigrams and
repeated-motif spans, giving a non-trivial but learnable distribution (the
~100M-param example in examples/train_lm.py drops loss well below the
unigram entropy on it).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

__all__ = ["SyntheticLMDataset", "DataIterator"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.5

    def _rng(self, index: int, host: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, index, host]))

    def host_batch(self, index: int, host_id: int = 0,
                   num_hosts: int = 1) -> dict[str, np.ndarray]:
        """The (host-local) slice of global batch ``index``."""
        assert self.global_batch % num_hosts == 0
        b = self.global_batch // num_hosts
        rng = self._rng(index, host_id)
        v = self.vocab_size
        # Zipf unigrams (clipped to vocab)
        toks = rng.zipf(self.zipf_a, size=(b, self.seq_len + 1)).astype(np.int64)
        toks = (toks - 1) % max(v - 2, 1) + 2  # reserve 0=pad, 1=bos
        # overwrite random spans with repeated motifs (learnable structure)
        n_spans = max(1, self.seq_len // (4 * self.motif_len))
        for row in range(b):
            if rng.random() > self.motif_prob or self.seq_len <= self.motif_len:
                continue
            for _ in range(n_spans):
                start = int(rng.integers(0, self.seq_len - self.motif_len))
                motif = rng.integers(2, v, size=self.motif_len // 4)
                span = np.tile(motif, 4)[: self.motif_len]
                toks[row, start : start + self.motif_len] = span
        toks[:, 0] = 1  # bos
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def global_arrays(self, index: int, sharding=None):
        """Global-batch jax arrays for batch ``index`` (single-process)."""
        host = self.host_batch(index)
        if sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding) for k, v in host.items()}


@dataclasses.dataclass
class DataIterator:
    """Stateful wrapper whose state is checkpointable (one int)."""

    dataset: SyntheticLMDataset
    sharding: object = None
    next_index: int = 0

    def __next__(self):
        batch = self.dataset.global_arrays(self.next_index, self.sharding)
        self.next_index += 1
        return batch

    def __iter__(self):
        return self

    # -- checkpoint protocol --------------------------------------------------
    def state_dict(self) -> dict:
        return {"next_index": self.next_index}

    def load_state_dict(self, state: dict) -> None:
        self.next_index = int(state["next_index"])
