from repro.optim.adamw import AdamW, adamw, apply_updates, cosine_schedule
from repro.optim.compression import compress_gradients, error_feedback_update
