"""Gradient compression for cross-pod all-reduce (distributed-optimization).

int8 quantization with *error feedback* (Seide et al. / EF-SGD): the
quantization residual is carried into the next step, so compression bias
vanishes over time.  The compressed representation is what crosses the DCI
between pods — 4x fewer bytes than f32 on the slowest link.

Usage in the train loop:
    cg, new_ef = compress_gradients(grads, ef_state)    # before all-reduce
    grads = decompress(cg)                              # after all-reduce
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressedGrads", "compress_gradients", "decompress",
           "error_feedback_update", "ef_init"]

QBLOCK = 512


class CompressedGrads(NamedTuple):
    q: jax.Array  # int8 blocks
    scale: jax.Array  # f32 per-block


def _compress_leaf(g: jax.Array, ef: jax.Array):
    gf = g.astype(jnp.float32) + ef
    flat = gf.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), -1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    recon = (q.astype(jnp.float32) * scale).reshape(-1)
    n = gf.size
    new_ef = (gf.reshape(-1) - recon[:n]).reshape(g.shape)
    return CompressedGrads(q, scale.astype(jnp.float32)), new_ef


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, ef_state):
    """Returns (compressed tree, new error-feedback tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = jax.tree.leaves(ef_state)
    cs, efs = [], []
    for g, e in zip(leaves, ef_leaves):
        c, ne = _compress_leaf(g, e)
        cs.append(c)
        efs.append(ne)
    return jax.tree.unflatten(treedef, cs), jax.tree.unflatten(treedef, efs)


def decompress(compressed, shapes_like):
    def leaf(c, g):
        flat = (c.q.astype(jnp.float32) * c.scale).reshape(-1)
        return flat[: g.size].reshape(g.shape).astype(g.dtype)

    return jax.tree.map(
        leaf, compressed, shapes_like,
        is_leaf=lambda x: isinstance(x, CompressedGrads),
    )


def error_feedback_update(grads, ef_state):
    """One combined compress->decompress round (what a fused collective does);
    returns (effective grads, new ef state)."""
    comp, new_ef = compress_gradients(grads, ef_state)
    eff = decompress(comp, grads)
    return eff, new_ef
