"""AdamW — pure-JAX, sharding-transparent, with optional int8 moment state.

The quantized-moment option carries the paper's theme (8-bit everything,
requantize between steps) into the optimizer: m and v are stored as
block-wise int8 with per-block scales (bitsandbytes-style), cutting optimizer
HBM from 8 to ~2.03 bytes/param — the difference between arctic-480b fitting
a 16 GB/chip pod or not (see EXPERIMENTS.md §Dry-run).

State layout: moments are stored as flat tuples aligned with
``jax.tree.leaves(params)`` — no structure surgery, checkpoint/shard friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "adamw", "apply_updates", "cosine_schedule", "MomentState"]

def _q8_pack(x: jax.Array) -> "MomentState":
    """f32 -> int8 with per-channel (last-axis) f32 scales.

    Shape-preserving on purpose: a flatten-into-blocks layout (bitsandbytes
    style) reshapes across sharding boundaries and GSPMD responds by
    replicating the full f32 working copy — measured as 625 GB/device
    buffers on arctic-480b's stacked expert moments.  Per-channel absmax is
    elementwise+reduce only, so the quantized state and every optimizer
    intermediate inherit the parameter's sharding unchanged.
    """
    if x.ndim == 0:
        return MomentState(
            jnp.zeros((), jnp.int8), x.astype(jnp.float32)[None])
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return MomentState(q, scale.astype(jnp.float32))


def _q8_unpack(ms: "MomentState", shape) -> jax.Array:
    if len(shape) == 0:
        return ms.scale[0]
    return ms.q.astype(jnp.float32) * ms.scale


class MomentState(NamedTuple):
    q: jax.Array
    scale: jax.Array


def _moment_zero(p, quantized: bool):
    if quantized:
        return _q8_pack(jnp.zeros(p.shape, jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False

    def init(self, params) -> dict:
        leaves = jax.tree.leaves(params)
        return {
            "m": tuple(_moment_zero(p, self.quantize_moments) for p in leaves),
            "v": tuple(_moment_zero(p, self.quantize_moments) for p in leaves),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        count = state["count"] + 1
        lr = self.lr(count) if callable(self.lr) else self.lr

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)

        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in g_leaves))
            cscale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))
            g_leaves = [g * cscale.astype(g.dtype) for g in g_leaves]

        bc1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        updates, new_m, new_v = [], [], []
        for g, m, v, p in zip(g_leaves, state["m"], state["v"], p_leaves):
            g = g.astype(jnp.float32)
            mf = _q8_unpack(m, g.shape) if isinstance(m, MomentState) else m
            vf = _q8_unpack(v, g.shape) if isinstance(v, MomentState) else v
            mf = self.b1 * mf + (1 - self.b1) * g
            vf = self.b2 * vf + (1 - self.b2) * jnp.square(g)
            step = (mf / bc1) / (jnp.sqrt(vf / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            updates.append((-lr * step).astype(p.dtype))
            new_m.append(_q8_pack(mf) if isinstance(m, MomentState) else mf)
            new_v.append(_q8_pack(vf) if isinstance(v, MomentState) else vf)

        return (
            jax.tree.unflatten(treedef, updates),
            {"m": tuple(new_m), "v": tuple(new_v), "count": count},
        )


def adamw(**kw) -> AdamW:
    return AdamW(**kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(c < warmup, warm, cos)

    return sched
