"""Formal serving-engine API (PR 9) — the JetStream ``engine_api`` idiom.

One ``NCServingEngine`` is one cache slice-pool (a "socket", §VI-C);
production traffic needs N of them behind a router.  This module is the
contract between the two layers: anything that implements
:class:`Engine` can sit behind ``launch/orchestrator.py``'s global queue,
and everything the router steers by is part of the interface —

===================  ======================================================
member               routing meaning
===================  ======================================================
``submit/step``      enqueue a request / execute one admitted batch
``stats``            accounting snapshot (completed, failed, histogram, …)
``queue_depth``      requests already dispatched to (and owned by) the
                     engine but not yet executed
``latency_model``    the engine's OWN calibrated
                     :class:`~repro.core.slo.LatencyModel` — the router
                     reads ``predict_p99_s`` per candidate batch, so a
                     slow or mis-calibrated socket prices itself out
``batch_cap``        hard admission bound: engine ``max_batch`` and the
                     §VI-C ``stream_batch_limit``, whichever bites first
``ready_in``         seconds until the engine can start a new batch
                     (0.0 = free; synchronous engines are always free)
===================  ======================================================

Two implementations ship: ``serve.NCServingEngine`` (real bit-serial
emulation; synchronous, so ``ready_in`` is always 0) and
:class:`SimulatedEngine` below (fake-clock execution over the same priced
plans, for traffic replay and capacity planning at 10^5+ requests).
"""
from __future__ import annotations

import abc
import dataclasses

import numpy as np

from repro.core import slo as nc_slo

__all__ = ["Engine", "SimulatedEngine", "SimRequest"]


class Engine(abc.ABC):
    """Abstract serving engine the orchestrator routes batches to.

    Implementations must also carry a ``name`` (unique within a fleet), a
    ``latency_model`` attribute (:class:`~repro.core.slo.LatencyModel`),
    and the ``completed``/``failed`` request lists the orchestrator
    accounts from.  The request objects flowing through are duck-typed:
    ``arrival_t``, ``latency_s``, ``slo_ok``, ``done``, ``failed``
    (``serve.NCRequest`` and :class:`SimRequest` both qualify).
    """

    name: str

    @abc.abstractmethod
    def submit(self, req, now: float | None = None) -> None:
        """Enqueue one request, stamping ``req.arrival_t`` (pass ``now=``
        to preserve an arrival stamped by an upstream global queue)."""

    @abc.abstractmethod
    def step(self, now: float | None = None, *, flush: bool = False) -> bool:
        """Admit and execute one batch; False when nothing was admitted.
        ``flush=True`` disables any hold-for-arrivals behavior."""

    @abc.abstractmethod
    def stats(self) -> dict:
        """Accounting snapshot (steps, completed, failed, histogram, …)."""

    @property
    @abc.abstractmethod
    def queue_depth(self) -> int:
        """Requests owned by the engine but not yet executed."""

    @property
    @abc.abstractmethod
    def batch_cap(self) -> int:
        """Hard admission bound (engine limit ∧ stream_batch_limit)."""

    def ready_in(self, now: float) -> float:
        """Seconds until a new batch can start (0.0 = free now).
        Synchronous engines execute inside ``step()`` and are always
        free; fake-clock engines report their busy horizon."""
        return 0.0


@dataclasses.dataclass
class SimRequest:
    """Minimal request for fake-clock replay (duck-types ``NCRequest``'s
    accounting fields without carrying an image)."""

    rid: int
    arrival_t: float = 0.0
    latency_s: float | None = None
    slo_ok: bool | None = None
    done: bool = False
    failed: bool = False


class SimulatedEngine(Engine):
    """Fake-clock engine over the same priced plans a real socket serves.

    Admission, calibration and accounting run the REAL code paths — a
    :class:`~repro.core.slo.LatencyModel` over ``schedule_for`` and (with
    ``slo_ms``) a :class:`~repro.core.slo.AdmissionPolicy` — only
    *execution* is simulated: ``step()`` computes the batch wall as
    ``true_scale`` x modeled batch time (x a seeded, bounded jitter),
    marks the engine busy until ``now + wall`` and stamps completion at
    that future instant.  That makes 10^5+-request traffic replay a
    python-speed loop while every routing-relevant quantity (calibrated
    curve, queue depth, busy horizon) behaves like a live engine's.

    ``true_scale`` is the socket's real speed as a multiple of modeled
    hardware time; heterogeneous fleets combine different
    ``CacheGeometry`` plans (different modeled curves) with different
    scales.  The latency model *learns* the scale from the simulated
    walls exactly as it would from measured ones.
    """

    def __init__(self, name: str, schedule_for, *, max_batch: int = 4,
                 slo_ms: float | None = None,
                 hold_slack_ms: float | None = None,
                 true_scale: float = 1.0, jitter: float = 0.0,
                 seed: int = 0, const=None,
                 arrivals: nc_slo.ArrivalRateEstimator | None = None):
        self.name = name
        self.queue: list = []
        self.completed: list = []
        self.failed: list = []
        self.steps = 0
        self.max_batch = max_batch
        self.latency_model = nc_slo.LatencyModel(schedule_for, const=const)
        self.slo_s = slo_ms / 1e3 if slo_ms is not None else None
        self.policy = None
        if self.slo_s is not None:
            self.policy = nc_slo.AdmissionPolicy(
                self.latency_model, self.slo_s, max_batch,
                hold_slack_s=(hold_slack_ms / 1e3
                              if hold_slack_ms is not None else None),
                arrivals=arrivals)
        self.true_scale = float(true_scale)
        self.jitter = float(jitter)
        self._rng = np.random.default_rng(seed)
        self.busy_until = 0.0
        self.decisions: list = []
        self.batch_histogram: dict[int, int] = {}
        self.slo_hits = 0
        self.slo_misses = 0

    # -- Engine API ----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def batch_cap(self) -> int:
        if self.policy is not None:
            return self.policy.batch_cap
        return max(1, min(self.max_batch,
                          self.latency_model.stream_batch_limit))

    def ready_in(self, now: float) -> float:
        return max(0.0, self.busy_until - now)

    def submit(self, req, now: float | None = None) -> None:
        req.arrival_t = 0.0 if now is None else now
        self.queue.append(req)

    def step(self, now: float | None = None, *, flush: bool = False) -> bool:
        now = self.busy_until if now is None else now
        if not self.queue or now < self.busy_until:
            return False
        if self.policy is None:
            n = min(self.max_batch, len(self.queue))
        else:
            decision = self.policy.admit(
                len(self.queue), now - self.queue[0].arrival_t, flush=flush)
            self.decisions.append(decision)
            if decision.admit == 0:
                return False
            n = decision.admit
        batch = [self.queue.pop(0) for _ in range(n)]
        wall = self.true_scale * self.latency_model.modeled_batch_s(n)
        if self.jitter:
            wall *= 1.0 + self.jitter * float(self._rng.uniform(-1.0, 1.0))
        self.busy_until = now + wall
        # the simulated wall calibrates the model exactly like a measured
        # one — the router learns this socket's true speed from it
        self.latency_model.observe(n, wall)
        self.batch_histogram[n] = self.batch_histogram.get(n, 0) + 1
        for r in batch:
            r.latency_s = (now - r.arrival_t) + wall
            r.done = True
            if self.slo_s is not None:
                r.slo_ok = r.latency_s <= self.slo_s
                if r.slo_ok:
                    self.slo_hits += 1
                else:
                    self.slo_misses += 1
            self.completed.append(r)
        self.steps += 1
        return True

    def stats(self) -> dict:
        total = self.slo_hits + self.slo_misses
        return dict(
            steps=self.steps,
            completed=len(self.completed),
            failed=len(self.failed),
            batch_histogram=dict(sorted(self.batch_histogram.items())),
            slo_hits=self.slo_hits,
            slo_misses=self.slo_misses,
            slo_hit_rate=self.slo_hits / total if total else None,
            calibration_scale=self.latency_model.scale,
            calibration_samples=self.latency_model.samples,
            stream_batch_limit=self.latency_model.stream_batch_limit,
            busy_until=self.busy_until,
        )
