import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: run one (arch x shape x variant) cell and print
the roofline delta vs the stored baseline.

    python -m repro.launch.perf --arch olmo-1b --shape train_4k \
        --variant remat_none [--out results/perf]

Variants are implemented in repro.launch.steps.VARIANTS; the baseline JSON
is read from results/dryrun (run the sweep first).
"""
import argparse
import json
import pathlib
import sys
import time

import jax

from repro.configs import REGISTRY, get_config
from repro.configs.base import SHAPES
from repro.distributed.hlo_loop_analysis import analyze_hlo
from repro.distributed.roofline import TPU_V5E, roofline
from repro.distributed.hlo_analysis import CollectiveStats
from repro.launch.mesh import make_production_mesh, set_mesh_compat
from repro.launch.steps import VARIANTS, build_jitted_step


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = build_jitted_step(cfg, spec, mesh, variant=variant)
    with set_mesh_compat(mesh):
        compiled = bundle.step.lower(*bundle.example_args).compile()
    mem = compiled.memory_analysis()
    la = analyze_hlo(compiled.as_text())
    peak = None
    if mem is not None:
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0))
    coll = CollectiveStats(
        ops={k: int(v) for k, v in la.collective_ops.items()},
        operand_bytes={},
        wire_bytes={"total": la.collective_wire_bytes})
    rl = roofline(arch, shape_name, "pod16x16", mesh.devices.size,
                  {"flops": la.flops, "bytes accessed": la.bytes_accessed},
                  coll, cfg, spec, TPU_V5E, peak_memory=peak)
    return {"arch": arch, "shape": shape_name, "variant": variant,
            "ok": True, "compile_s": round(time.time() - t0, 1),
            "peak_bytes_per_device": peak, "roofline": rl.as_dict()}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--variant", required=True, choices=VARIANTS)
    ap.add_argument("--out", default="results/perf")
    ap.add_argument("--baseline-dir", default="results/dryrun")
    args = ap.parse_args()

    rec = run_variant(args.arch, args.shape, args.variant)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.variant}"
    (out / f"{tag}.json").write_text(json.dumps(rec, indent=1))

    base_path = (pathlib.Path(args.baseline_dir)
                 / f"{args.arch}__{args.shape}__single.json")
    rl = rec["roofline"]
    line = (f"{tag}: peak {rec['peak_bytes_per_device']/1e9:.2f} GB | "
            f"comp {rl['t_compute']:.4g}s mem {rl['t_memory']:.4g}s "
            f"coll {rl['t_collective']:.4g}s -> {rl['dominant']}")
    if base_path.exists():
        b = json.loads(base_path.read_text())["roofline"]
        for term in ("t_compute", "t_memory", "t_collective"):
            delta = (rl[term] - b[term]) / max(b[term], 1e-12) * 100
            line += f" | {term[2:]} {delta:+.1f}%"
    print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
