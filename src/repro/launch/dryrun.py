import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. assembles the jitted step with explicit in/out shardings,
  3. ``.lower(**ShapeDtypeStructs).compile()`` — no arrays are allocated,
  4. records ``memory_analysis()`` (proves it fits), ``cost_analysis()``
     (FLOPs/bytes for the roofline), and the collective schedule parsed from
     the optimized HLO,
  5. writes one JSON per cell under ``--out`` for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import REGISTRY, get_config, shapes_for
from repro.configs.base import SHAPES
from repro.distributed.hlo_analysis import (CollectiveStats, collective_bytes,
                                             xla_cost_analysis)
from repro.distributed.hlo_loop_analysis import analyze_hlo
from repro.distributed.roofline import TPU_V5E, roofline
from repro.launch.mesh import make_production_mesh, set_mesh_compat
from repro.launch.steps import build_jitted_step


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             xla_flags_extra: str = "") -> dict:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size

    t0 = time.time()
    bundle = build_jitted_step(cfg, spec, mesh)
    # set_mesh (not `with mesh:`) — activation sharding constraints inside
    # the model read the abstract-mesh context at trace time.
    with set_mesh_compat(mesh):
        lowered = bundle.step.lower(*bundle.example_args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_raw = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware: cost_analysis() charges every while body ONE iteration;
    # analyze_hlo multiplies by known_trip_count (scan-over-layers, flash
    # tiles, microbatches, loss chunks).  Validated in tests/test_hlo_analysis.
    la = analyze_hlo(hlo)
    cost = {"flops": la.flops, "bytes accessed": la.bytes_accessed}
    coll = CollectiveStats(
        ops={k: int(v) for k, v in la.collective_ops.items()},
        operand_bytes={},
        wire_bytes={"loop_aware_total": la.collective_wire_bytes},
    )

    peak = None
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = getattr(mem, k, None)
        args = mem_d.get("argument_size_in_bytes") or 0
        temp = mem_d.get("temp_size_in_bytes") or 0
        alias = mem_d.get("alias_size_in_bytes") or 0
        out = mem_d.get("output_size_in_bytes") or 0
        # peak live bytes: arguments + temps + non-aliased outputs
        peak = args + temp + max(out - alias, 0)

    rl = roofline(arch, shape_name, mesh_name, chips, cost, coll, cfg, spec,
                  TPU_V5E, peak_memory=peak)

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": bundle.kind,
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "peak_bytes_per_device": peak,
        "fits_hbm": (peak is not None and peak <= TPU_V5E.hbm_bytes),
        "cost_analysis": cost,
        "cost_analysis_raw_xla": {k: cost_raw.get(k) for k in
                                  ("flops", "bytes accessed",
                                   "transcendentals") if k in cost_raw},
        "loops": la.loops,
        "collectives": coll.as_dict(),
        "roofline": rl.as_dict(),
        "sharding_fallbacks": bundle.report.fallbacks,
    }


def cells(arch_filter=None, shape_filter=None):
    for arch, cfg in REGISTRY.items():
        if arch_filter and arch != arch_filter:
            continue
        for spec in shapes_for(cfg):
            if shape_filter and spec.name != shape_filter:
                continue
            yield arch, spec.name


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(REGISTRY) + [None])
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape in cells(args.arch, args.shape):
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = out / f"{tag}.json"
            if args.skip_existing and path.exists():
                ok = json.loads(path.read_text()).get("ok", False)
                if ok:
                    print(f"[skip] {tag}", flush=True)
                    continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, multi)
                rec["ok"] = True
                print(f"  ok: peak={rec['peak_bytes_per_device'] and rec['peak_bytes_per_device']/1e9:.2f} GB"
                      f" dominant={rec['roofline']['dominant']}"
                      f" compile={rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi else "single",
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"  FAIL: {type(e).__name__}: {str(e)[:200]}", flush=True)
            path.write_text(json.dumps(rec, indent=1))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
