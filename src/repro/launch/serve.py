"""Batched serving drivers: LM continuous-batching decode AND Neural Cache
batched image inference.

The LM serving loop implements the standard production pattern:

  * requests queue up; a scheduler packs up to ``max_batch`` active
    sequences into the fixed decode batch (padding inactive slots),
  * prefill runs per admitted request (chunked flash attention), its KV
    written into the slot's cache region,
  * one fused ``decode_step`` advances EVERY active slot one token per
    iteration (the decode_32k / long_500k dry-run shapes lower exactly this
    step),
  * finished sequences (eos or max_tokens) free their slot for the queue.

The Neural Cache path (:class:`NCServingEngine`) serves the paper's
workload the paper's way (§VI-C): admitted image requests form one batch
that streams through the reserved I/O way while the filters stay resident
— the engine plans a :class:`~repro.core.schedule.NetworkSchedule` once
per batch size and routes every admitted batch through
``models.inception.nc_forward(batch=N)`` (batch folded into the packed
lane axis, in-cache §IV-D min/max quantization, bucketed-jit engine).

With ``--slo-ms`` the engine turns SLO-aware (core/slo.py): a
:class:`~repro.core.slo.LatencyModel` built over the SAME per-batch-size
plan cache predicts ``latency(batch)`` from the simulator's modeled
cycles calibrated against measured batch wall times, and an
:class:`~repro.core.slo.AdmissionPolicy` picks the largest batch whose
predicted p99 fits the oldest queued request's remaining deadline budget
— never past ``NetworkSchedule.stream_batch_limit`` — admitting ragged
tails early when holding would blow the deadline.  Per-request latency,
the admitted-batch histogram and the SLO hit rate are tracked.

Weights can be served quantized (W8A8 via repro.quant) — the paper's
inference pipeline — with ``--quantize``.

Usage:
    python -m repro.launch.serve --arch olmo-1b --reduced --requests 12
    python -m repro.launch.serve --neural-cache --requests 8 --max-batch 4
    python -m repro.launch.serve --neural-cache --requests 8 --slo-ms 50
    python -m repro.launch.serve --neural-cache --requests 8 \
        --fault-profile seed=7,filter=0.05,stuck=3
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, get_config, reduced_config
from repro.launch.engine_api import Engine as _EngineAPI
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    failed: bool = False
    error: str | None = None


@dataclasses.dataclass
class Slot:
    active: bool = False
    req: Request | None = None
    pos: int = 0


class BatchQueueEngine:
    """Shared admission plumbing: a request queue drained by ``step()``.

    Failure contract (PR 7): an exception raised while executing one
    admitted batch fails ONLY that batch — its requests land in
    ``failed`` with the error string recorded, ``errors`` keeps the
    engine-level log, and the engine keeps draining the rest of the
    queue instead of unwinding ``run()``."""

    def __init__(self):
        self.queue = []
        self.completed = []
        self.failed = []
        self.errors: list[str] = []
        self.steps = 0

    def submit(self, req) -> None:
        self.queue.append(req)

    def _fail_requests(self, reqs, err: BaseException | str) -> None:
        """Mark ``reqs`` failed with the error recorded, engine-wide and
        per-request; they are terminal (never re-queued)."""
        msg = ((str(err) or type(err).__name__)
               if isinstance(err, BaseException) else str(err))
        self.errors.append(msg)
        for r in reqs:
            r.done = True
            r.failed = True
            r.error = msg
            self.failed.append(r)


class ServingEngine(BatchQueueEngine):
    """Fixed-batch continuous-batching engine over decode_step."""

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_len: int = 512, eos: int = -1):
        super().__init__()
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_len, self.eos = max_batch, max_len, eos
        self.caches = T.init_caches(cfg, max_batch, max_len)
        self.slots = [Slot() for _ in range(max_batch)]
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefill this slot: simple per-request prefill into row i.
            # A prefill failure fails only this request — the slot stays
            # free for the next queued one
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            try:
                logits, caches1 = T.prefill(self.cfg, self.params, toks,
                                            max_len=self.max_len)
            except Exception as e:  # noqa: BLE001 — batch-failure contract
                self._fail_requests([req], e)
                continue
            self.caches = _write_slot(self.caches, caches1, i)
            nxt = int(jnp.argmax(logits[0]))
            req.out.append(nxt)
            self.tokens = self.tokens.at[i, 0].set(nxt)
            slot.active, slot.req, slot.pos = True, req, len(req.prompt)

    # -- one engine tick -----------------------------------------------------
    def step(self) -> bool:
        self._admit()
        if not any(s.active for s in self.slots):
            return False
        # per-slot positions: slots admitted with different prompt lengths
        # decode — and write KV — each at its OWN position (decoding every
        # slot at max(pos) corrupted shorter sequences; PR 9 bugfix).
        # Inactive slots pass 0; their rows are ignored and overwritten by
        # the next admission's prefill
        pos = jnp.asarray([s.pos if s.active else 0 for s in self.slots],
                          jnp.int32)
        try:
            logits, self.caches = self._decode(self.params, self.tokens,
                                               self.caches, pos)
        except Exception as e:  # noqa: BLE001 — batch-failure contract
            # the fused decode advances every active slot at once, so a
            # mid-batch failure fails exactly the admitted batch (the
            # active slots); freed slots keep draining the queue
            active = [s.req for s in self.slots if s.active]
            self._fail_requests(active, e)
            for s in self.slots:
                if s.active:
                    s.active, s.req = False, None
            self.steps += 1
            return True
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        new_tokens = np.asarray(self.tokens).copy()
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            tok = int(nxt[i])
            slot.req.out.append(tok)
            new_tokens[i, 0] = tok
            slot.pos += 1
            if (tok == self.eos or len(slot.req.out) >= slot.req.max_tokens
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                self.completed.append(slot.req)
                slot.active, slot.req = False, None
        self.tokens = jnp.asarray(new_tokens)
        self.steps += 1
        return True

    def run(self) -> list[Request]:
        while self.queue or any(s.active for s in self.slots):
            self.step()
        return self.completed


def _write_slot(caches, caches1, i: int):
    """Copy a single-sequence prefill cache into batch row ``i``."""

    def leaf(c, c1):
        return c.at[:, i : i + 1].set(c1.astype(c.dtype))

    return jax.tree.map(leaf, caches, caches1)


# ---------------------------------------------------------------------------
# Neural Cache image serving (§VI-C batched streaming)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NCRequest:
    rid: int
    image: np.ndarray  # [H, W, 3] float32 in [0, 1]
    logits: np.ndarray | None = None
    done: bool = False
    failed: bool = False  # unrecoverable after the degradation ladder
    error: str | None = None
    degraded: str | None = None  # "fallback-schedule" | "float" when not primary
    # SLO accounting (stamped by the engine)
    arrival_t: float = 0.0  # engine-clock submit time
    latency_s: float | None = None  # queue wait + batch execution wall
    slo_ok: bool | None = None  # None when the engine has no SLO set


class NCServingEngine(BatchQueueEngine, _EngineAPI):
    """Batched Neural Cache inference server.

    Each ``step()`` admits up to ``max_batch`` queued images and executes
    them as ONE batched forward through the bit-serial emulation
    (``models.inception.nc_forward``): the batch folds into the packed
    lane axis, filters pack once per layer per batch, and quantization
    ranges come from the in-cache min/max tree — the serving half of the
    paper's 604 inf/s headline (§VI-C).  The per-layer tiling comes from a
    :class:`~repro.core.schedule.NetworkSchedule` planned once per batch
    size (ragged final batches plan-and-cache their own), so the mapper,
    the packed engine and the server all execute the same plan object.

    ``sparse=True`` (the default) plans against the deployed weights'
    detected value sparsity (``inception.network_occupancy``): serialized
    passes of all-zero (pruned) filters are dropped from every batch's
    schedule, with logits byte-identical to dense execution — a deployment
    serving an EIE-style pruned model gets the cycle and wall-time win for
    free.  Unpruned weights detect zero sparsity and plan exactly dense.

    ``overlap=True`` (the default) plans every batch size double-buffered
    (PR 6 / §IV-E): serialized passes whose next filter columns fit the
    reserved I/O way stream those columns under the previous pass's
    MAC+reduce, so ``simulator.batch_time_s`` — and therefore the
    ``LatencyModel`` below — prices the overlapped pipeline the engine
    actually executes.  ``overlap=False`` restores the PR 3/4 serial
    plans bit-for-bit.

    ``slo_ms`` arms the SLO-aware admission policy (core/slo.py): instead
    of greedy FIFO-up-to-``max_batch``, each ``step()`` asks the policy
    for the largest batch whose predicted p99 latency (from the
    :class:`~repro.core.slo.LatencyModel` sharing this engine's plan
    cache) fits the oldest queued request's remaining deadline budget,
    capped by ``min(max_batch, schedule.stream_batch_limit)``.  Shallow
    queues are *held* for more arrivals while slack remains and flushed
    early (``ragged-early``) when it runs out; ``run()`` drains with
    ``flush=True`` since no more arrivals are coming.  Execution is
    unchanged — admitted batches route through the same planned
    ``nc_forward``, so logits stay bit-identical to standalone runs
    whatever batch sizes the policy picks.

    ``compressed=True`` (PR 8) plans every batch size with CSR
    bit-plane filter residency (``plan_network(..., compressed=True)``):
    resident filters shrink to their live bit planes plus a per-plane
    live-column bitmap, the modeled time earns the exact residency
    credit, and — because a spilling layer's staged outputs stop
    occupying the reserved I/O way — ``schedule.stream_batch_limit``
    (the SLO policy's hard batch cap) can only rise.  Logits stay
    byte-identical to the dense store.

    ``warmup_replan=True`` (PR 8) treats the first successfully served
    batch as a measurement: its report's observed per-layer input
    sparsity and live output bytes replace the advisory ReLU-chain
    estimate (``inception.observed_occupancy``), every cached plan is
    rebuilt from the measured occupancy (requant passes shrink to the
    live output set), and the latency model drops its priced results so
    the calibration curve never mixes estimate-planned and
    measurement-planned predictions.  The warmup batch itself is
    excluded from calibration; logits are byte-identical throughout.

    ``integrity=True`` (PR 7) plans every batch size with ABFT checksum
    verification (``plan_network(..., integrity=True)``): corruption
    under an active ``core.faults`` scope is detected and re-executed
    inside the engine's forward, logits stay byte-identical, and the
    latency model prices the checksum passes.  Independent of the flag, a
    batch whose forward RAISES walks the recovery ladder (``_recover``):
    primary-schedule retries within the oldest request's remaining
    deadline budget, then a dense/no-overlap fallback schedule, then the
    float reference forward, then the batch is marked failed — the engine
    never strands queued requests.  Only primary successes (retries
    included, at their true total wall time) calibrate the
    :class:`~repro.core.slo.LatencyModel`; degraded batches are
    explicitly excluded (``LatencyModel.exclude``).

    The engine clock is injectable (``now_fn``; ``step``/``submit`` also
    take an explicit ``now``) so deadline behavior is testable without
    wall-clock sleeps.  Stats: ``batch_histogram`` (admitted batch size →
    count), ``slo_hits``/``slo_misses``/``slo_hit_rate``, ``decisions``
    (every :class:`~repro.core.slo.AdmissionDecision`), plus the
    fault/recovery ledger (``failed``/``errors``/``retries``/
    ``degraded_batches``/``calibration_excluded``).
    """

    def __init__(self, params, config=None, *, max_batch: int = 4,
                 geom=None, engine: str | None = None, sparse: bool = True,
                 overlap: bool = True, integrity: bool = False,
                 compressed: bool = False, warmup_replan: bool = False,
                 slo_ms: float | None = None,
                 hold_slack_ms: float | None = None, now_fn=time.monotonic,
                 name: str = "nc-engine"):
        from repro.core import schedule as nc_schedule
        from repro.core import slo as nc_slo
        from repro.core.cache_geometry import XEON_E5_35MB
        from repro.models import inception

        super().__init__()
        self.name = name
        self._inception = inception
        self._plan_network = nc_schedule.plan_network
        self.config = config or inception.REDUCED
        self.params = params
        self.max_batch = max_batch
        self.geom = geom or XEON_E5_35MB
        # validate the backend name up front (core/backends.py registry);
        # None defers to nc_forward's resolution (NC_BACKEND > batch size)
        if engine is not None:
            from repro.core import backends as nc_backends
            engine = nc_backends.get_backend(engine).name
        self.engine = engine
        self.now_fn = now_fn
        self.specs = inception.inception_v3_specs(self.config)
        # resident filters quantize ONCE per deployment, not once per batch;
        # the occupancy scan runs on the same resident weights
        self.wpack = inception.prepare_conv_weights(params, self.config)
        self.occupancy = (inception.network_occupancy(self.wpack, self.config)
                          if sparse else None)
        self.overlap = overlap
        self.integrity = integrity
        self.compressed = compressed
        self.warmup_replan = warmup_replan
        self._warmup_pending = bool(warmup_replan)
        self.warmup_replans = 0
        self.schedule = self._plan_network(self.specs, self.geom,
                                           batch=max_batch,
                                           occupancy=self.occupancy,
                                           overlap=self.overlap,
                                           integrity=self.integrity,
                                           compressed=self.compressed)
        self._schedules = {max_batch: self.schedule}
        self._fallback_schedules: dict = {}
        self.retries = 0  # primary re-attempts that succeeded or ran
        self.degraded_batches = 0  # batches served off the degradation ladder
        self.reports = []
        # SLO control loop: the latency model prices the SAME plan objects
        # this engine executes (shared _schedule_for cache)
        self.latency_model = nc_slo.LatencyModel(self._schedule_for)
        # EWMA inter-arrival estimator (PR 9): bounds the policy's hold —
        # a shallow queue is kept waiting only while the target batch is
        # expected to fill inside the remaining slack
        self.arrivals = nc_slo.ArrivalRateEstimator()
        self.slo_s = slo_ms / 1e3 if slo_ms is not None else None
        self.policy = None
        if self.slo_s is not None:
            self.policy = nc_slo.AdmissionPolicy(
                self.latency_model, self.slo_s, max_batch,
                hold_slack_s=(hold_slack_ms / 1e3
                              if hold_slack_ms is not None else None),
                arrivals=self.arrivals)
        self.decisions = []
        self.batch_histogram: dict[int, int] = {}
        self.slo_hits = 0
        self.slo_misses = 0

    def _schedule_for(self, n: int):
        if n not in self._schedules:
            self._schedules[n] = self._plan_network(self.specs, self.geom,
                                                    batch=n,
                                                    occupancy=self.occupancy,
                                                    overlap=self.overlap,
                                                    integrity=self.integrity,
                                                    compressed=self.compressed)
        return self._schedules[n]

    def _replan_from_report(self, report) -> None:
        """Warmup re-planning (PR 8): replace the advisory ReLU-chain
        occupancy estimate with what the warmup batch MEASURED —
        ``inception.observed_occupancy`` re-scans the resident filters and
        takes each conv's input sparsity and live output bytes from the
        report — then drop every cached plan and the latency model's
        priced results so subsequent batches plan, execute and are
        predicted from the measured occupancy.  The dense/serial fallback
        plans never depended on occupancy, so they stay."""
        self.occupancy = self._inception.observed_occupancy(
            self.wpack, self.config, report)
        self._schedules.clear()
        self.schedule = self._schedule_for(self.max_batch)
        self.latency_model.invalidate_plans()
        self.warmup_replans += 1

    def set_engine(self, engine: str | None) -> None:
        """Switch the execution backend (PR 10).  Validates the name
        against the registry, then resets the latency model's priced
        plans AND its measured calibration — wall-clock per modeled cycle
        is a property of the execution body, so a host-calibrated scale
        must not price jit or Pallas batches (see docs/SERVING.md)."""
        if engine is not None:
            from repro.core import backends as nc_backends
            engine = nc_backends.get_backend(engine).name
        if engine == self.engine:
            return
        self.engine = engine
        self.latency_model.invalidate_plans()
        self.latency_model.reset_calibration()

    def _fallback_schedule_for(self, n: int):
        """Degradation rung 2's plan: dense (no pruned passes), serial (no
        double buffering), uncompressed — the most conservative schedule
        the engine can execute, keeping any integrity checking the
        deployment asked for."""
        if n not in self._fallback_schedules:
            self._fallback_schedules[n] = self._plan_network(
                self.specs, self.geom, batch=n, occupancy=None,
                overlap=False, integrity=self.integrity)
        return self._fallback_schedules[n]

    def _forward(self, x: np.ndarray, schedule):
        """One batched forward through the planned emulation (the seam the
        recovery ladder — and fault tests — route every attempt through)."""
        return self._inception.nc_forward(
            self.params, x, config=self.config, geom=self.geom,
            engine=self.engine, schedule=schedule, wpack=self.wpack)

    def submit(self, req, now: float | None = None) -> None:
        req.arrival_t = self.now_fn() if now is None else now
        self.arrivals.observe(req.arrival_t)
        super().submit(req)

    def step(self, now: float | None = None, *, flush: bool = False) -> bool:
        """One engine tick: admit a batch (policy-sized under an SLO,
        greedy FIFO otherwise) and execute it.  Returns False when
        nothing was admitted — queue empty, or the policy is holding a
        shallow queue for more arrivals (``flush=True`` overrides the
        hold, not the SLO batch cap)."""
        if not self.queue:
            return False
        now = self.now_fn() if now is None else now
        if self.policy is None:
            n = min(self.max_batch, len(self.queue))
        else:
            decision = self.policy.admit(
                len(self.queue), now - self.queue[0].arrival_t, flush=flush)
            self.decisions.append(decision)
            if decision.admit == 0:
                return False
            n = decision.admit
        batch = [self.queue.pop(0) for _ in range(n)]
        x = np.stack([np.asarray(r.image, np.float32) for r in batch])
        t0 = time.perf_counter()
        try:
            logits, report = self._forward(x, self._schedule_for(len(batch)))
            degraded = None
        except Exception as e:  # noqa: BLE001 — recovery ladder below
            logits, report, degraded = self._recover(batch, x, now, e)
            if logits is None:
                # unreclaimable: the whole ladder failed — the batch is
                # marked failed with the error recorded, and the engine
                # keeps draining the rest of the queue.  The batch still
                # HAPPENED: its requests waited and its wall was burned, so
                # it lands in the histogram, its requests are stamped as
                # SLO misses, and the wall is routed through ``exclude``
                # (it executed no single plan the model prices) — without
                # this, slo_hit_rate overstates under faults and
                # calibration_excluded undercounts
                wall = time.perf_counter() - t0
                self.latency_model.exclude(n, wall)
                self.batch_histogram[n] = self.batch_histogram.get(n, 0) + 1
                for r in batch:
                    r.latency_s = (now - r.arrival_t) + wall
                    if self.slo_s is not None:
                        r.slo_ok = False
                        self.slo_misses += 1
                self.steps += 1
                return True
        wall = time.perf_counter() - t0
        if degraded is None:
            if self._warmup_pending and report is not None:
                # warmup batch: fold its MEASURED occupancy back into the
                # planner, then EXCLUDE it from calibration — it executed
                # (and was priced by) the retired estimate plan, and
                # observing it against the re-planned predictions would
                # seed the curve with a stale ratio
                self._warmup_pending = False
                self._replan_from_report(report)
                self.latency_model.exclude(len(batch), wall)
            else:
                # calibrate the latency model with the measured batch wall
                # time (retried batches fold their TRUE total wall in — the
                # retries are real latency the next admission must predict
                # around)
                self.latency_model.observe(len(batch), wall)
        else:
            # degraded batches did not execute the plan the model prices;
            # folding their wall time in would poison later predictions
            self.latency_model.exclude(len(batch), wall)
            self.degraded_batches += 1
        self.batch_histogram[n] = self.batch_histogram.get(n, 0) + 1
        for i, r in enumerate(batch):
            r.logits = np.asarray(logits[i])
            r.done = True
            r.degraded = degraded
            r.latency_s = (now - r.arrival_t) + wall
            if self.slo_s is not None:
                r.slo_ok = r.latency_s <= self.slo_s
                if r.slo_ok:
                    self.slo_hits += 1
                else:
                    self.slo_misses += 1
            self.completed.append(r)
        if report is not None:
            self.reports.append(report)
        self.steps += 1
        return True

    def _recover(self, batch, x, now: float, err: BaseException):
        """Degradation ladder for a failed batch (PR 7).

        1. Re-attempt the primary schedule while the oldest request's
           remaining deadline budget still covers a predicted execution
           (no SLO: one retry) — transient faults recover here.
        2. Dense/no-overlap fallback schedule — plan-shape trouble
           (quarantine storms, overlap/sparsity interactions) recovers
           here; the batch is excluded from calibration.
        3. Float reference forward — always numerically available; the
           result is no longer the emulation's logits, but the request is
           answered.
        4. Mark the batch failed (``stats()['errors']`` records why) and
           keep draining.

        Returns ``(logits, report, degraded_tag)``; logits None means
        rung 4."""
        n = len(batch)
        last = err
        # rung 1: bounded retries inside the deadline budget
        retries_left = 1
        if self.slo_s is not None:
            budget = self.slo_s - (now - batch[0].arrival_t)
            predicted = max(self.latency_model.predict_s(n), 1e-9)
            retries_left = max(0, int(budget / predicted) - 1)
        while retries_left > 0:
            retries_left -= 1
            self.retries += 1
            try:
                logits, report = self._forward(x, self._schedule_for(n))
                return logits, report, None
            except Exception as e:  # noqa: BLE001
                last = e
        # rung 2: most conservative emulated plan (dense, serial)
        try:
            logits, report = self._forward(x, self._fallback_schedule_for(n))
            return logits, report, "fallback-schedule"
        except Exception as e:  # noqa: BLE001
            last = e
        # rung 3: float reference — answers the request outside the emulation
        try:
            logits = np.asarray(self._inception.apply(
                self.params, jnp.asarray(x, jnp.float32), quant=False,
                config=self.config))
            return logits, None, "float"
        except Exception as e:  # noqa: BLE001
            last = e
        # rung 4: unreclaimable
        self._fail_requests(batch, last)
        return None, None, None

    @property
    def slo_hit_rate(self) -> float | None:
        total = self.slo_hits + self.slo_misses
        return self.slo_hits / total if total else None

    # -- Engine API (PR 9, launch/engine_api.py) -----------------------------
    @property
    def queue_depth(self) -> int:
        """Requests owned by this engine but not yet executed."""
        return len(self.queue)

    @property
    def batch_cap(self) -> int:
        """Hard admission bound: ``max_batch`` and the §VI-C streaming
        limit, whichever bites first (what the orchestrator may dispatch
        at once)."""
        if self.policy is not None:
            return self.policy.batch_cap
        return max(1, min(self.max_batch,
                          self.latency_model.stream_batch_limit))

    def stats(self) -> dict:
        """Serving stats: admitted-batch histogram, SLO accounting, the
        latency model's calibration state, and the fault/recovery ledger
        (failed requests, error log, retries, degraded batches and the
        calibration exclusions that kept the model honest)."""
        return dict(
            steps=self.steps,
            completed=len(self.completed),
            batch_histogram=dict(sorted(self.batch_histogram.items())),
            slo_ms=self.slo_s * 1e3 if self.slo_s is not None else None,
            slo_hits=self.slo_hits,
            slo_misses=self.slo_misses,
            slo_hit_rate=self.slo_hit_rate,
            calibration_scale=self.latency_model.scale,
            calibration_samples=self.latency_model.samples,
            calibration_excluded=self.latency_model.excluded,
            stream_batch_limit=self.schedule.stream_batch_limit,
            integrity=self.integrity,
            compressed=self.compressed,
            residency_credit_bytes=self.schedule.residency_credit_bytes,
            warmup_replans=self.warmup_replans,
            failed=len(self.failed),
            errors=list(self.errors),
            retries=self.retries,
            degraded_batches=self.degraded_batches,
        )

    def run(self) -> list[NCRequest]:
        # draining: no more arrivals are coming, so holding for a fuller
        # batch is pointless — flush, keeping the SLO batch cap
        while self.queue:
            self.step(flush=True)
        return self.completed


def _main_neural_cache(args) -> int:
    import contextlib

    from repro.core import faults
    from repro.core.simulator import simulate_network, throughput
    from repro.models import inception

    profile = (faults.FaultProfile.parse(args.fault_profile)
               if args.fault_profile else None)
    cfg = inception.reduced_config()
    params = inception.init_params(jax.random.key(0), config=cfg)
    engine = NCServingEngine(params, cfg, max_batch=args.max_batch,
                             overlap=not args.no_overlap,
                             integrity=profile is not None,
                             compressed=args.compressed,
                             warmup_replan=args.warmup_replan,
                             slo_ms=args.slo_ms)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        engine.submit(NCRequest(
            rid=r, image=rng.random((cfg.img, cfg.img, 3),
                                    dtype=np.float32)))
    scope = (faults.inject(profile) if profile is not None
             else contextlib.nullcontext())
    t0 = time.perf_counter()
    with scope as fs:
        done = engine.run()
    dt = time.perf_counter() - t0
    # modeled throughput from the engine's own schedule: filter load once
    # per batch + per-image marginal + spill (simulator.throughput), NOT
    # images / summed per-image latencies (which overstates by ~batch)
    res = simulate_network(engine.schedule)
    tp = throughput(res, args.max_batch, sockets=1)
    print(f"[serve-nc] {len(done)} images in {dt:.2f}s emulated "
          f"({len(done)/dt:.2f} img/s wall, {engine.steps} batches of "
          f"<= {args.max_batch}); modeled: {res.latency_s*1e3:.3f} ms/img "
          f"unbatched, {tp:.0f} inf/s at batch {args.max_batch} "
          f"(single socket)")
    if args.compressed or args.warmup_replan:
        s = engine.stats()
        print(f"[serve-nc] compressed residency: "
              f"{'on' if s['compressed'] else 'off'}, credit "
              f"{s['residency_credit_bytes']} B/batch, stream limit "
              f"{s['stream_batch_limit']}, warmup re-plans "
              f"{s['warmup_replans']}")
    if args.slo_ms is not None:
        s = engine.stats()
        print(f"[serve-nc] SLO {args.slo_ms:.0f} ms: hit rate "
              f"{s['slo_hit_rate']:.0%} ({s['slo_hits']} hit / "
              f"{s['slo_misses']} miss), admitted batches "
              f"{s['batch_histogram']}, stream limit "
              f"{s['stream_batch_limit']}, calibration x"
              f"{s['calibration_scale']:.1f} over "
              f"{s['calibration_samples']} batches")
    if profile is not None:
        s = engine.stats()
        fstats = fs.stats()
        print(f"[serve-nc] faults (seed {fstats['seed']}): "
              f"{fstats['injected']} injected, {fstats['detected']} "
              f"detected / {fstats['corrupt_attempts']} corrupt passes, "
              f"{fstats['reexecuted']} re-executed, quarantined slices "
              f"{list(fstats['quarantined_slices'])}; serving: "
              f"{s['retries']} batch retries, {s['degraded_batches']} "
              f"degraded, {s['failed']} failed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY))
    ap.add_argument("--neural-cache", action="store_true",
                    help="serve Inception images through the Neural Cache "
                         "emulation instead of an LM")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--no-overlap", action="store_true",
                    help="plan --neural-cache batches serial (no filter "
                         "streaming under MAC+reduce); default plans are "
                         "double-buffered per §IV-E headroom")
    ap.add_argument("--compressed", action="store_true",
                    help="plan --neural-cache batches with CSR bit-plane "
                         "filter residency (PR 8): smaller resident "
                         "footprint, exact modeled residency credit, and "
                         "a raised streaming batch ceiling; logits stay "
                         "byte-identical")
    ap.add_argument("--warmup-replan", action="store_true",
                    help="treat the first served --neural-cache batch as "
                         "a measurement: re-plan all batch sizes from its "
                         "observed per-layer sparsity and live outputs "
                         "instead of the ReLU-chain estimate")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request latency SLO for --neural-cache: "
                         "batches are sized by the predicted p99 from the "
                         "cycle model (core/slo.py) instead of greedy FIFO")
    ap.add_argument("--fault-profile", type=str, default=None,
                    help="seeded fault injection for --neural-cache, e.g. "
                         "'seed=7,filter=0.05,act=0.01,compute=0.01,"
                         "stuck=3,stall=0.1:0.002' (core/faults.py); "
                         "implies integrity checking, prints the "
                         "detection/recovery ledger")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.neural_cache:
        return _main_neural_cache(args)
    if args.arch is None:
        ap.error("--arch is required unless --neural-cache is given")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    rng = np.random.default_rng(0)
    params = T.init_lm(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_len=args.max_len)
    t0 = time.perf_counter()
    for r in range(args.requests):
        engine.submit(Request(
            rid=r,
            prompt=rng.integers(2, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_tokens=args.max_tokens))
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s, {engine.steps} engine "
          f"steps, batch {args.max_batch})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
