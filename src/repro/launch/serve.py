"""Batched serving driver: continuous-batching decode over a prefix cache.

The serving loop implements the standard production pattern:

  * requests queue up; a scheduler packs up to ``max_batch`` active
    sequences into the fixed decode batch (padding inactive slots),
  * prefill runs per admitted request (chunked flash attention), its KV
    written into the slot's cache region,
  * one fused ``decode_step`` advances EVERY active slot one token per
    iteration (the decode_32k / long_500k dry-run shapes lower exactly this
    step),
  * finished sequences (eos or max_tokens) free their slot for the queue.

Weights can be served quantized (W8A8 via repro.quant) — the paper's
inference pipeline — with ``--quantize``.

Usage:
    python -m repro.launch.serve --arch olmo-1b --reduced --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class Slot:
    active: bool = False
    req: Request | None = None
    pos: int = 0


class ServingEngine:
    """Fixed-batch continuous-batching engine over decode_step."""

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_len: int = 512, eos: int = -1):
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_len, self.eos = max_batch, max_len, eos
        self.caches = T.init_caches(cfg, max_batch, max_len)
        self.slots = [Slot() for _ in range(max_batch)]
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps = 0

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefill this slot: simple per-request prefill into row i
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches1 = T.prefill(self.cfg, self.params, toks,
                                        max_len=self.max_len)
            self.caches = _write_slot(self.caches, caches1, i)
            nxt = int(jnp.argmax(logits[0]))
            req.out.append(nxt)
            self.tokens = self.tokens.at[i, 0].set(nxt)
            slot.active, slot.req, slot.pos = True, req, len(req.prompt)

    # -- one engine tick -----------------------------------------------------
    def step(self) -> bool:
        self._admit()
        if not any(s.active for s in self.slots):
            return False
        pos = max(s.pos for s in self.slots if s.active)
        logits, self.caches = self._decode(self.params, self.tokens,
                                           self.caches, jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        new_tokens = np.asarray(self.tokens).copy()
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            tok = int(nxt[i])
            slot.req.out.append(tok)
            new_tokens[i, 0] = tok
            slot.pos += 1
            if (tok == self.eos or len(slot.req.out) >= slot.req.max_tokens
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                self.completed.append(slot.req)
                slot.active, slot.req = False, None
        self.tokens = jnp.asarray(new_tokens)
        self.steps += 1
        return True

    def run(self) -> list[Request]:
        while self.queue or any(s.active for s in self.slots):
            self.step()
        return self.completed


def _write_slot(caches, caches1, i: int):
    """Copy a single-sequence prefill cache into batch row ``i``."""

    def leaf(c, c1):
        return c.at[:, i : i + 1].set(c1.astype(c.dtype))

    return jax.tree.map(leaf, caches, caches1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    rng = np.random.default_rng(0)
    params = T.init_lm(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_len=args.max_len)
    t0 = time.perf_counter()
    for r in range(args.requests):
        engine.submit(Request(
            rid=r,
            prompt=rng.integers(2, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_tokens=args.max_tokens))
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s, {engine.steps} engine "
          f"steps, batch {args.max_batch})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
