"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run JSONs.

    python -m repro.launch.report [--results results/dryrun]
                                  [--out EXPERIMENTS.md]

EXPERIMENTS.md keeps hand-written sections; everything between
<!-- BEGIN AUTOGEN --> and <!-- END AUTOGEN --> is replaced.
"""
from __future__ import annotations

import argparse
import json
import pathlib

MARK_BEGIN = "<!-- BEGIN AUTOGEN (repro.launch.report) -->"
MARK_END = "<!-- END AUTOGEN -->"

_ADVICE = {
    "compute": "compute-bound: raise MXU utilization (larger per-chip tiles,"
               " fewer remat recomputes)",
    "memory": "HBM-bound: fuse epilogues / cut activation round-trips"
              " (quantized weights halve the stream)",
    "collective": "ICI-bound: overlap collectives with compute or reshard to"
                  " cut cross-chip traffic",
}


def _gb(x):
    return "-" if x is None else f"{x/1e9:.2f}"


def load(results: pathlib.Path):
    recs = []
    for p in sorted(results.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compiles | peak GB/dev | fits 16GB | "
        "GFLOPs/dev | HLO GB/dev | coll GB/dev (wire) | collective ops | "
        "compile s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** "
                f"| - | - | - | - | - | {r.get('error','')[:60]} | - |")
            continue
        rl = r["roofline"]
        ops = ", ".join(f"{k}x{v}" for k, v in
                        sorted(r["collectives"]["ops"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {_gb(r['peak_bytes_per_device'])} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} "
            f"| {rl['hlo_flops_per_device']/1e9:,.0f} "
            f"| {rl['hlo_bytes_per_device']/1e9:,.1f} "
            f"| {rl['collective_wire_bytes_per_device']/1e9:,.2f} "
            f"| {ops} | {r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | t_compute s | t_memory s | t_collective s |"
        " dominant | MODEL_FLOPS | useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            continue
        if r["mesh"] != "pod16x16":
            continue  # roofline table is single-pod per the assignment
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['t_compute']:.4g} | {rl['t_memory']:.4g} "
            f"| {rl['t_collective']:.4g} | **{rl['dominant']}** "
            f"| {rl['model_flops_total']:.3g} "
            f"| {rl['useful_flops_ratio']:.3f} "
            f"| {rl['roofline_fraction']:.3f} "
            f"| {_ADVICE[rl['dominant']]} |")
    return "\n".join(lines)


def summary(recs) -> str:
    ok = [r for r in recs if r.get("ok")]
    fails = [r for r in recs if not r.get("ok")]
    single = [r for r in ok if r["mesh"] == "pod16x16"]
    multi = [r for r in ok if r["mesh"] != "pod16x16"]
    fits = sum(1 for r in ok if r["fits_hbm"])
    dom = {}
    for r in single:
        d = r["roofline"]["dominant"]
        dom[d] = dom.get(d, 0) + 1
    return (
        f"- cells compiled: **{len(ok)}/{len(recs)}** "
        f"({len(single)} single-pod + {len(multi)} multi-pod; "
        f"{len(fails)} failures)\n"
        f"- fit in 16 GB/chip HBM: {fits}/{len(ok)} "
        f"(see notes on CPU-XLA artifacts below)\n"
        f"- dominant roofline term (single-pod): "
        + ", ".join(f"{k} x{v}" for k, v in sorted(dom.items())))


def render(results_dir: str) -> str:
    recs = load(pathlib.Path(results_dir))
    return "\n".join([
        MARK_BEGIN,
        "",
        "### Summary",
        "",
        summary(recs),
        "",
        "### §Dry-run — every (arch x shape) x both meshes",
        "",
        "Loop-corrected per-device numbers (`cost_analysis` charges scan"
        " bodies once; `hlo_loop_analysis` multiplies by trip counts;"
        " validated in tests/test_hlo_analysis.py).",
        "",
        dryrun_table(recs),
        "",
        "### §Roofline — three terms per cell (single-pod, 256 chips)",
        "",
        "Terms per the assignment: compute = FLOPs/(chips x 197 TF/s),"
        " memory = bytes/(chips x 819 GB/s), collective ="
        " wire-bytes/(chips x 50 GB/s); per-device quantities divided by"
        " per-chip rates are the same ratio. `useful ratio` ="
        " 6·N_active·D / total HLO FLOPs; `roofline frac` ="
        " t_compute / max(term) (1.0 = compute-bound).",
        "",
        roofline_table(recs),
        "",
        MARK_END,
    ])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    block = render(args.results)
    if out.exists() and MARK_BEGIN in out.read_text():
        text = out.read_text()
        pre = text.split(MARK_BEGIN)[0]
        post = text.split(MARK_END)[-1]
        out.write_text(pre + block + post)
    else:
        body = out.read_text() if out.exists() else ""
        out.write_text(body + "\n" + block + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
