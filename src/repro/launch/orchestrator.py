"""Multi-engine serving orchestrator (PR 9, ROADMAP open item 1).

The paper's throughput headline is per-socket (§VI-C: one 35 MB LLC =
604 inf/s) and its scaling story is more sockets.  This module is that
scaling story's serving half: N :class:`~repro.launch.engine_api.Engine`
sockets — possibly heterogeneous (different ``CacheGeometry``s, different
calibrated speeds) — behind ONE global request queue and a router that
picks **engine x batch jointly** to maximize the SLO hit rate.

Routing rule (``router="latency"``):

1. Engines with a backlog are drained first; only *free* engines
   (``ready_in == 0``, empty internal queue) are dispatch candidates.
2. For each free engine, bisect its OWN calibrated
   :class:`~repro.core.slo.LatencyModel` curve for the largest batch
   whose predicted p99 fits the oldest queued request's remaining
   budget (capped by ``batch_cap`` and the queue depth).
3. Pick the candidate maximizing ``(fits deadline, batch size, -p99)``:
   meet the deadline first, amortize the filter load over the biggest
   batch second, finish soonest third.
4. If NO free engine can meet the deadline but a busy one could after
   freeing (``ready_in + p99(1) <= budget``), hold and wait for it —
   the decision a latency-blind router cannot make.
5. A shallow queue is held for more arrivals only while slack remains
   AND the :class:`~repro.core.slo.ArrivalRateEstimator` expects the
   target batch to fill inside that slack (PR 5's open thread).

``router="round-robin"`` is the baseline foil: cycle over free engines,
greedy ``batch_cap`` batches, no holds — what you would deploy if
engines were interchangeable.  ``benchmarks/traffic_replay.py`` gates
that the latency router beats it on a heterogeneous fleet.

Requests keep their GLOBAL arrival stamp through dispatch
(``engine.submit(req, now=req.arrival_t)``), so per-request latency spans
orchestrator queue wait + engine execution, and logits stay bit-identical
to standalone ``nc_forward`` whichever engine serves a batch — the router
changes placement and batch sizes, never results.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

from repro.core import slo as nc_slo
from repro.launch.engine_api import Engine

__all__ = ["Orchestrator", "RouteDecision"]


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routing verdict (kept in ``Orchestrator.decisions``).

    ``engine`` is the chosen engine's name (None = no dispatch this
    tick); ``admit`` the batch popped from the global queue; ``target``
    the SLO-optimal batch for the chosen engine; ``budget_s`` the oldest
    request's remaining deadline budget (NaN with no SLO or empty
    queue); ``reason`` one of ``full`` / ``ragged-early`` / ``flush`` /
    ``greedy`` / ``floor`` (deadline already blown, dispatch the floor
    batch and record the miss) / ``hold`` (wait for arrivals) /
    ``wait-better`` (a busy engine will make the deadline, no free one
    will) / ``busy`` (no free engine) / ``round-robin``."""

    engine: str | None
    admit: int
    target: int
    budget_s: float
    reason: str


class Orchestrator:
    """Global queue + router over N :class:`Engine` sockets.

    ``engines`` need unique names.  ``slo_ms`` arms deadline routing and
    orchestrator-level SLO accounting (engines under an orchestrator are
    normally built WITHOUT their own ``slo_ms``: the orchestrator owns
    admission sizing and stamps ``slo_ok`` itself, so hits/misses are
    counted once, at the layer that owns the queue wait).  The clock is
    injectable (``now_fn`` + explicit ``now=``) exactly like the
    engines', so fleet behavior is testable on a fake clock.
    """

    def __init__(self, engines, *, slo_ms: float | None = None,
                 router: str = "latency",
                 hold_slack_ms: float | None = None,
                 now_fn=time.monotonic):
        engines = list(engines)
        if not engines:
            raise ValueError("orchestrator needs at least one engine")
        names = [e.name for e in engines]
        if len(set(names)) != len(names):
            raise ValueError(f"engine names must be unique, got {names}")
        if router not in ("latency", "round-robin"):
            raise ValueError(f"unknown router {router!r}")
        self.engines: list[Engine] = engines
        self.by_name = {e.name: e for e in engines}
        self.router = router
        self.slo_s = slo_ms / 1e3 if slo_ms is not None else None
        self.hold_slack_s = (hold_slack_ms / 1e3
                             if hold_slack_ms is not None
                             else (0.25 * self.slo_s) if self.slo_s else 0.0)
        self.now_fn = now_fn
        self.arrivals = nc_slo.ArrivalRateEstimator()
        # deque: traffic replay backlogs run thousands deep and pop from
        # the left once per dispatched request
        self.queue: collections.deque = collections.deque()
        self.completed: list = []
        self.failed: list = []
        self.decisions: list[RouteDecision] = []
        self.dispatched = {e.name: 0 for e in engines}  # batches routed
        self.slo_hits = 0
        self.slo_misses = 0
        self.steps = 0
        self._rr_next = 0
        self._acct = {e.name: (0, 0) for e in engines}

    # -- queue ---------------------------------------------------------------
    def submit(self, req, now: float | None = None) -> None:
        """Enqueue one request on the GLOBAL queue (arrival observed by
        the fleet-wide rate estimator)."""
        now = self.now_fn() if now is None else now
        req.arrival_t = now
        self.arrivals.observe(now)
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests not yet finished: global queue + engine backlogs."""
        return len(self.queue) + sum(e.queue_depth for e in self.engines)

    def next_event_s(self, now: float) -> float:
        """Earliest instant a busy engine frees (``now`` if none is busy)
        — the fake-clock driver's wait target."""
        waits = [e.ready_in(now) for e in self.engines]
        waits = [w for w in waits if w > 0.0]
        return now + min(waits) if waits else now

    # -- accounting ----------------------------------------------------------
    def _account(self, eng: Engine) -> None:
        """Fold requests the engine finished since the last tick into the
        orchestrator ledger, stamping ``slo_ok`` here — the engine has no
        SLO of its own, and the deadline spans the global queue wait."""
        c0, f0 = self._acct[eng.name]
        for r in eng.completed[c0:]:
            if self.slo_s is not None:
                r.slo_ok = (r.latency_s is not None
                            and r.latency_s <= self.slo_s)
                if r.slo_ok:
                    self.slo_hits += 1
                else:
                    self.slo_misses += 1
            self.completed.append(r)
        for r in eng.failed[f0:]:
            if self.slo_s is not None:
                r.slo_ok = False
                self.slo_misses += 1
            self.failed.append(r)
        self._acct[eng.name] = (len(eng.completed), len(eng.failed))

    # -- one orchestrator tick -----------------------------------------------
    def step(self, now: float | None = None, *, flush: bool = False) -> bool:
        """Drain engine backlogs, then route at most one batch from the
        global queue.  Returns False when nothing moved (queue empty,
        every engine busy, or the router is holding)."""
        now = self.now_fn() if now is None else now
        progressed = False
        # a previously dispatched batch an engine deferred or split is
        # drained before new placement — no stranded requests, ever
        for e in self.engines:
            if e.queue_depth > 0 and e.ready_in(now) <= 0.0:
                if e.step(now, flush=True):
                    progressed = True
                self._account(e)
        if not self.queue:
            return progressed
        if self.router == "latency":
            decision = self._route_latency(now, flush)
        else:
            decision = self._route_round_robin(now, flush)
        self.decisions.append(decision)
        if decision.engine is None or decision.admit <= 0:
            return progressed
        eng = self.by_name[decision.engine]
        batch = [self.queue.popleft() for _ in range(decision.admit)]
        for r in batch:
            # preserve the global arrival stamp: queue wait spans the
            # orchestrator queue, not just the engine's
            eng.submit(r, now=r.arrival_t)
        self.dispatched[decision.engine] += 1
        if eng.step(now, flush=True):
            progressed = True
        self._account(eng)
        self.steps += 1
        return progressed

    # -- routers -------------------------------------------------------------
    def _budget(self, now: float) -> float:
        if self.slo_s is None:
            return math.inf
        return self.slo_s - (now - self.queue[0].arrival_t)

    def _free(self, now: float) -> list[Engine]:
        return [e for e in self.engines
                if e.ready_in(now) <= 0.0 and e.queue_depth == 0]

    def _route_latency(self, now: float, flush: bool) -> RouteDecision:
        queued = len(self.queue)
        budget = self._budget(now)
        free = self._free(now)
        if not free:
            return RouteDecision(None, 0, 0,
                                 budget if math.isfinite(budget)
                                 else float("nan"), "busy")
        if math.isinf(budget):
            # no SLO: amortize the filter load over the biggest batch,
            # finish soonest on ties
            best = max(free, key=lambda e: (
                min(e.batch_cap, queued),
                -e.latency_model.predict_p99_s(min(e.batch_cap, queued))))
            n = min(best.batch_cap, queued)
            return RouteDecision(best.name, n, n, float("nan"), "greedy")
        clamped = max(budget, 0.0)
        best = None  # (fits, n, -p99, engine, target)
        for e in free:
            policy = nc_slo.AdmissionPolicy(e.latency_model, self.slo_s,
                                            e.batch_cap)
            target = policy.target_batch(clamped)
            n = min(target, queued)
            p99 = e.latency_model.predict_p99_s(n)
            key = (p99 <= budget, n, -p99)
            if best is None or key > best[0]:
                best = (key, e, n, target, p99)
        key, eng, n, target, p99 = best
        fits = key[0]
        if flush:
            return RouteDecision(eng.name, n, target, budget, "flush")
        if not fits:
            # every free engine misses the deadline — a busy engine that
            # would still make it after freeing is worth waiting for
            for o in self.engines:
                wait = o.ready_in(now)
                if (wait > 0.0 and
                        wait + o.latency_model.predict_p99_s(1) <= budget):
                    return RouteDecision(None, 0, target, budget,
                                         "wait-better")
            return RouteDecision(eng.name, n, target, budget, "floor")
        if queued >= target:
            return RouteDecision(eng.name, target, target, budget, "full")
        slack = budget - eng.latency_model.predict_p99_s(queued)
        if slack <= self.hold_slack_s:
            return RouteDecision(eng.name, queued, target, budget,
                                 "ragged-early")
        fill = self.arrivals.expected_fill_time_s(target - queued)
        if fill is not None and fill >= slack:
            return RouteDecision(eng.name, queued, target, budget,
                                 "ragged-early")
        return RouteDecision(None, 0, target, budget, "hold")

    def _route_round_robin(self, now: float, flush: bool) -> RouteDecision:
        budget = self._budget(now)
        budget = budget if math.isfinite(budget) else float("nan")
        free = set(id(e) for e in self._free(now))
        if not free:
            return RouteDecision(None, 0, 0, budget, "busy")
        for k in range(len(self.engines)):
            idx = (self._rr_next + k) % len(self.engines)
            e = self.engines[idx]
            if id(e) in free:
                self._rr_next = (idx + 1) % len(self.engines)
                n = min(e.batch_cap, len(self.queue))
                return RouteDecision(e.name, n, n, budget, "round-robin")
        return RouteDecision(None, 0, 0, budget, "busy")

    # -- draining ------------------------------------------------------------
    def run(self):
        """Drain everything with ``flush=True`` (no more arrivals are
        coming): every submitted request ends in ``completed`` or
        ``failed`` — none stranded in the global queue or any engine.
        Synchronous fleets drain in one pass; fake-clock fleets busy-wait
        ``now_fn`` up to the next engine-free instant."""
        frozen = 0
        last_now = None
        while self.pending:
            now = self.now_fn()
            if self.step(now=now, flush=True):
                frozen = 0
            elif last_now is not None and now <= last_now:
                frozen += 1
                if frozen > 100_000:
                    raise RuntimeError(
                        "orchestrator stalled: engines busy but the clock "
                        "never advances — fake-clock fleets must drive "
                        "step(now=...) from their own event loop")
            last_now = now
        return self.completed

    def stats(self) -> dict:
        """Fleet snapshot: orchestrator-level accounting + per-engine
        stats under their names."""
        total = self.slo_hits + self.slo_misses
        hist: dict[int, int] = {}
        for e in self.engines:
            for n, c in getattr(e, "batch_histogram", {}).items():
                hist[n] = hist.get(n, 0) + c
        return dict(
            router=self.router,
            steps=self.steps,
            queue_depth=len(self.queue),
            completed=len(self.completed),
            failed=len(self.failed),
            slo_ms=self.slo_s * 1e3 if self.slo_s is not None else None,
            slo_hits=self.slo_hits,
            slo_misses=self.slo_misses,
            slo_hit_rate=self.slo_hits / total if total else None,
            batch_histogram=dict(sorted(hist.items())),
            dispatched=dict(self.dispatched),
            arrival_rate_hz=self.arrivals.rate_hz,
            engines={e.name: e.stats() for e in self.engines},
        )
