"""Step builders: jitted, sharded train / prefill / decode steps per config.

These are the functions the launcher runs and the dry-run lowers.  Inputs
come from :func:`input_specs` as ShapeDtypeStructs (weak-type-correct, no
allocation), so ``jax.jit(...).lower(...)`` works without materializing a
480-billion-parameter model.

Shape kinds map to entry points (per the assignment):
    train_4k    -> train_step   (loss + grads + AdamW update)
    prefill_32k -> prefill_step (prompt pass, returns last logits + caches)
    decode_32k / long_500k -> decode_step (one token, KV/state cache in+out)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import (
    ShardingReport,
    make_batch_sharding,
    make_cache_shardings,
    make_param_shardings,
)
from repro.models import transformer as T
from repro.optim.adamw import AdamW, MomentState, apply_updates, cosine_schedule

__all__ = [
    "input_specs", "abstract_params", "make_optimizer", "abstract_opt_state",
    "make_train_step", "make_prefill_step", "make_decode_step",
    "build_jitted_step", "StepBundle",
]


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins, no device allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Model inputs for one (arch x shape) cell.

    ``[vlm]`` archs take precomputed patch embeddings (the modality frontend
    is a stub per the assignment); everything else takes token ids.
    Decode kinds take a [B, 1] token and the scalar cache position; their
    caches are produced by :func:`abstract_caches`.
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.frontend == "vision_patch":
            return {"embeds": sds((B, S, cfg.d_model), cfg.jdtype),
                    "labels": sds((B, S), jnp.int32)}
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.frontend == "vision_patch":
            return {"embeds": sds((B, S, cfg.d_model), cfg.jdtype)}
        return {"tokens": sds((B, S), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sds((B, 1), jnp.int32),
            "pos": sds((), jnp.int32)}


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_lm(cfg, jax.random.key(0)))


def abstract_caches(cfg: ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def make_optimizer(cfg: ModelConfig, *, lr: float = 3e-4, warmup: int = 200,
                   total: int = 10_000) -> AdamW:
    """AdamW with int8 moments for models whose f32 moments would not fit
    16 GB/chip at 256-way sharding (the paper's 8-bit theme, applied to the
    optimizer)."""
    quantize = cfg.param_count() * 8 / 256 > 6e9  # m+v bytes per chip
    return AdamW(lr=cosine_schedule(lr, warmup, total),
                 quantize_moments=quantize)


def abstract_opt_state(optimizer: AdamW, params):
    return jax.eval_shape(optimizer.init, params)


def _opt_state_shardings(optimizer: AdamW, params_sh, opt_state, mesh: Mesh):
    """Moment shardings: mirror the param sharding; quantized moments are
    flat int8 blocks -> shard the block axis over EVERY mesh axis that
    divides it (the unpacked f32 working copy inherits this sharding, so it
    must match the params' total shard count or the update step balloons)."""
    p_leaves = jax.tree.leaves(
        params_sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def moment(ms, psh):
        if isinstance(ms, MomentState):
            # q is shape-preserving -> shard exactly like the param;
            # the per-channel scale drops the last dim's sharding.
            spec = tuple(psh.spec) + (None,) * (len(ms.q.shape)
                                                - len(psh.spec))
            sspec = (spec[:-1] + (None,)) if len(ms.scale.shape) else ()
            return MomentState(
                NamedSharding(mesh, P(*spec)),
                NamedSharding(mesh, P(*sspec)),
            )
        return psh

    def tup(key):
        return tuple(moment(ms, psh)
                     for ms, psh in zip(opt_state[key], p_leaves))

    return {"m": tup("m"), "v": tup("v"),
            "count": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# step functions (pure; closed over cfg)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, optimizer: AdamW,
                    n_microbatches: int = 1, grad_specs=None):
    """Loss + grad + AdamW update.  ``n_microbatches > 1`` scans over
    microbatches accumulating grads in f32 (sharded like the params), so the
    live activation set shrinks by the microbatch factor at the cost of one
    scan — standard gradient accumulation.

    ``grad_specs`` (tree of PartitionSpecs matching params) constrains each
    microbatch's gradients to the parameter sharding *inside* the scan, so
    GSPMD folds the cross-shard reduction into a reduce-scatter against the
    sharded accumulator instead of a full all-reduce of every dW per layer
    per microbatch (§Perf cell B: halves the wire bytes and shrinks the
    accumulation buffer by the shard count)."""

    def loss_fn(p, mb):
        return T.lm_loss(cfg, p, mb.get("tokens"), mb["labels"],
                         embeds=mb.get("embeds"))

    def _constrain_grads(g):
        if grad_specs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_specs)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_microbatches,
                                     x.shape[0] // n_microbatches)
                                    + x.shape[1:]),
                batch)
            g0 = _constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

            def acc(carry, mb):
                tot, g = carry
                l, gi = jax.value_and_grad(loss_fn)(params, mb)
                gi = _constrain_grads(gi)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g, gi)
                g = _constrain_grads(g)
                return (tot + l, g), None

            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0), g0), mbs)
            scale = 1.0 / n_microbatches
            loss = loss * scale
            grads = jax.tree.map(lambda g: g * scale, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                         budget_bytes: float = 2.5e9) -> int:
    """Smallest power-of-two microbatch count whose saved-activation set
    fits the budget.  Saved bytes/layer/local-token under the remat policy:
      full -> d (the scan carry);  dots -> d + qkv/o projections + ff outs
    (ff outs are model-sharded in tp mode).

    The budget is deliberately conservative: XLA:CPU's float normalization
    promotes bf16 loop-carried residual stacks to f32 (no native bf16 on
    CPU), so the dry-run pays ~3x the bf16 activation bytes a TPU compile
    would.  Documented in DESIGN.md §Hardware-adaptation."""
    if shape.kind != "train":
        return 1
    from repro.distributed.sharding import plan_parallelism
    mode = plan_parallelism(cfg)
    n_batch_shards = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = (("pod", "data") if mode == "tp" else ("pod", "data", "model"))
    b = shape.global_batch
    for a in axes:
        n = sizes.get(a, 1)
        if b % n == 0:
            n_batch_shards *= n
            b //= n
    tok_loc = shape.global_batch * shape.seq_len / n_batch_shards
    if (mode == "tp" and shape.seq_len % sizes.get("model", 1) == 0):
        tok_loc /= sizes.get("model", 1)  # sequence parallelism (see _act_spec)
    d = cfg.d_model
    policy = "full" if cfg.param_count() > 10e9 else "dots"
    if policy == "full":
        per_tok = d
    else:
        ff_eff = (cfg.d_ff // sizes.get("model", 1)) if mode == "tp" else cfg.d_ff
        attn = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd if cfg.has_attention else 0
        ssm = 3 * cfg.d_inner if cfg.has_ssm else 0
        moe_ff = 0 if cfg.is_moe else 2 * ff_eff  # expert dots are batched -> recomputed
        per_tok = 2 * d + attn + ssm + moe_ff
    act = cfg.n_layers * tok_loc * per_tok * 2  # bf16
    # each microbatch's *global* batch must still divide the batch shards
    mb_cap = max(shape.global_batch // n_batch_shards, 1)
    mb = 1
    while act / mb > budget_bytes and mb < mb_cap:
        mb *= 2
    while shape.global_batch % mb != 0 and mb < mb_cap:
        mb *= 2
    return min(mb, mb_cap)


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch.get("tokens"),
                         embeds=batch.get("embeds"), max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, caches, batch):
        logits, caches = T.decode_step(cfg, params, batch["tokens"], caches,
                                       batch["pos"])
        return logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# jit assembly with explicit in/out shardings
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepBundle:
    """Everything the launcher / dry-run needs for one (arch x shape) cell."""
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Mesh
    step: Any            # jitted function
    example_args: tuple  # ShapeDtypeStructs to .lower(*example_args)
    report: ShardingReport
    kind: str


def _dryrun_cfg(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Remat policy for lowering: big models full-remat their scan body,
    small ones only save dots — same knob a production run would set."""
    if shape.kind != "train" or cfg.remat != "none":
        return cfg
    policy = "full" if cfg.param_count() > 10e9 else "dots"
    return dataclasses.replace(cfg, remat=policy)


def _act_spec(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
              tok_spec) -> tuple:
    """(batch_axes, seq_axes, vocab_axis) for activation constraints.

    TP mode adds Megatron-style sequence parallelism: between blocks the
    residual stream is sharded over ``model`` on the *sequence* dim, so the
    per-device saved-activation stack shrinks by the TP degree.  (Without
    it, batch microbatching alone bottoms out at B/batch_shards and a 110B
    train step carries an 86 GB residual stack.)
    """
    from repro.distributed.sharding import plan_parallelism
    b, s = tok_spec[0], (tok_spec[1] if len(tok_spec) > 1 else None)
    used = set(b) if isinstance(b, tuple) else ({b} if b else set())
    used |= set(s) if isinstance(s, tuple) else ({s} if s else set())
    if (s is None and shape.kind in ("train", "prefill")
            and plan_parallelism(cfg) == "tp" and "model" not in used
            and shape.seq_len % _ax(mesh, "model") == 0):
        s = "model"
        used.add("model")
    v = "model" if ("model" not in used
                    and cfg.vocab_size % _ax(mesh, "model") == 0) else None
    return (b, s, v)


VARIANTS = ("baseline", "remat_none", "remat_dots", "ep_resident",
            "w8_weights", "kv8", "w8kv8", "no_seqpar", "mb_half",
            "logits_bf16", "grad_shard", "loss_vtp", "loss_vtp_mb_half",
            "sp_gather", "combo_tp", "combo_tp_mb8")


def build_jitted_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                      *, donate: bool = True,
                      variant: str = "baseline") -> StepBundle:
    """``variant`` selects one §Perf hillclimb change (see VARIANTS):

      remat_none / remat_dots — force the activation-checkpoint policy,
      ep_resident  — expert weights sharded on E only (no ZeRO-3 on d/ff:
                     weights stay resident, activations do the moving —
                     the paper's weight-stationary insight),
      w8_weights   — int8 weight-only serving (weights stream at half the
                     bytes; dequant fused at use — the paper's pipeline),
      no_seqpar    — disable Megatron sequence parallelism (ablation),
      mb_half      — half the auto-chosen microbatch count (ablation),
      logits_bf16  — keep the loss logits in bf16 (halve loss-chunk bytes).
    """
    assert variant in VARIANTS, variant
    cfg = _dryrun_cfg(cfg, shape)
    if variant == "remat_none":
        cfg = dataclasses.replace(cfg, remat="none")
    elif variant == "remat_dots":
        cfg = dataclasses.replace(cfg, remat="dots")
    elif variant == "logits_bf16":
        cfg = dataclasses.replace(cfg, loss_dtype="bfloat16")
    elif variant in ("kv8", "w8kv8") and shape.kind != "train":
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    elif variant in ("loss_vtp", "loss_vtp_mb_half"):
        cfg = dataclasses.replace(cfg, loss_vocab_tp=True)
    elif variant == "sp_gather":
        cfg = dataclasses.replace(cfg, megatron_sp=True)
    elif variant in ("combo_tp", "combo_tp_mb8"):  # sp_gather + loss_vtp
        cfg = dataclasses.replace(cfg, megatron_sp=True, loss_vocab_tp=True)
    report = ShardingReport()
    batch = input_specs(cfg, shape)
    batch_sh = {}
    tok_sh = make_batch_sharding(cfg, mesh, shape, report)
    aspec = _act_spec(cfg, shape, mesh, tuple(tok_sh.spec))
    if variant == "no_seqpar":
        aspec = (aspec[0], None, aspec[2])
    cfg = dataclasses.replace(cfg, act_spec=aspec)
    params = abstract_params(cfg)
    params_sh = make_param_shardings(cfg, mesh, params, report)
    if variant == "ep_resident":
        params_sh = _ep_resident_shardings(params_sh, mesh)
    for k, v in batch.items():
        if k in ("tokens", "labels"):
            batch_sh[k] = tok_sh
        elif k == "embeds":
            batch_sh[k] = NamedSharding(mesh, P(*tok_sh.spec, None))
        else:  # pos scalar
            batch_sh[k] = NamedSharding(mesh, P())
    repl = NamedSharding(mesh, P())

    if variant in ("w8_weights", "w8kv8") and shape.kind != "train":
        params, params_sh = _quantized_abstract_params(cfg, mesh, params_sh)

    if shape.kind == "train":
        optimizer = make_optimizer(cfg)
        opt_state = abstract_opt_state(optimizer, params)
        opt_sh = _opt_state_shardings(optimizer, params_sh, opt_state, mesh)
        n_mb = default_microbatches(cfg, shape, mesh)
        if variant in ("mb_half", "loss_vtp_mb_half", "combo_tp_mb8"):
            n_mb = max(1, n_mb // 2)
        if n_mb > 1:
            report.fallbacks.append(f"gradient accumulation: {n_mb} microbatches")
        gspecs = None
        if variant == "grad_shard":
            gspecs = jax.tree.map(lambda s: s.spec, params_sh,
                                  is_leaf=lambda x: isinstance(x, NamedSharding))
        step = jax.jit(
            make_train_step(cfg, optimizer, n_mb, grad_specs=gspecs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh,
                           {"loss": repl, "grad_norm": repl}),
            donate_argnums=(0, 1) if donate else (),
        )
        args = (params, opt_state, batch)
    elif shape.kind == "prefill":
        caches = abstract_caches(cfg, shape)
        caches_sh = make_cache_shardings(cfg, mesh, shape, caches, report)
        logits_sh = NamedSharding(
            mesh, P(tok_sh.spec[0],
                    "model" if cfg.vocab_size % _ax(mesh, "model") == 0
                    else None))
        prefill_fn = make_prefill_step(cfg)
        if variant in ("w8_weights", "w8kv8"):
            inner_p = prefill_fn
            prefill_fn = lambda p, b: inner_p(_dequant_tree(p, cfg.jdtype), b)
        step = jax.jit(
            prefill_fn,
            in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, caches_sh),
        )
        args = (params, batch)
    else:  # decode
        caches = abstract_caches(cfg, shape)
        caches_sh = make_cache_shardings(cfg, mesh, shape, caches, report)
        logits_sh = NamedSharding(
            mesh, P(make_batch_sharding(cfg, mesh, shape).spec[0],
                    "model" if cfg.vocab_size % _ax(mesh, "model") == 0
                    else None))
        decode_fn = make_decode_step(cfg)
        if variant in ("w8_weights", "w8kv8"):
            inner_d = decode_fn
            decode_fn = lambda p, c, b: inner_d(_dequant_tree(p, cfg.jdtype),
                                                c, b)
        step = jax.jit(
            decode_fn,
            in_shardings=(params_sh, caches_sh, batch_sh),
            out_shardings=(logits_sh, caches_sh),
            donate_argnums=(1,) if donate else (),
        )
        args = (params, caches, batch)

    return StepBundle(cfg=cfg, shape=shape, mesh=mesh, step=step,
                      example_args=args, report=report, kind=shape.kind)


def _ax(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


# ---------------------------------------------------------------------------
# §Perf variant helpers
# ---------------------------------------------------------------------------
def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and "q" in x and "scale" in x


def _dequant_tree(params_q, dtype):
    """{'q': int8, 'scale': f32} leaves -> dense weights (fused at use)."""

    def leaf(x):
        if _is_qleaf(x):
            scale = x["scale"]
            if scale.ndim == 1:
                scale = scale[None, :]
            return (x["q"].astype(dtype) * scale.astype(dtype))
        return x

    return jax.tree.map(leaf, params_q, is_leaf=_is_qleaf)


def _quantized_abstract_params(cfg: ModelConfig, mesh: Mesh, params_sh):
    """Abstract int8 weight tree + matching shardings (w8_weights variant)."""
    from repro.quant import quantize_lm_params

    qparams = jax.eval_shape(
        lambda: quantize_lm_params(T.init_lm(cfg, jax.random.key(0))))

    def shard(qx, psh):
        if not _is_qleaf(qx):
            return psh
        spec = tuple(psh.spec)
        # scales are per-channel over the whole stack (leading dims of 1):
        # replicate — they're O(channels) bytes.
        sspec = (None,) * qx["scale"].ndim
        return {"q": NamedSharding(mesh, P(*spec)),
                "scale": NamedSharding(mesh, P(*sspec))}

    qsh = jax.tree.map(shard, qparams, params_sh,
                       is_leaf=lambda x: _is_qleaf(x)
                       or isinstance(x, NamedSharding))
    return qparams, qsh


def _ep_resident_shardings(params_sh, mesh: Mesh):
    """Expert weights sharded on E only (weight-stationary EP)."""

    def leaf(path, sh):
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if len(parts) >= 2 and parts[-2] == "moe" and \
                parts[-1] in ("wi", "wg", "wo"):
            spec = list(sh.spec)
            nd = len(spec)
            new = [None] * nd
            new[nd - 3] = spec[nd - 3]  # keep the expert axis only
            return NamedSharding(mesh, P(*new))
        return sh

    return jax.tree_util.tree_map_with_path(
        leaf, params_sh, is_leaf=lambda x: isinstance(x, NamedSharding))
