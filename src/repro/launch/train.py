"""Fault-tolerant training driver.

Production posture (scaled down to whatever devices exist — the same loop
runs on the CPU container and on a 512-chip fleet because every
device-dependent choice lives in mesh/sharding builders):

  * **checkpoint/restart**: atomic+async checkpoints every ``--ckpt-every``
    steps including optimizer and data-iterator state; on start, the newest
    complete checkpoint is restored (elastic: onto whatever mesh exists).
  * **preemption**: SIGTERM/SIGINT trigger a synchronous final checkpoint
    before exit (the SLURM/Borg eviction contract).
  * **straggler watchdog**: per-step wall time is tracked against an EWMA;
    steps slower than ``watchdog_factor`` x EWMA are logged with the step
    index — on real fleets this feeds the controller that evicts the slow
    host (here it is surfaced in the metrics stream).
  * **NaN handling**: non-finite loss skips the update (the params/opt
    donation makes this a re-materialization, so we fold it into the next
    step's metrics rather than halting the fleet).

Usage:
    python -m repro.launch.train --arch olmo-1b --steps 200 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import REGISTRY, get_config, reduced_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.data import DataIterator, SyntheticLMDataset
from repro.distributed.sharding import (
    make_batch_sharding, make_param_shardings, ShardingReport)
from repro.launch import steps as S
from repro.launch.mesh import make_local_mesh, set_mesh_compat
from repro.models import transformer as T


@dataclasses.dataclass
class TrainState:
    params: object
    opt_state: object
    step: int = 0


class Watchdog:
    """EWMA straggler detector."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor, self.alpha, self.ewma = factor, alpha, None
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.factor * self.ewma
        if slow:
            self.flagged.append(step)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def train(cfg, shape: ShapeSpec, *, steps: int, ckpt_dir: str | None,
          ckpt_every: int = 50, mesh=None, seed: int = 0,
          log_every: int = 10, watchdog_factor: float = 3.0):
    mesh = mesh or make_local_mesh()
    report = ShardingReport()
    tok_sh = make_batch_sharding(cfg, mesh, shape, report)
    cfg = dataclasses.replace(
        cfg, act_spec=S._act_spec(cfg, shape, mesh, tuple(tok_sh.spec)))
    optimizer = S.make_optimizer(cfg, total=steps)
    n_mb = S.default_microbatches(cfg, shape, mesh)
    step_fn = jax.jit(
        S.make_train_step(cfg, optimizer, n_mb), donate_argnums=(0, 1))

    dataset = SyntheticLMDataset(cfg.vocab_size, shape.seq_len,
                                 shape.global_batch, seed=seed)
    it = DataIterator(dataset, tok_sh)

    with set_mesh_compat(mesh):
        params = T.init_lm(cfg, jax.random.key(seed))
        params = jax.device_put(
            params, make_param_shardings(cfg, mesh, params))
        opt_state = optimizer.init(params)
        start = 0

        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            step0, trees, extras = restore_checkpoint(
                ckpt_dir, {"params": params, "opt_state": opt_state})
            params, opt_state = trees["params"], trees["opt_state"]
            it.load_state_dict(extras["data"])
            start = step0
            print(f"[train] resumed from step {start}", flush=True)

        # --- preemption hook ---------------------------------------------
        preempted = {"flag": False}

        def on_term(signum, frame):
            preempted["flag"] = True

        old_handlers = {s: signal.signal(s, on_term)
                        for s in (signal.SIGTERM, signal.SIGINT)}

        wd = Watchdog(watchdog_factor)
        history = []
        try:
            for step in range(start, steps):
                batch = next(it)
                t0 = time.perf_counter()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                slow = wd.observe(step, dt)
                history.append({"step": step, "loss": loss,
                                "grad_norm": float(metrics["grad_norm"]),
                                "time_s": dt, "straggler": slow})
                if not np.isfinite(loss):
                    print(f"[train] step {step}: non-finite loss, "
                          f"skipping optimizer effects via next clip",
                          flush=True)
                if step % log_every == 0 or step == steps - 1:
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"gnorm {history[-1]['grad_norm']:.3f} "
                          f"{dt*1e3:.0f} ms" + (" [STRAGGLER]" if slow else ""),
                          flush=True)
                do_ckpt = ckpt and (
                    (step + 1) % ckpt_every == 0 or preempted["flag"]
                    or step == steps - 1)
                if do_ckpt:
                    ckpt.save(step + 1,
                              {"params": params, "opt_state": opt_state},
                              extras={"data": it.state_dict(),
                                      "arch": cfg.name})
                if preempted["flag"]:
                    print(f"[train] preempted at step {step}; checkpoint "
                          f"flushed, exiting", flush=True)
                    break
        finally:
            if ckpt:
                ckpt.wait()
            for s, h in old_handlers.items():
                signal.signal(s, h)
        return params, opt_state, history


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = reduced_config(cfg)
        shape = ShapeSpec("reduced", args.seq, args.batch, "train")
    _, _, history = train(cfg, shape, steps=args.steps,
                          ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    losses = [h["loss"] for h in history]
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
