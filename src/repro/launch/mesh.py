"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state.  ``dryrun.py`` sets XLA_FLAGS for 512 host devices BEFORE
importing anything.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_mesh_compat",
           "set_mesh_compat", "mesh_axes"]


def set_mesh_compat(mesh):
    """Context manager installing ``mesh`` for trace-time sharding-constraint
    resolution: ``jax.set_mesh`` where it exists, the legacy ``with mesh:``
    (Mesh is itself a context manager) on older JAX."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with explicit-Auto axis types where the installed
    JAX supports them (``jax.sharding.AxisType`` and the ``axis_types``
    kwarg were added/renamed across releases; Auto is the default when the
    kwarg is absent, so omitting it is behavior-preserving)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    data = data or (n // model)
    return make_mesh_compat((data, model), ("data", "model"))


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod','data') when pods exist."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
