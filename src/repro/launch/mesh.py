"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state.  ``dryrun.py`` sets XLA_FLAGS for 512 host devices BEFORE
importing anything.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod','data') when pods exist."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
