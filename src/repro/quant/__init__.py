from repro.quant.ptq import (
    CalibrationStats, calibrate, quantize_lm_params, QuantizedLinear,
    quantized_matmul, bitserial_linear,
)
