"""Post-training quantization — the paper's pipeline as a TPU-native flow.

Neural Cache's execution model is: all layer I/O is uint8, weights are 8-bit
stationary in the arrays, partial sums are wide (24/32-bit), and each
layer's outputs are requantized from layer-wise min/max with a scalar fixup
from the CPU.  On TPU this becomes:

  * weights: per-channel symmetric int8 (scales absorbed into the epilogue),
  * activations: per-tensor affine uint8 from calibration min/max,
  * GEMM: int8 x int8 -> int32 on the MXU (kernels/quant_matmul.py fuses the
    dequant epilogue in VMEM — the "never leave the array" insight),
  * sub-8-bit weights: bit-plane decomposition (kernels/bitserial_matmul.py)
    whose cost scales with the number of planes, i.e. the paper's
    precision-proportional latency, with all-zero planes skipped at pack
    time (beyond-paper optimization).

``calibrate`` runs the fp model on sample batches collecting per-site
min/max (the paper's in-cache min/max reduction); ``quantize_lm_params``
converts a trained LM param tree; ``QuantizedLinear``/``quantized_matmul``
are the serving-path ops.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.quantize import (
    QuantParams, choose_qparams, choose_qparams_symmetric, quantize,
    quantize_per_channel,
)
from repro.kernels import ops as K
from repro.kernels import ref as KR

__all__ = [
    "CalibrationStats", "calibrate", "quantize_lm_params",
    "QuantizedLinear", "quantized_matmul", "bitserial_linear",
]


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CalibrationStats:
    """Running min/max per named site (EMA like TF-Lite's calibrator)."""

    momentum: float = 0.9
    mins: dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    maxs: dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def observe(self, name: str, x: jax.Array) -> None:
        mn = jnp.min(x).astype(jnp.float32)
        mx = jnp.max(x).astype(jnp.float32)
        if name in self.mins:
            m = self.momentum
            self.mins[name] = m * self.mins[name] + (1 - m) * mn
            self.maxs[name] = m * self.maxs[name] + (1 - m) * mx
        else:
            self.mins[name] = mn
            self.maxs[name] = mx

    def qparams(self, name: str, bits: int = 8) -> QuantParams:
        return choose_qparams(self.mins[name], self.maxs[name], bits=bits)


def calibrate(apply_fn: Callable[..., Any], batches, stats: CalibrationStats,
              observe_sites: Callable[[CalibrationStats, Any, Any], None]):
    """Run ``apply_fn`` over ``batches``; the caller's ``observe_sites``
    records the tensors it cares about.  Returns the stats (mutated)."""
    for batch in batches:
        out = apply_fn(batch)
        observe_sites(stats, batch, out)
    return stats


# ---------------------------------------------------------------------------
# weight conversion
# ---------------------------------------------------------------------------
def _is_linear_leaf(path: str, x) -> bool:
    name = path.rsplit("/", 1)[-1]
    return (hasattr(x, "ndim") and x.ndim >= 2
            and name in ("wq", "wk", "wv", "wo", "wi", "wg", "embed", "head",
                         "in_proj", "out_proj"))


def quantize_lm_params(params: Any, bits: int = 8,
                       skip: tuple[str, ...] = ("embed",)) -> Any:
    """Convert matmul weights to {'q': int8, 'scale': f32-per-channel}.

    Norms/biases/SSM dynamics stay fp (they're O(d) and precision-critical
    — DESIGN.md §Arch-applicability).  ``bits < 8`` additionally returns the
    bit-plane packing for the bit-serial kernel.
    """

    def leaf(path, x):
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        name = p.rsplit("/", 1)[-1]
        if not _is_linear_leaf(p, x) or name in skip:
            return x
        q, scale = quantize_per_channel(x.astype(jnp.float32), axis=-1,
                                        bits=bits)
        if x.ndim == 2:  # kernel convention: w_scale is [N]
            scale = scale.reshape(-1)
        out = {"q": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}
        if bits < 8:
            # byte-packed planes (bit b of each uint8 == plane b); the
            # plane count travels alongside — the MSB plane's -2^(bits-1)
            # weight is not recoverable from the bytes alone.
            out["planes"] = K.pack_weights(q.astype(jnp.int32), bits)
            out["plane_bits"] = bits
        return out

    return jax.tree_util.tree_map_with_path(leaf, params)


# ---------------------------------------------------------------------------
# serving-path ops
# ---------------------------------------------------------------------------
def quantized_matmul(x: jax.Array, wq: dict, x_qp: QuantParams | None = None,
                     prefer_pallas: bool = False) -> jax.Array:
    """x (fp) @ quantized weight -> fp.

    With ``x_qp`` the activation is quantized to int8 first and the GEMM
    runs W8A8 through the fused kernel (the paper path); without it the
    weight is dequantized on the fly (weight-only quantization).
    """
    if x_qp is None:
        w = wq["q"].astype(x.dtype) * wq["scale"].astype(x.dtype)
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, zp = _to_int8(quantize(x2, x_qp), x_qp)
    y = K.quant_matmul(xq, wq["q"], jnp.float32(x_qp.scale), wq["scale"],
                       prefer_pallas=prefer_pallas)
    # exact affine correction: x = s*(q - zp)  =>
    # x @ W = s*sw*(q @ qw) - s*zp*sw*colsum(qw)
    y = y + _zp_correction(wq, x_qp.scale, zp)
    return y.reshape(*lead, -1).astype(x.dtype)


def _to_int8(q, x_qp: QuantParams):
    """uint8 [0,255] -> int8 [-128,127] by re-centering (kernels are int8);
    the shifted zero point keeps the affine math exact."""
    if x_qp.signed:
        return q.astype(jnp.int8), x_qp.zero_point
    return ((q.astype(jnp.int32) - 128).astype(jnp.int8),
            x_qp.zero_point - 128)


def _zp_correction(wq, scale, zp, plane_axis: int = 0):
    qw = wq["q"].astype(jnp.int32)
    colsum = jnp.sum(qw, axis=0).astype(jnp.float32)
    return -(jnp.float32(scale) * zp) * colsum * wq["scale"].reshape(-1)


def bitserial_linear(x: jax.Array, wq: dict, x_qp: QuantParams,
                     prefer_pallas: bool = False) -> jax.Array:
    """Sub-8-bit path: plane-decomposed GEMM (precision-proportional cost)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, zp = _to_int8(quantize(x2, x_qp), x_qp)
    y = K.bitserial_matmul(xq, wq["planes"], jnp.float32(x_qp.scale),
                           wq["scale"], n_bits=int(wq.get("plane_bits", 8)),
                           prefer_pallas=prefer_pallas)
    y = y + _zp_correction(wq, x_qp.scale, zp)
    return y.reshape(*lead, -1).astype(x.dtype)


@dataclasses.dataclass
class QuantizedLinear:
    """A linear layer bound to its calibrated activation qparams."""

    wq: dict
    x_qp: QuantParams | None = None
    bits: int = 8

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.bits < 8 and "planes" in self.wq and self.x_qp is not None:
            return bitserial_linear(x, self.wq, self.x_qp)
        return quantized_matmul(x, self.wq, self.x_qp)
