"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec

from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.internvl2_26b import CONFIG as _internvl
from repro.configs.qwen1_5_110b import CONFIG as _qwen110
from repro.configs.qwen2_7b import CONFIG as _qwen2_7
from repro.configs.qwen1_5_32b import CONFIG as _qwen32
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.arctic_480b import CONFIG as _arctic

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _musicgen, _internvl, _qwen110, _qwen2_7, _qwen32,
        _olmo, _mamba2, _hymba, _moonshot, _arctic,
    ]
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Same-family smoke-test config: tiny depth/width/experts/vocab."""
    small = dict(
        n_layers=2,
        d_model=64,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        head_dim=16,
        moe_group_size=64,
        attn_chunk_q=64,
        attn_chunk_kv=64,
        dtype="float32",
    )
    if cfg.has_attention:
        small.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)))
    if cfg.is_moe:
        # generous capacity -> no token drops -> decode matches full forward
        small.update(n_experts=4, top_k=min(cfg.top_k, 2), capacity_factor=8.0)
        if cfg.moe_dense_residual:
            small.update(dense_ff=96)
    if cfg.has_ssm:
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.global_layers:
        small.update(global_layers=(0,), attn_window=32)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


def long_context_capable(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM / SWA hybrids)."""
    return cfg.family == "ssm" or (cfg.family == "hybrid" and cfg.attn_window > 0)


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not long_context_capable(cfg):
            continue  # skip noted in DESIGN.md §Arch-applicability
        out.append(s)
    return out
