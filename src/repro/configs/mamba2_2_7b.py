"""mamba2-2.7b [ssm]: SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: every layer is a Mamba-2 mixer (d_ff=0).  Runs long_500k —
decode state is O(1) in sequence length.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    norm="rmsnorm",
)
