"""musicgen-large [audio]: decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only — the EnCodec frontend is a stub; input_specs() provides the
token stream (vocab 2048 = one codebook) / precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,  # MHA
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    act="gelu",
    frontend="audio_tokens",
)
