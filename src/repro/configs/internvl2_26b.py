"""internvl2-26b [vlm]: InternViT + InternLM2 [arXiv:2404.16821; hf].

LM backbone only — the InternViT patch frontend is a stub; input_specs()
provides precomputed patch embeddings interleaved with text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,  # GQA
    d_ff=16384,
    vocab_size=92553,
    norm="rmsnorm",
    act="swiglu",
    frontend="vision_patch",
)
