"""Model configuration schema covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

__all__ = ["ModelConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_np (non-parametric)
    act: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    dense_ff: int = 0  # hidden of the dense-residual MLP
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"  # einsum (GShard) | scatter (gather-based)
    moe_group_size: int = 1024  # GShard dispatch group
    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Hymba) ------------------------------------------------------
    attn_window: int = 0  # sliding-window size for SWA layers (0 = full)
    global_layers: tuple[int, ...] = ()  # full-attention layer indices
    # --- frontend stub -------------------------------------------------------
    frontend: str = "none"  # none | audio_tokens | vision_patch
    # --- distribution (set by the launcher per mesh/shape, not arch files) ---
    # (batch_axes, seq_axes, vocab_axis): activation sharding constraints
    # applied at layer boundaries; None -> unconstrained (single-device runs).
    act_spec: tuple | None = None
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    loss_dtype: str = "float32"  # dtype of the loss-chunk logits
    kv_dtype: str = "bfloat16"  # "int8": quantized KV cache + int8 attention
    #   (the paper's in-cache quantization applied to the decode cache:
    #    per-(position, head) scales, int8 QK^T and PV matmuls on the MXU)
    loss_vocab_tp: bool = False  # reshard the loss region seq->vocab TP
    #   (keeps dW_head shard-local instead of all-reducing it per chunk)
    megatron_sp: bool = False  # gather seq-sharded acts at block entry so
    #   the TP GEMMs run on full-sequence activations with *sharded* weights
    #   (otherwise GSPMD replicates the ff weights per layer under SP)
    remat: str = "none"  # none | full | dots  (activation checkpoint policy)
    attn_chunk_q: int = 1024  # flash-attention tile sizes (pure-JAX scan)
    attn_chunk_kv: int = 1024

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads if self.n_kv_heads else 0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (embedding + layers + head), analytic."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # head
        per_layer = 0
        if self.has_attention:
            per_layer += d * (H * hd) + 2 * d * (Hkv * hd) + (H * hd) * d
            if self.qkv_bias:
                per_layer += (H + 2 * Hkv) * hd
        if self.family == "hybrid" or self.family == "ssm":
            di, N, P = self.d_inner, self.ssm_state, self.ssm_head_dim
            nh = self.ssm_heads
            # in_proj -> [z, x, B, C, dt], conv, dt bias, A, D, norm, out_proj
            per_layer += d * (2 * di + 2 * N + nh) + self.ssm_conv * (di + 2 * N)
            per_layer += 2 * nh + di + di * d
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * (3 * d * ff if self.act == "swiglu" else 2 * d * ff)
            if self.moe_dense_residual:
                dff = self.dense_ff or 2 * d
                per_layer += 3 * d * dff
        elif ff > 0:
            per_layer += 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        n += self.n_layers * per_layer
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        expert = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
