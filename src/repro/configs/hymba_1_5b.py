"""hymba-1.5b [hybrid]: parallel attn+mamba heads [arXiv:2411.13676; hf].

Sliding-window attention everywhere except three full-attention layers
(first / middle / last, per the paper); runs long_500k — SWA caches are
window-bounded and SSM state is O(1).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    norm="rmsnorm",
    act="swiglu",
    attn_window=1024,
    global_layers=(0, 15, 31),
)
