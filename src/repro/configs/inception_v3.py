"""inception-v3 — the paper's own evaluation workload (not an LM cell).

Selectable via --arch inception-v3 in the launchers; maps onto the Neural
Cache simulator and the quantized-inference example."""
from repro.models.inception import inception_v3_specs  # noqa: F401

NAME = "inception-v3"
