"""Table III: energy per inference and average power."""
from benchmarks.common import row, sim
from repro.core.simulator import PAPER


def run() -> list[str]:
    r = sim()
    return [
        row("tab3/nc_energy_j", r.energy_j * 1e6, f"{r.energy_j:.3f} J (paper 0.246)"),
        row("tab3/nc_power_w", 0.0, f"{r.power_w:.1f} W (paper 52.92)"),
        row("tab3/cpu_energy_j", PAPER["cpu_energy_j"] * 1e6, "paper-measured"),
        row("tab3/gpu_energy_j", PAPER["gpu_energy_j"] * 1e6, "paper-measured"),
        row("tab3/efficiency_vs_cpu", 0.0, f"{PAPER['cpu_energy_j']/r.energy_j:.1f}x (paper 37.1x)"),
        row("tab3/efficiency_vs_gpu", 0.0, f"{PAPER['gpu_energy_j']/r.energy_j:.1f}x (paper 16.6x)"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
