"""Figure 15: total Inception v3 inference latency, NC vs CPU vs GPU."""
from benchmarks.common import row, sim
from repro.core.simulator import PAPER


def run() -> list[str]:
    r = sim()
    nc_ms = r.latency_s * 1e3
    return [
        row("fig15/neural_cache", nc_ms * 1e3, "modeled"),
        row("fig15/cpu_xeon_e5", PAPER["cpu_latency_ms"] * 1e3, "paper-measured baseline"),
        row("fig15/gpu_titan_xp", PAPER["gpu_latency_ms"] * 1e3, "paper-measured baseline"),
        row("fig15/speedup_vs_cpu", 0.0, f"{PAPER['cpu_latency_ms']/nc_ms:.1f}x (paper 18.3x)"),
        row("fig15/speedup_vs_gpu", 0.0, f"{PAPER['gpu_latency_ms']/nc_ms:.1f}x (paper 7.7x)"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
