"""Figure 13: inference latency by layer of Inception v3 on Neural Cache."""
from collections import defaultdict

from benchmarks.common import row, sim


def run() -> list[str]:
    r = sim()
    per_block = defaultdict(float)
    for l in r.layers:
        per_block[l.spec.block] += l.total_s
    rows = []
    for block, t in per_block.items():
        rows.append(row(f"fig13/{block}", t * 1e6, f"neural-cache layer latency"))
    rows.append(row("fig13/TOTAL", r.latency_s * 1e6, "sum over layers"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
