"""Traffic replay: the multi-engine orchestrator under 10^5+ requests.

The paper's throughput story scales by adding sockets (§VI-C/VI-D); this
bench replays seeded arrival traces — Poisson and bursty — through a
heterogeneous three-socket fleet behind ``launch/orchestrator.py`` and
GATES the routing claim: the latency-model router ("latency") must beat
the latency-blind baseline ("round-robin") on SLO hit rate, on BOTH
traces, or this module RAISES.

Fleet (``engine_api.SimulatedEngine`` over compressed full-Inception
plans — real ``LatencyModel``/``AdmissionPolicy`` code paths, fake-clock
execution):

=============  ======================  ==========  ====================
socket         geometry                true_scale  modeled s/img (b=1)
=============  ======================  ==========  ====================
socket-35MB    XEON_E5_35MB (14 sl)    1.00        ~0.0047 (cap 2)
socket-17MB    scaled(7)               1.25        ~0.0070 (cap 1)
socket-10MB    scaled(4)               1.60        ~0.0104 (cap 1)
=============  ======================  ==========  ====================

The 10 MB socket cannot meet the 12 ms deadline even unloaded (p99 ~21 ms
once calibrated) — round-robin still sends it a third of the singles;
the latency router prices it out and only uses it as a deadline-blown
floor.  Every quantity is seeded (traces, per-engine jitter), so the
recorded mean latencies are deterministic and the BENCH_kernels.json
regression gate flags *routing* regressions, not host noise.

A second, real-execution segment routes a handful of images through
three real ``NCServingEngine`` sockets (tiny stem config) on the same
orchestrator and RAISES unless every completed request's logits are
byte-identical to a standalone ``nc_forward`` — the router changes
placement, never results.

``run_quick()`` replays a short Poisson trace through both routers in
under a second (the ``--quick`` smoke in benchmarks/run.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row

RECORDS: list[dict] = []
RETIMERS: dict[str, object] = {}

SLO_MS = 12.0
POISSON_RATE_HZ = 180.0
BURSTY_RATE_HZ = 120.0
JITTER = 0.05

# fleet: (name, slice scale of XEON_E5_35MB, true wall / modeled time)
FLEET_SPEC = [
    ("socket-35MB", 14, 1.00),
    ("socket-17MB", 7, 1.25),
    ("socket-10MB", 4, 1.60),
]


def _rec(name: str, us: float, shape: str, derived: str = "") -> str:
    RECORDS.append({"op": name, "shape": shape, "us_per_call": round(us, 2),
                    "derived": derived})
    return row(name, us, derived or shape)


def make_fleet(jitter: float = JITTER):
    """Three heterogeneous simulated sockets over compressed plans."""
    from repro.core import schedule as nc_schedule
    from repro.core.cache_geometry import XEON_E5_35MB
    from repro.launch.engine_api import SimulatedEngine
    from repro.models import inception

    specs = inception.inception_v3_specs()

    def schedule_for(geom):
        cache: dict = {}

        def f(n):
            if n not in cache:
                cache[n] = nc_schedule.plan_network(specs, geom, batch=n,
                                                    compressed=True)
            return cache[n]
        return f

    fleet = []
    for i, (name, n_slices, scale) in enumerate(FLEET_SPEC):
        geom = (XEON_E5_35MB if n_slices == XEON_E5_35MB.n_slices
                else XEON_E5_35MB.scaled(n_slices, name))
        fleet.append(SimulatedEngine(name, schedule_for(geom), max_batch=4,
                                     true_scale=scale, jitter=jitter,
                                     seed=100 + i))
    return fleet


def make_poisson_trace(n: int, rate_hz: float, seed: int) -> list[float]:
    """``n`` seeded Poisson arrival timestamps at ``rate_hz``."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n)).tolist()


def make_bursty_trace(n: int, rate_hz: float, seed: int, *,
                      burst: float = 2.5, lull: float = 0.3,
                      period_s: float = 2.0) -> list[float]:
    """On/off-modulated Poisson: alternating ``period_s`` phases at
    ``burst`` x and ``lull`` x the mean rate — queues build during bursts
    and drain during lulls."""
    rng = np.random.default_rng(seed)
    out: list[float] = []
    t = 0.0
    while len(out) < n:
        phase = burst if (int(t / period_s) % 2 == 0) else lull
        t += float(rng.exponential(1.0 / (rate_hz * phase)))
        out.append(t)
    return out


def replay(trace, router: str, *, slo_ms: float = SLO_MS,
           fleet=None):
    """Event-loop one arrival trace through an orchestrated fleet on a
    fake clock; returns the drained :class:`Orchestrator`.

    The clock jumps to the next event: the next arrival, the next
    engine-free instant, or — only while a free engine exists and the
    router is holding — a short recheck tick so holds release on time.
    """
    from repro.launch.engine_api import SimRequest
    from repro.launch.orchestrator import Orchestrator

    engines = make_fleet() if fleet is None else fleet
    clock = {"t": 0.0}
    orch = Orchestrator(engines, slo_ms=slo_ms, router=router,
                        now_fn=lambda: clock["t"])
    i, n = 0, len(trace)
    hold_tick = (slo_ms / 1e3) / 8.0
    while i < n or orch.pending:
        while orch.step(now=clock["t"], flush=(i >= n)):
            pass
        cands = []
        if i < n:
            cands.append(trace[i])
        nxt = orch.next_event_s(clock["t"])
        if nxt > clock["t"]:
            cands.append(nxt)
        if orch.queue and any(e.ready_in(clock["t"]) <= 0.0
                              and e.queue_depth == 0
                              for e in orch.engines):
            # a free engine + a held queue: wake soon to release the hold
            cands.append(clock["t"] + hold_tick)
        if not cands:
            break
        clock["t"] = max(clock["t"], min(cands))
        while i < n and trace[i] <= clock["t"]:
            orch.submit(SimRequest(rid=i), now=trace[i])
            i += 1
    return orch


def _check_accounting(orch, n: int, label: str) -> None:
    """The PR 9 accounting identities, fleet-wide — RAISES on violation."""
    s = orch.stats()
    if s["completed"] + s["failed"] != n:
        raise RuntimeError(f"{label}: {s['completed']} completed + "
                           f"{s['failed']} failed != {n} submitted")
    if s["slo_hits"] + s["slo_misses"] != s["completed"] + s["failed"]:
        raise RuntimeError(f"{label}: slo_hits {s['slo_hits']} + slo_misses "
                           f"{s['slo_misses']} != completed + failed")
    if orch.pending:
        raise RuntimeError(f"{label}: {orch.pending} requests stranded")
    batches = sum(s["batch_histogram"].values())
    admitted = sum(n_ * c for n_, c in s["batch_histogram"].items())
    if admitted != s["completed"] + s["failed"]:
        raise RuntimeError(f"{label}: histogram admits {admitted} != "
                           f"{s['completed'] + s['failed']} finished "
                           f"({batches} batches)")


def _replay_pair(trace_name: str, trace) -> tuple[list[str], dict]:
    """Replay one trace through both routers; gate latency > round-robin."""
    out = []
    rates = {}
    for router in ("latency", "round-robin"):
        orch = replay(trace, router)
        _check_accounting(orch, len(trace), f"{trace_name}/{router}")
        s = orch.stats()
        rates[router] = s["slo_hit_rate"]
        mean_us = float(np.mean([r.latency_s for r in orch.completed])) * 1e6
        tag = router.replace("-", "_")
        out.append(_rec(f"replay/{trace_name}_{tag}", mean_us,
                        f"{len(trace)} reqs, 3 sockets",
                        f"hit_rate {s['slo_hit_rate']:.4f}; "
                        f"dispatched {s['dispatched']}"))
    if rates["latency"] <= rates["round-robin"]:
        raise RuntimeError(
            f"{trace_name}: latency router hit rate {rates['latency']:.4f} "
            f"does not beat round-robin {rates['round-robin']:.4f} — the "
            f"calibrated-curve routing rule regressed")
    return out, rates


def _real_fleet_bitidentity() -> str:
    """Route real images through three real NCServingEngine sockets and
    RAISE unless every logit row is byte-identical to standalone
    ``nc_forward`` — whichever socket served it."""
    import time

    import jax

    from repro.core.cache_geometry import XEON_E5_35MB
    from repro.launch.orchestrator import Orchestrator
    from repro.launch.serve import NCRequest, NCServingEngine
    from repro.models import inception

    cfg = inception.reduced_config(img=47, width_div=8, classes=8, stages=())
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    clock = {"t": 0.0}
    engines = [
        NCServingEngine(params, cfg, max_batch=2, geom=geom, name=name,
                        now_fn=lambda: clock["t"])
        for name, geom in [
            ("socket-35MB", XEON_E5_35MB),
            ("socket-17MB", XEON_E5_35MB.scaled(7, "xeon-17MB")),
            ("socket-10MB", XEON_E5_35MB.scaled(4, "xeon-10MB")),
        ]
    ]
    orch = Orchestrator(engines, slo_ms=1e7, now_fn=lambda: clock["t"])
    rng = np.random.default_rng(0)
    images = rng.uniform(size=(6, cfg.img, cfg.img, 3)).astype(np.float32)
    t0 = time.perf_counter()
    for i, img in enumerate(images):
        orch.submit(NCRequest(rid=i, image=img), now=float(i))
        clock["t"] = float(i)
    clock["t"] = float(len(images))
    orch.run()
    wall_us = (time.perf_counter() - t0) * 1e6
    _check_accounting(orch, len(images), "real-fleet")
    for r in orch.completed:
        ref, _ = inception.nc_forward(params, images[r.rid], config=cfg)
        if not np.array_equal(np.asarray(r.logits), np.asarray(ref)):
            raise RuntimeError(f"real-fleet: request {r.rid} logits differ "
                               f"from standalone nc_forward")
    served = {n: c for n, c in orch.dispatched.items() if c}
    return row("replay/real_fleet_bitident", wall_us,
               f"6 imgs byte-identical across {len(served)} real sockets")


def run() -> list[str]:
    out = []
    poisson = make_poisson_trace(60_000, POISSON_RATE_HZ, seed=1)
    bursty = make_bursty_trace(40_000, BURSTY_RATE_HZ, seed=2)
    # >= 1e5 requests per router across the two gated traces
    rows, p_rates = _replay_pair("poisson", poisson)
    out.extend(rows)
    rows, b_rates = _replay_pair("bursty", bursty)
    out.extend(rows)
    out.append(row("replay/gate", 0.0,
                   f"latency beats round-robin: poisson "
                   f"{p_rates['latency']:.4f} > {p_rates['round-robin']:.4f}, "
                   f"bursty {b_rates['latency']:.4f} > "
                   f"{b_rates['round-robin']:.4f}"))
    out.append(_real_fleet_bitidentity())
    return out


def run_quick() -> list[str]:
    """Sub-second smoke: a short Poisson trace, both routers, the same
    accounting + router gates as the full replay.  Registers a retimer so
    ``--only replay/`` can re-measure it."""
    out = []
    trace = make_poisson_trace(500, POISSON_RATE_HZ, seed=1)

    def measure() -> float:
        rates = {}
        us = 0.0
        for router in ("latency", "round-robin"):
            orch = replay(trace, router)
            _check_accounting(orch, len(trace), f"quick/{router}")
            rates[router] = orch.stats()["slo_hit_rate"]
            if router == "latency":
                us = float(np.mean([r.latency_s
                                    for r in orch.completed])) * 1e6
        if rates["latency"] <= rates["round-robin"]:
            raise RuntimeError(
                f"quick: latency router hit rate {rates['latency']:.4f} "
                f"does not beat round-robin {rates['round-robin']:.4f}")
        return us

    us = measure()
    RETIMERS["replay/quick_poisson"] = measure
    out.append(_rec("replay/quick_poisson", us, "500 reqs, 3 sockets",
                    "mean latency, latency router; gates router + "
                    "accounting"))
    return out


if __name__ == "__main__":
    import sys

    rows = run_quick() if "--quick" in sys.argv[1:] else run()
    print("\n".join(rows))
