"""Table IV: inference latency vs LLC capacity (35/45/60 MB)."""
from benchmarks.common import row, sim
from repro.core.simulator import PAPER


def run() -> list[str]:
    rows = []
    for mb in (35, 45, 60):
        r = sim(mb)
        rows.append(
            row(f"tab4/{mb}MB", r.latency_s * 1e6,
                f"{r.latency_s*1e3:.2f} ms (paper {PAPER['capacity_ms'][mb]})")
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
