"""Beyond-paper bench: the Neural Cache cost model applied to the assigned
LM architectures.

The paper evaluates a CNN whose weights (max 5.8 MB/layer, Table I) fit the
35 MB LLC with room to replicate.  Modern LMs do not: this bench maps each
assigned arch's *decode-step* GEMM workload (active params, FC-as-1x1-conv
with the paper's filter packing) onto the same Xeon geometry and splits the
time into in-cache compute vs DRAM weight streaming.  The result — every LM
is dominated by weight loading unless served at batch >> 1 — is the paper's
own Fig 14 observation (46% filter loading) taken to its limit, and is why
the TPU translation (§Perf) focuses on keeping weights resident and
streaming activations instead.
"""
from __future__ import annotations

import dataclasses
import math

from benchmarks.common import row
from repro.configs import REGISTRY
from repro.core import bitserial as bs
from repro.core.cache_geometry import XEON_E5_35MB


@dataclasses.dataclass
class FCGemmResult:
    total_ms: float          # per-inference latency at batch=1
    amortized_ms: float      # per-inference at batch=64
    compute_ms: float
    weight_ms: float
    fits: bool


def simulate_fc_gemm(n_active_params: int, bits: int = 8,
                     geom=XEON_E5_35MB, batch: int = 64,
                     dram_bw: float = 60e9) -> FCGemmResult:
    """FC workload on the paper's geometry with 1x1 filter packing (§IV-A):
    16 packed weights per bit line, one MAC pipeline per bit line."""
    arrays = geom.compute_arrays
    lanes = arrays * geom.array_cols          # parallel bit lines
    pack = 16                                  # bytes of filter per bit line
    resident = lanes * pack                    # weights on-cache at once
    loads = max(1, math.ceil(n_active_params / resident))
    mac = bs.OpCycles(bits=bits).mac8 * pack + bs.reduce_cycles(pack, 24)
    compute_s = loads * mac / geom.compute_freq_hz
    weight_s = n_active_params * (bits / 8) / dram_bw
    total = compute_s + weight_s
    amortized = compute_s + weight_s / batch
    return FCGemmResult(total * 1e3, amortized * 1e3, compute_s * 1e3,
                        weight_s * 1e3, n_active_params <= resident)


def run():
    out = []
    for name, cfg in REGISTRY.items():
        n_active = cfg.active_param_count()
        r = simulate_fc_gemm(n_active)
        out.append(row(
            f"lm_nc/{name}", r.total_ms * 1e3,
            f"{n_active/1e9:.2f}B active; compute {r.compute_ms:.1f} ms + "
            f"weights {r.weight_ms:.1f} ms; batch64 -> {r.amortized_ms:.1f} "
            f"ms/inf; fits_llc={r.fits}"))
    return out
