"""Figure 16: throughput (inferences/s) vs batch size."""
from benchmarks.common import row, sim
from repro.core.simulator import PAPER, throughput


def run() -> list[str]:
    r = sim()
    rows = []
    for b in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        tp = throughput(r, b)
        rows.append(row(f"fig16/batch_{b}", 1e6 / tp, f"{tp:.1f} inf/s (dual socket)"))
    tp64 = throughput(r, 64)
    rows.append(row("fig16/vs_cpu", 0.0, f"{tp64/PAPER['cpu_throughput']:.1f}x (paper 12.4x)"))
    rows.append(row("fig16/vs_gpu", 0.0, f"{tp64/PAPER['gpu_throughput']:.1f}x (paper 2.2x)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
