"""Shared benchmark plumbing: CSV rows + cached simulation results."""
from __future__ import annotations

import functools
import os
import time

from repro.core.cache_geometry import XEON_E5_35MB, XEON_45MB, XEON_60MB
from repro.core.simulator import NetworkResult, simulate_network
from repro.models.inception import inception_v3_specs


@functools.lru_cache(maxsize=None)
def sim(mb: int = 35) -> NetworkResult:
    geom = {35: XEON_E5_35MB, 45: XEON_45MB, 60: XEON_60MB}[mb]
    return simulate_network(inception_v3_specs(), geom)


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.4f},{derived}"


def overlap_wall_slack() -> float:
    """Allowed overlapped/serial wall ratio for the §IV-E double-buffer
    gates (kernel_bench measures the pair, sched_breakdown re-checks the
    recorded baseline).

    The emulation's overlap pipeline hides HOST packing under the jit
    engine's asynchronously dispatched compute.  That is real concurrency
    only when there is a second core to run it on: on a single-core
    container (this CI box reports ``os.cpu_count() == 1``) the XLA
    worker thread and the packing python thread timeslice the same core,
    total work is conserved, and the model's floor for the measured win
    is parity, not improvement — so the gate there only demands that the
    double buffer costs no more than the ambient noise band (the same
    >1.3x drift documented in SPEEDUP_NOTES["host_noise"] bounds how
    tightly parity can be asserted).  With real parallelism available the
    floor tightens to no-loss."""
    return 1.0 if (os.cpu_count() or 1) > 1 else 1.25


def timed(fn, *args, iters: int = 3, **kw):
    """Wall-time a python callable (model-evaluation cost, informational).

    Reports the *minimum* over ``iters`` calls (timeit-style): on this
    shared-host container the mean is dominated by CPU-steal spikes, and
    the min is the stable estimate the BENCH_kernels.json regression gate
    needs to avoid flagging noise."""
    fn(*args, **kw)  # warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6
