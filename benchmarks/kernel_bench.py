"""Kernel benches: fused W8A8 and bit-serial GEMM vs fp32 XLA dot, plus the
word-packed emulation engine.

CPU wall-times are informational (TPU is the target); the structural
result is the plane-count scaling of the bit-serial kernel — the paper's
precision-proportional-latency property (Stripes-style) — measured as
HLO FLOPs of the lowered kernel, which *is* hardware-portable.  The
``emulation/*`` section times the packed bit-plane engine
(core/bitserial.py + core/nc_layers.py): 32 lanes per uint32 word, one
bitwise op per 32 lanes.

Besides the printed CSV rows, every result is appended to the module-level
``RECORDS`` list ({op, shape, us_per_call, derived}) so benchmarks/run.py
can dump a machine-readable ``BENCH_kernels.json`` perf baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.core.quantize import choose_qparams_symmetric, quantize, quantize_per_channel
from repro.distributed.hlo_analysis import xla_cost_analysis
from repro.kernels import ops as K

RECORDS: list[dict] = []

# op name -> zero-arg callable returning a fresh us_per_call measurement.
# benchmarks/run.py re-times flagged regressions through this registry
# (median of 3) before recording them, so the known kernel/f32_dot
# host-load flap (SPEEDUP_NOTES["host_noise"]) stops producing phantom
# notes.regressions entries.  Only the subsecond kernel/* ops register —
# re-timing a multi-second emulation record would double the bench wall.
RETIMERS: dict[str, object] = {}


def _rec(name: str, us: float, shape: str, derived: str = "") -> str:
    RECORDS.append({"op": name, "shape": shape, "us_per_call": round(us, 2),
                    "derived": derived})
    return row(name, us, derived or shape)


def _timed_rec(name: str, call, iters: int, shape: str,
               derived: str = "") -> str:
    """Time ``call``, record it, and register a retimer for it."""
    _, us = timed(call, iters=iters)
    RETIMERS[name] = lambda: timed(call, iters=iters)[1]
    return _rec(name, us, shape, derived)


def _emulation_rows():
    """Wall-time the packed bit-plane engine on emulation-suite shapes."""
    from repro.core import bitserial as bs
    from repro.core import nc_layers as nc
    from repro.core import quantize as q

    out = []
    rng = np.random.default_rng(0)

    # element-wise MAC over 4096 packed lanes (128 uint32 words / plane)
    a = rng.integers(0, 256, size=(4096,), dtype=np.uint32)
    b = rng.integers(0, 256, size=(4096,), dtype=np.uint32)
    pa, pb = bs.bitplane_pack(a, 8), bs.bitplane_pack(b, 8)
    acc = np.zeros((24, 4096), np.uint8)
    _, us = timed(lambda: bs.bitserial_mac(acc, pa, pb), iters=15)
    out.append(_rec("emulation/mac8_4096lanes", us, "4096 lanes x 8b MAC",
                    "packed words: 128 uint32/plane"))

    # log-tree reduction of 4096-lane rows of 24-bit partial sums.  The
    # micro-op is BATCHED (64 rows, one lockstep tree call) and the operand
    # packs row-aligned outside the timed body: a single cold row is pure
    # per-call python overhead (it times the interpreter, not the engine —
    # the old B=1 record cost the same wall time as these 64 rows and kept
    # flagging phantom ~1.4x regressions), while per-row time of the
    # batched call is the number the layer pipeline actually sees.
    rows64 = rng.integers(0, 1 << 16, size=(64, 4096), dtype=np.uint32)
    pp64 = bs.pack_values(rows64, 24, row_align=True)
    _, us = timed(lambda: bs.bitserial_reduce(pp64), iters=15)
    out.append(_rec("emulation/reduce_64x4096lanes", us, "64 rows x 4096, 24b",
                    f"{us / 64:.1f} us/row; "
                    f"{bs.reduce_cycles(4096, 24)} modeled cycles/row"))

    # §IV-D in-cache min/max over an int32 accumulator tensor
    acc = rng.integers(-(1 << 24), 1 << 24, size=(16384,)).astype(np.int64)
    _, us = timed(lambda: nc.nc_minmax(acc, bits=32, signed=True), iters=15)
    out.append(_rec("emulation/nc_minmax_16klanes", us, "16384 -> 2 scalars",
                    f"{bs.minmax_cycles(16384, 32) + 2} modeled cycles"))

    # full conv layer through the array model (all pixels/filters in lockstep)
    x = rng.normal(size=(14, 14, 8)).astype(np.float32)
    w = rng.normal(size=(3, 3, 8, 16)).astype(np.float32) * 0.5
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    _, us = timed(lambda: nc.nc_conv2d(jnp.asarray(x), jnp.asarray(w),
                                       x_qp, w_qp), iters=5)
    out.append(_rec("emulation/nc_conv2d", us, "14x14x8 * 3x3x8x16",
                    "12x12x16 outputs, one packed MAC+reduce"))

    # the same conv with a 4-image batch folded into the packed lane axis,
    # through the engine nc_forward defaults to at batch >= 2: the bucketed
    # jit kernel (timed() warms once, so the bucket compile amortizes away
    # exactly as it does across a serving run's batches)
    xb = rng.normal(size=(4, 14, 14, 8)).astype(np.float32)
    _, us_b = timed(lambda: nc.nc_conv2d(xb, jnp.asarray(w),
                                         [x_qp] * 4, w_qp, engine="jit"),
                    iters=5)
    out.append(_rec("emulation/nc_conv2d_batch4", us_b, "4x 14x14x8 * 3x3x8x16",
                    f"batch in lane axis, bucketed-jit engine; "
                    f"{us_b / 4:.0f} us/img vs {us:.0f} single host"))

    # max pooling via subtract + tag-masked copies (sub-ms op: extra iters
    # so the min actually rejects this host's CPU-steal spikes)
    xq = rng.integers(0, 256, size=(28, 28, 8), dtype=np.uint8)
    _, us = timed(lambda: nc.nc_maxpool2d(jnp.asarray(xq), 2, 2), iters=15)
    out.append(_rec("emulation/nc_maxpool2d", us, "28x28x8 w2 s2",
                    "14x14x8 lanes in lockstep"))

    # end-to-end: reduced Inception v3 stem through the emulation (tiled,
    # packed-resident; per-layer cycles reported by nc_forward)
    import jax as _jax
    from repro.models import inception
    cfg = inception.reduced_config(img=63, width_div=8, classes=8, stages=())
    params = inception.init_params(_jax.random.PRNGKey(0), config=cfg)
    img = _jax.random.uniform(_jax.random.PRNGKey(1), (63, 63, 3), jnp.float32)
    (_, report), us = timed(
        lambda: inception.nc_forward(params, img, config=cfg), iters=1)
    out.append(_rec("emulation/inception_stem", us, "63px /8 widths stem",
                    f"{len(report.layers)} layers, "
                    f"{report.total_emulated_cycles} emulated cycles"))
    out.extend(_sparsity_rows())
    out.extend(_overlap_rows())
    out.extend(_compressed_rows())
    return out


def _sparsity_rows():
    """Dense-vs-sparse record pair: reduced_config at batch 4 with a fixed
    50% filter pruning, executed dense and through the sparse schedule
    (pruned pass list).  GATE: sparse wall time above dense fails the run
    — the pruned pass list must actually be cheaper, not just modeled so.
    Both runs are timed back to back in this process, so the shared-host
    noise in SPEEDUP_NOTES["host_noise"] largely cancels; logits are also
    asserted byte-identical, making this a correctness gate too."""
    import time

    import jax as _jax
    from repro.models import inception

    cfg = inception.reduced_config()
    params = inception.init_params(_jax.random.PRNGKey(0), config=cfg)
    wpack = inception.prune_wpack(
        inception.prepare_conv_weights(params, cfg), 0.5)
    xb = np.asarray(_jax.random.uniform(
        _jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3), jnp.float32))

    # interleaved min-of-3 (first pass also warms the bucketed-jit engine
    # caches): the host_noise drift hits dense and sparse alike, and the
    # min rejects CPU-steal spikes the way timed() does for every other
    # record — the gate must not flap on a loaded container
    wall_d = wall_s = float("inf")
    logits_d = logits_s = None
    for _ in range(3):
        t0 = time.perf_counter()
        logits_d, rep_d = inception.nc_forward(params, xb, config=cfg,
                                               wpack=wpack)
        wall_d = min(wall_d, time.perf_counter() - t0)
        t0 = time.perf_counter()
        logits_s, rep_s = inception.nc_forward(params, xb, config=cfg,
                                               wpack=wpack, sparse=True)
        wall_s = min(wall_s, time.perf_counter() - t0)
    if not np.array_equal(np.asarray(logits_d), np.asarray(logits_s)):
        raise RuntimeError("sparsity gate: sparse nc_forward logits diverge "
                           "from dense on the same pruned weights")
    if wall_s > wall_d:
        raise RuntimeError(
            f"sparsity gate: sparse wall time {wall_s * 1e3:.0f} ms exceeds "
            f"dense {wall_d * 1e3:.0f} ms on the fixed 50% pruning")
    zero_filters = sum(l.zero_filters for l in rep_s.layers)
    out = [
        _rec("emulation/nc_forward_b4_pruned50_dense", wall_d * 1e6,
             f"{cfg.img}px /4 widths, batch 4, 50% filters zero",
             f"{wall_d / 4 * 1e3:.0f} ms/img; engine runs every filter"),
        _rec("emulation/nc_forward_b4_pruned50_sparse", wall_s * 1e6,
             f"{cfg.img}px /4 widths, batch 4, 50% filters zero",
             f"{wall_s / 4 * 1e3:.0f} ms/img; {zero_filters} filters pruned "
             f"from the pass list, {wall_d / wall_s:.2f}x vs dense"),
    ]
    return out


def _kernel_rows():
    """The subsecond ``kernel/*`` subset (every op registers a retimer).

    This is also the whole of ``python -m benchmarks.run --quick``: fast
    enough for a CI pre-gate, diffed against the same baseline."""
    out = []
    k1, k2 = jax.random.split(jax.random.key(0))
    M, Kdim, N = 256, 512, 256
    x = jax.random.normal(k1, (M, Kdim), jnp.float32)
    w = jax.random.normal(k2, (Kdim, N), jnp.float32) * 0.2
    qp = choose_qparams_symmetric(jnp.max(jnp.abs(x)))
    xq = quantize(x, qp)

    f32 = jax.jit(lambda a, b: a @ b)
    out.append(_timed_rec("kernel/f32_dot",
                          lambda: jax.block_until_ready(f32(x, w)), 15,
                          f"{M}x{Kdim}x{N}"))

    wq, ws = quantize_per_channel(w)
    q8 = jax.jit(lambda a, b: K.quant_matmul(a, b, qp.scale, ws.reshape(-1)))
    out.append(_timed_rec("kernel/w8a8_fused",
                          lambda: jax.block_until_ready(q8(xq, wq)), 15,
                          f"{M}x{Kdim}x{N}", "int8 MXU path (xla ref on cpu)"))

    base_flops = None
    for bits in (8, 4, 2, 1):
        wqb, wsb = quantize_per_channel(w, bits=bits)
        planes = K.pack_weights(wqb.astype(jnp.int32), bits)  # byte-packed
        fn = jax.jit(lambda a, p, bits=bits, wsb=wsb: K.bitserial_matmul(
            a, p, qp.scale, wsb.reshape(-1), n_bits=bits))
        flops = xla_cost_analysis(fn.lower(xq, planes).compile()).get("flops", 0)
        if bits == 8:
            base_flops = flops or 1
        out.append(_timed_rec(
            f"kernel/bitserial_{bits}b",
            lambda fn=fn, planes=planes: jax.block_until_ready(fn(xq, planes)),
            9, f"{M}x{Kdim}x{N}",
            f"{bits} planes byte-packed; HLO flops "
            f"{flops/base_flops:.2f}x of 8b"))

    # W4A4: byte-packing extended to the activations (2 elements/byte,
    # 2 half-K MXU passes per plane) — flops still plane-proportional
    from repro.kernels import ref as kref
    x4 = jax.random.randint(k1, (M, Kdim), -8, 8, jnp.int8)
    w4, ws4 = quantize_per_channel(w, bits=4)
    xp4 = kref.pack_activation_nibbles(x4)
    wp4 = K.pack_weights(w4.astype(jnp.int32), 4)
    fn4 = jax.jit(lambda a, p: K.bitserial_matmul_a4(
        a, p, qp.scale, ws4.reshape(-1), k=Kdim))
    flops4 = xla_cost_analysis(fn4.lower(xp4, wp4).compile()).get("flops", 0)
    out.append(_timed_rec("kernel/bitserial_w4a4_packed_act",
                          lambda: jax.block_until_ready(fn4(xp4, wp4)), 9,
                          f"{M}x{Kdim}x{N}",
                          f"2 elems/byte activations; HLO flops "
                          f"{flops4/base_flops:.2f}x of 8b"))
    return out


def _overlap_rows():
    """Serial-vs-overlapped record pair: a batch-4 reduced config executed
    through the PR 3/4 serial plan and through the double-buffered plan
    (``nc_forward(..., overlap=True)``: pass k+1's packed filter columns
    prefetch while pass k's MAC+reduce runs).  The workload is the stem at
    ``width_div=2`` on a ``scaled(4)`` geometry — at the full 35 MB array
    every reduced-config layer is single-pass and the §IV-E legality rule
    correctly denies overlap everywhere (nothing to hide), so the measured
    pair runs where multi-pass layers carry ~3/4 of the modeled time and
    the double buffer actually executes.  GATE: overlapped wall time must
    stay within :func:`benchmarks.common.overlap_wall_slack` of serial —
    no-loss where a second core gives the prefetch real concurrency,
    parity-within-noise on a single-core container (total work is
    conserved there; the model's floor for the measured win is zero
    either way, since overlap only re-times the copies, never the
    computed values); logits are asserted byte-identical, making this a
    correctness gate too.  A third record runs the 50%-pruned sparse
    schedule WITH overlap (pruning drops passes first, overlap hides the
    survivors' loads), gated locally against its own sparse-serial
    timing.  Interleaved min-of-3 as in :func:`_sparsity_rows` so host
    noise cancels."""
    import time

    import jax as _jax
    from benchmarks.common import overlap_wall_slack
    from repro.core.cache_geometry import XEON_E5_35MB
    from repro.models import inception

    cfg = inception.reduced_config(width_div=2, stages=())
    geom = XEON_E5_35MB.scaled(4)
    params = inception.init_params(_jax.random.PRNGKey(0), config=cfg)
    wpack = inception.prepare_conv_weights(params, cfg)
    xb = np.asarray(_jax.random.uniform(
        _jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3), jnp.float32))

    wall_s = wall_o = float("inf")
    logits_srl = logits_ov = None
    rep_o = None
    for _ in range(3):
        t0 = time.perf_counter()
        logits_srl, _ = inception.nc_forward(params, xb, config=cfg,
                                             geom=geom, wpack=wpack)
        wall_s = min(wall_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        logits_ov, rep_o = inception.nc_forward(params, xb, config=cfg,
                                                geom=geom, wpack=wpack,
                                                overlap=True)
        wall_o = min(wall_o, time.perf_counter() - t0)
    if not np.array_equal(np.asarray(logits_srl), np.asarray(logits_ov)):
        raise RuntimeError("overlap gate: overlapped nc_forward logits "
                           "diverge from serial on the same weights")
    slack = overlap_wall_slack()
    if wall_o > slack * wall_s:
        raise RuntimeError(
            f"overlap gate: overlapped wall time {wall_o * 1e3:.0f} ms "
            f"exceeds {slack:.2f}x serial {wall_s * 1e3:.0f} ms at batch "
            f"4 — the double buffer must be free, not a cost")
    n_ov = sum(1 for l in rep_o.layers if l.overlap)
    if n_ov == 0:
        raise RuntimeError("overlap gate: no layer executed double-buffered "
                           "— the record pair would be measuring noise")
    shape = f"{cfg.img}px /2 widths stem, batch 4, 1/4-scale array"
    out = [
        _rec("emulation/nc_forward_b4_serial", wall_s * 1e6, shape,
             f"{wall_s / 4 * 1e3:.0f} ms/img; load-then-compute per pass"),
        _rec("emulation/nc_forward_b4_overlap", wall_o * 1e6, shape,
             f"{wall_o / 4 * 1e3:.0f} ms/img; {n_ov} layers prefetch "
             f"filters under MAC+reduce, {wall_s / wall_o:.2f}x vs serial"),
    ]

    # pruning x overlap: the sparse schedule's surviving passes still
    # double-buffer; gate against sparse-serial so the comparison point
    # shares the pruned pass list
    wp = inception.prune_wpack(wpack, 0.5)
    wall_ps = wall_po = float("inf")
    logits_ps = logits_po = None
    for _ in range(3):
        t0 = time.perf_counter()
        logits_ps, _ = inception.nc_forward(params, xb, config=cfg,
                                            geom=geom, wpack=wp, sparse=True)
        wall_ps = min(wall_ps, time.perf_counter() - t0)
        t0 = time.perf_counter()
        logits_po, _ = inception.nc_forward(params, xb, config=cfg,
                                            geom=geom, wpack=wp, sparse=True,
                                            overlap=True)
        wall_po = min(wall_po, time.perf_counter() - t0)
    if not np.array_equal(np.asarray(logits_ps), np.asarray(logits_po)):
        raise RuntimeError("overlap gate: sparse+overlap logits diverge "
                           "from sparse-serial on the same pruned weights")
    if wall_po > slack * wall_ps:
        raise RuntimeError(
            f"overlap gate: sparse+overlap wall time {wall_po * 1e3:.0f} ms "
            f"exceeds {slack:.2f}x sparse-serial {wall_ps * 1e3:.0f} ms "
            f"at batch 4")
    out.append(_rec(
        "emulation/nc_forward_b4_pruned50_overlap", wall_po * 1e6,
        f"{shape}, 50% pruned",
        f"{wall_po / 4 * 1e3:.0f} ms/img; skipped passes first, loads "
        f"hidden second, {wall_ps / wall_po:.2f}x vs sparse-serial"))
    return out


def _compressed_rows():
    """Compressed-vs-dense record pair (PR 8): reduced_config at batch
    4 with the fixed 50% filter pruning, executed from the dense filter
    store (every filter runs) and from the CSR bit-plane store through
    the compressed sparse schedule.  GATES, any failure raises like the
    sparsity/overlap gates: (1) the compressed schedule must keep no more
    than 0.55x the dense schedule's ``filter_bytes_loaded`` resident —
    the modeled §IV-A residency win the simulator credits exactly; (2)
    compressed wall time must not regress past dense; (3) logits must be
    byte-identical (decompression scatters live columns into zero words,
    the multiply identity).  Interleaved min-of-3 as in
    :func:`_sparsity_rows` so shared-host noise cancels."""
    import time

    import jax as _jax
    from repro.core import schedule as nc_sched
    from repro.core.cache_geometry import XEON_E5_35MB
    from repro.models import inception

    cfg = inception.reduced_config()
    params = inception.init_params(_jax.random.PRNGKey(0), config=cfg)
    wpack = inception.prune_wpack(
        inception.prepare_conv_weights(params, cfg), 0.5)
    xb = np.asarray(_jax.random.uniform(
        _jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3), jnp.float32))

    # modeled residency gate first — deterministic, no timing noise
    specs = inception.inception_v3_specs(cfg)
    occ = inception.network_occupancy(wpack, cfg)
    dense_plan = nc_sched.plan_network(specs, XEON_E5_35MB, batch=4)
    comp_plan = nc_sched.plan_network(specs, XEON_E5_35MB, batch=4,
                                      occupancy=occ, compressed=True)
    fbl_ratio = comp_plan.filter_bytes_loaded / dense_plan.filter_bytes_loaded
    if fbl_ratio > 0.55:
        raise RuntimeError(
            f"compression gate: compressed schedule keeps {fbl_ratio:.3f}x "
            f"the dense filter bytes resident at 50% pruning — must be "
            f"<= 0.55x")

    wall_d = wall_c = float("inf")
    logits_d = logits_c = None
    for _ in range(3):
        t0 = time.perf_counter()
        logits_d, _ = inception.nc_forward(params, xb, config=cfg,
                                           wpack=wpack)
        wall_d = min(wall_d, time.perf_counter() - t0)
        t0 = time.perf_counter()
        logits_c, rep_c = inception.nc_forward(params, xb, config=cfg,
                                               wpack=wpack, sparse=True,
                                               compressed=True)
        wall_c = min(wall_c, time.perf_counter() - t0)
    if not np.array_equal(np.asarray(logits_d), np.asarray(logits_c)):
        raise RuntimeError("compression gate: CSR-store nc_forward logits "
                           "diverge from the dense store on the same "
                           "pruned weights")
    if wall_c > wall_d:
        raise RuntimeError(
            f"compression gate: compressed wall time {wall_c * 1e3:.0f} ms "
            f"exceeds dense {wall_d * 1e3:.0f} ms on the fixed 50% pruning")
    shape = f"{cfg.img}px /4 widths, batch 4, 50% filters zero"
    return [
        _rec("emulation/nc_forward_b4_pruned50_densestore", wall_d * 1e6,
             shape, f"{wall_d / 4 * 1e3:.0f} ms/img; full dense residency "
             f"({dense_plan.filter_bytes_loaded} filter bytes)"),
        _rec("emulation/nc_forward_b4_pruned50_csr", wall_c * 1e6, shape,
             f"{wall_c / 4 * 1e3:.0f} ms/img; CSR bit-plane store, "
             f"{fbl_ratio:.3f}x dense residency (credit "
             f"{comp_plan.residency_credit_bytes} B/batch), "
             f"{wall_d / wall_c:.2f}x vs dense"),
    ]


def _compressed_smoke_rows():
    """``--quick`` compressed smoke (PR 8): a small half-pruned conv
    executed from the CSR bit-plane store — GATE: byte-identical to the
    dense store.  Subsecond, registers a retimer like the kernel rows."""
    from repro.core import nc_layers as nc
    from repro.core import quantize as q

    rng = np.random.default_rng(0)
    wq = rng.integers(0, 256, size=(3, 3, 4, 16)).astype(np.uint8)
    wq[..., 8:] = 7  # half the filters at the zero point
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=7)
    x = rng.uniform(-1, 1, (2, 10, 10, 4)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    dense, _ = nc.nc_conv2d(x, wq, [x_qp] * 2, w_qp, padding="SAME")
    comp, _ = nc.nc_conv2d(x, wq, [x_qp] * 2, w_qp, padding="SAME",
                           occupancy="detect", compressed=True)
    if not np.array_equal(np.asarray(comp), np.asarray(dense)):
        raise RuntimeError("compression smoke gate: CSR-store conv diverges "
                           "from the dense store")
    return [_timed_rec(
        "emulation/csr_conv_smoke",
        lambda: nc.nc_conv2d(x, wq, [x_qp] * 2, w_qp, padding="SAME",
                             occupancy="detect", compressed=True), 5,
        "2x 10x10x4 * 3x3x4x16, 50% pruned",
        "CSR bit-plane store, byte-identical to dense")]


def _backend_rows():
    """Backend-registry record pair (PR 10): the batch-4 pruned-50
    reduced forward executed through the ``host`` and ``jit`` backends of
    core/backends.py — the same workload twice, differing ONLY in the
    ``engine=`` name.  GATES, raising like the sparsity/overlap gates:
    logits must be byte-identical across backends (the conformance
    contract of tests/test_backends.py at network scale) and both the
    emulated and modeled cycle totals must be bit-identical (backends
    re-time execution, never the model).  A third subsecond record times
    the ``pallas-interpret`` adapter on one packed dot (gated on byte-
    identity to host), so the interpret path's wall cost is tracked in
    the same baseline.  Interleaved min-of-2 so shared-host noise
    cancels; per-backend wall times are EXPECTED to differ — that is the
    point of the records — only values and cycles are gated."""
    import time

    import jax as _jax
    from repro.core import backends as nc_backends
    from repro.core import bitserial as bs
    from repro.core import nc_layers as nc
    from repro.models import inception

    cfg = inception.reduced_config()
    params = inception.init_params(_jax.random.PRNGKey(0), config=cfg)
    wpack = inception.prune_wpack(
        inception.prepare_conv_weights(params, cfg), 0.5)
    xb = np.asarray(_jax.random.uniform(
        _jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3), jnp.float32))

    walls = {"host": float("inf"), "jit": float("inf")}
    logits: dict = {}
    reports: dict = {}
    for _ in range(2):
        for name in ("host", "jit"):
            t0 = time.perf_counter()
            logits[name], reports[name] = inception.nc_forward(
                params, xb, config=cfg, wpack=wpack, sparse=True,
                engine=name)
            walls[name] = min(walls[name], time.perf_counter() - t0)
    if not np.array_equal(np.asarray(logits["host"]),
                          np.asarray(logits["jit"])):
        raise RuntimeError("backend gate: jit-backend nc_forward logits "
                           "diverge from the host backend on the same "
                           "pruned weights")
    for field in ("total_emulated_cycles", "total_modeled_cycles"):
        if getattr(reports["host"], field) != getattr(reports["jit"], field):
            raise RuntimeError(
                f"backend gate: {field} differs across backends — backends "
                f"must re-time execution, never the cycle model")
    shape = f"{cfg.img}px /4 widths, batch 4, 50% filters zero"
    out = [
        _rec(f"backend/{name}/nc_forward_b4_pruned50", walls[name] * 1e6,
             shape,
             f"{walls[name] / 4 * 1e3:.0f} ms/img via the {name} backend; "
             f"logits and cycles gated identical across backends")
        for name in ("host", "jit")
    ]

    # interpret-mode adapter: one packed dot, byte-identity gated, timed
    # so the Pallas path's wall cost rides the same regression baseline
    rng = np.random.default_rng(0)
    xw = nc._pack_x_rows(
        rng.integers(0, 256, size=(13, 144)).astype(np.uint32), 8)
    ww = nc._pack_w_rows(
        rng.integers(0, 256, size=(8, 144)).astype(np.uint32), 8)
    ref, _ = bs.packed_dot_words(xw, ww, K=144, acc_bits=32, engine="host")
    nc_backends.dispatch_stats_clear()
    vals, _ = bs.packed_dot_words(xw, ww, K=144, acc_bits=32,
                                  engine="pallas-interpret")
    if not np.array_equal(np.asarray(vals), np.asarray(ref)):
        raise RuntimeError("backend gate: pallas-interpret packed dot "
                           "diverges from the host backend")
    if nc_backends.dispatch_stats()["pallas-interpret"]["native"] != 1:
        raise RuntimeError("backend gate: pallas-interpret delegated the "
                           "in-envelope dot to host — the record would "
                           "time the wrong body")
    out.append(_timed_rec(
        "backend/pallas-interpret/dot",
        lambda: bs.packed_dot_words(xw, ww, K=144, acc_bits=32,
                                    engine="pallas-interpret"), 3,
        "13x144 . 8x144 word grids",
        "interpret-mode Pallas GEMM, byte-identical to host"))
    return out


# checksum verification may not cost more than this multiple of the
# unchecked conv wall/cycles on the _fault_rows workload — the recorded
# bound the fault gate enforces (the modeled overhead is one extra lane
# group riding every pass: a few percent, so 1.5x leaves only noise room)
INTEGRITY_OVERHEAD_BOUND = 1.5


def _fault_rows():
    """Fault-sweep smoke gate (PR 7), quick enough for ``--quick``.

    A small conv runs once unchecked and once integrity-checked with no
    faults — GATE: logits byte-identical (verification never perturbs the
    data path) and both cycle and wall overhead under
    :data:`INTEGRITY_OVERHEAD_BOUND`.  Then every covered fault class
    (``faults.COVERED_CLASSES``) injects at rate 1 under integrity —
    GATE: every corrupted pass is detected (zero silent corruption) and
    the recovered logits are byte-identical to clean.  Any gate failure
    raises, failing the bench run like the sparsity/overlap gates."""
    import time

    from repro.core import faults, nc_layers as nc
    from repro.core import quantize as q
    from repro.core.cache_geometry import XEON_E5_35MB

    rng = np.random.default_rng(0)
    geom = XEON_E5_35MB
    x = rng.uniform(-1, 1, (2, 10, 10, 4)).astype(np.float32)
    w = rng.uniform(-1, 1, (3, 3, 4, 16)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))

    def conv(**kw):
        t0 = time.perf_counter()
        res = nc.nc_conv2d(x, w, [x_qp] * 2, w_qp, stride=1, padding="SAME",
                           geom=geom, **kw)
        return res, time.perf_counter() - t0

    (out0, cyc0), wall0 = conv()
    (out1, cyc1, st1), wall1 = conv(integrity=True, return_stats=True)
    if not np.array_equal(np.asarray(out0), np.asarray(out1)):
        raise RuntimeError("fault gate: integrity-checked conv logits "
                           "diverge from unchecked on clean execution")
    cyc_ratio = cyc1 / cyc0
    if cyc_ratio > INTEGRITY_OVERHEAD_BOUND:
        raise RuntimeError(
            f"fault gate: checksum cycle overhead {cyc_ratio:.2f}x exceeds "
            f"the {INTEGRITY_OVERHEAD_BOUND}x bound")
    out = [
        _rec("faults/conv_unchecked", wall0 * 1e6, "2x 10x10x4 * 3x3x4x16",
             f"{cyc0} emulated cycles"),
        _rec("faults/conv_integrity", wall1 * 1e6, "2x 10x10x4 * 3x3x4x16",
             f"{cyc1} emulated cycles, {cyc_ratio:.3f}x unchecked "
             f"(bound {INTEGRITY_OVERHEAD_BOUND}x)"),
    ]

    t0 = time.perf_counter()
    detected_total = 0
    for cls in faults.COVERED_CLASSES:
        if cls == "stuck":
            probe = faults.FaultState(
                faults.FaultProfile(n_slices=geom.n_slices))
            sid = probe.slice_for("nc_conv2d", 0)
            prof = faults.FaultProfile(seed=5, stuck_slices=(sid,),
                                       n_slices=geom.n_slices)
        else:
            kw = {"filter_flip": dict(filter_flip_rate=1.0),
                  "act_flip": dict(act_flip_rate=1.0),
                  "compute": dict(compute_rate=1.0)}[cls]
            prof = faults.FaultProfile(seed=5, n_slices=geom.n_slices, **kw)
        with faults.inject(prof) as fs:
            (outf, _, stf), _ = conv(integrity=True, return_stats=True)
        if fs.corrupt_attempts == 0:
            raise RuntimeError(f"fault gate: class {cls!r} injected nothing "
                               f"at rate 1 — the sweep is not covering it")
        if fs.detected != fs.corrupt_attempts:
            raise RuntimeError(
                f"fault gate: class {cls!r} had {fs.corrupt_attempts} "
                f"corrupt passes but only {fs.detected} detected — "
                f"silent corruption")
        if not np.array_equal(np.asarray(out0), np.asarray(outf)):
            raise RuntimeError(f"fault gate: class {cls!r} recovered logits "
                               f"diverge from clean")
        detected_total += fs.detected
    wall_sweep = time.perf_counter() - t0
    out.append(_rec(
        "faults/covered_class_sweep", wall_sweep * 1e6,
        f"{len(faults.COVERED_CLASSES)} classes x rate 1",
        f"{detected_total} faults detected, 0 silent, logits clean"))
    return out


def run():
    RECORDS.clear()
    RETIMERS.clear()
    out = _kernel_rows()
    out.extend(_emulation_rows())
    out.extend(_fault_rows())
    out.extend(_compressed_smoke_rows())
    out.extend(_backend_rows())
    return out


def run_quick():
    """``kernel/*`` + fault-gate + compressed-smoke + cross-backend
    records; ``benchmarks.run --quick``."""
    RECORDS.clear()
    RETIMERS.clear()
    out = _kernel_rows()
    out.extend(_fault_rows())
    out.extend(_compressed_smoke_rows())
    out.extend(_backend_rows())
    return out
