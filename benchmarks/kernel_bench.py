"""Kernel benches: fused W8A8 and bit-serial GEMM vs fp32 XLA dot.

CPU wall-times are informational (TPU is the target); the structural
result is the plane-count scaling of the bit-serial kernel — the paper's
precision-proportional-latency property (Stripes-style) — measured as
HLO FLOPs of the lowered kernel, which *is* hardware-portable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core.quantize import choose_qparams_symmetric, quantize, quantize_per_channel
from repro.kernels import ops as K


def run():
    out = []
    k1, k2 = jax.random.split(jax.random.key(0))
    M, Kdim, N = 256, 512, 256
    x = jax.random.normal(k1, (M, Kdim), jnp.float32)
    w = jax.random.normal(k2, (Kdim, N), jnp.float32) * 0.2
    qp = choose_qparams_symmetric(jnp.max(jnp.abs(x)))
    xq = quantize(x, qp)

    f32 = jax.jit(lambda a, b: a @ b)
    _, us = timed(lambda: jax.block_until_ready(f32(x, w)))
    out.append(row("kernel/f32_dot", us, f"{M}x{Kdim}x{N}"))

    wq, ws = quantize_per_channel(w)
    q8 = jax.jit(lambda a, b: K.quant_matmul(a, b, qp.scale, ws.reshape(-1)))
    _, us = timed(lambda: jax.block_until_ready(q8(xq, wq)))
    out.append(row("kernel/w8a8_fused", us, "int8 MXU path (xla ref on cpu)"))

    base_flops = None
    for bits in (8, 4, 2, 1):
        wqb, wsb = quantize_per_channel(w, bits=bits)
        planes = K.pack_weights(wqb.astype(jnp.int32), bits)
        fn = jax.jit(lambda a, p: K.bitserial_matmul(
            a, p, qp.scale, wsb.reshape(-1)))
        flops = fn.lower(xq, planes).compile().cost_analysis().get("flops", 0)
        if bits == 8:
            base_flops = flops
        _, us = timed(lambda: jax.block_until_ready(fn(xq, planes)))
        out.append(row(f"kernel/bitserial_{bits}b", us,
                       f"{planes.shape[0]} planes; HLO flops "
                       f"{flops/base_flops:.2f}x of 8b"))
    return out
