"""Benchmark harness — one module per paper table/figure, plus kernel and
LM-architecture benches.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.fig13_latency_by_layer",
    "benchmarks.fig14_breakdown",
    "benchmarks.fig15_total_latency",
    "benchmarks.fig16_throughput_batch",
    "benchmarks.tab3_energy",
    "benchmarks.tab4_cache_scaling",
    "benchmarks.kernel_bench",
    "benchmarks.lm_neural_cache",
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            for line in mod.run():
                print(line)
        except Exception:  # pragma: no cover - harness robustness
            failures += 1
            print(f"{modname},0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
