"""Benchmark harness — one module per paper table/figure, plus kernel and
LM-architecture benches.  Prints ``name,us_per_call,derived`` CSV and dumps
the kernel/emulation rows to ``BENCH_kernels.json`` (a machine-readable
perf baseline: op, shape, wall-time, plane-count scaling).

Perf-regression gate: before refreshing the baseline, every new record is
diffed against the previous ``BENCH_kernels.json`` — any recorded op that
got more than ``REGRESSION_THRESHOLD`` x slower is re-timed (median of 3
via ``kernel_bench.RETIMERS``, rejecting transient host-load spikes like
the known ``kernel/f32_dot`` flap) and, if the slowdown survives, flagged
on stderr and listed under ``notes.regressions`` in the refreshed file,
so a later PR's run makes its own slowdowns visible.

``--quick`` runs only the subsecond subset — the ``kernel/*`` rows plus
the ``replay/quick_poisson`` traffic-replay smoke (PR 9) — through the
same diff-vs-baseline gate (no baseline rewrite, no slow-test gate) — a
CI pre-check; ``tests/test_bench_quick.py`` keeps it working.  ``--only
<record-prefix>`` narrows further: just the matching retimer-backed
records, median of 3, diffed against the baseline.  The gate output and
the refreshed baseline both carry a host fingerprint (cpu count,
platform, jax/jaxlib versions) so recorded wall times keep their
provenance.

Slow-test gate: tier-1 (`pytest -x -q`) deselects the ``slow``-,
``faults``- and ``backends``-marked tests (pytest.ini) — the end-to-end
reduced-Inception/serving runs, the fault-injection sweeps, and the
interpret-mode backend conformance sweeps; this harness runs them
(`pytest -m "slow or faults or backends"`) after the benches so they
stay exercised.  Set ``BENCH_SKIP_SLOW=1`` to skip the gate."""
from __future__ import annotations

import importlib
import json
import os
import pathlib
import subprocess
import sys
import traceback

MODULES = [
    "benchmarks.fig13_latency_by_layer",
    "benchmarks.fig14_breakdown",
    "benchmarks.fig15_total_latency",
    "benchmarks.fig16_throughput_batch",
    "benchmarks.sched_breakdown",
    "benchmarks.tab3_energy",
    "benchmarks.tab4_cache_scaling",
    "benchmarks.kernel_bench",
    "benchmarks.lm_neural_cache",
    "benchmarks.traffic_replay",
]

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernels.json"

REGRESSION_THRESHOLD = 1.3  # flag ops that got >1.3x slower than baseline

# Measured on the CI container (PR 2: packed-resident tiled layer pipeline
# vs PR 1's word-packed engine, vs the per-lane uint8 seed emulation;
# PR 3: batched slice-scheduler + decoded bucketed-jit engine body);
# kept as provenance next to the fresh numbers dumped on every run.
SPEEDUP_NOTES = {
    "emulation_engine": "packed-resident row-aligned words; schedule-planned "
                        "tiles ((image,pixel) rows x filters, geometry-"
                        "bounded) reusing packed window planes across filters "
                        "and packed filters across the batch; EIE-style "
                        "zero-operand word skipping; bucketed-jit engine "
                        "cache with decoded integer-lane kernel body",
    "batch4_reduced_forward": "nc_forward(batch=4) reduced_config(): "
                              "~0.4-1.0 s/img (jit default) vs ~1.8-2.0 s "
                              "at batch=1 (host) — §VI-C amortization",
    "sparsity": "PR 4: dense-vs-sparse pair "
                "(emulation/nc_forward_b4_pruned50_*): reduced_config at "
                "batch 4 with the last 50% of every conv's filters zeroed; "
                "the sparse schedule drops zero-filter passes (engine runs "
                "live columns only, logits byte-identical — asserted) and "
                "kernel_bench RAISES if sparse wall time exceeds dense; "
                "full-network modeled credit at 50% pruning is ~48% of "
                "compute cycles (sparsity/TOTAL row of sched_breakdown)",
    "compression": "PR 8: compressed-vs-dense pair "
                   "(emulation/nc_forward_b4_pruned50_densestore/_csr): "
                   "CSR bit-plane filter residency at 50% pruning keeps "
                   "<= 0.55x the dense filter bytes resident (gated), "
                   "logits byte-identical, wall no worse than dense; "
                   "emulation/csr_conv_smoke is the --quick smoke row; "
                   "the compressed staging rule lifts the full-network "
                   "stream_batch_limit 1 -> 2 (sched_breakdown gates it)",
    "host_noise": "this shared container shows >1.3x ambient cross-run "
                  "drift even at min-of-15 (PR 3: untouched ops incl. the "
                  "pure-XLA kernel/f32_dot flapped 1.3-2.7x between "
                  "back-to-back runs); treat notes.regressions entries as "
                  "real only when kernel/f32_dot (the load canary) is NOT "
                  "also flagged and the ratio reproduces across runs",
    "emulation_suite_seed_s": 14.45,   # pytest tests/test_nc_layers.py @ seed
    "emulation_suite_now_s": 2.5,      # same module, packed engine (PR 1)
    "emulation_speedup_vs_seed": 5.8,  # wall; per-op bodies are >20x
    "nc_conv2d_pr1_us": 168421.96,     # 14x14x8 * 3x3x8x16 @ PR 1 baseline
    "orchestrator": "PR 9: replay/* rows are fully seeded fake-clock "
                    "replays (traces + jitter), so their recorded mean "
                    "latencies are deterministic — a notes.regressions "
                    "entry there is a routing/admission behavior change, "
                    "never host noise; traffic_replay RAISES unless the "
                    "latency router beats round-robin on SLO hit rate on "
                    "both traces and completed logits stay byte-identical "
                    "to standalone nc_forward on the real-fleet segment",
}


def host_fingerprint() -> dict:
    """Provenance for the recorded wall times (PR 8): which host shape
    produced them.  Written under ``notes.host`` in BENCH_kernels.json and
    printed next to the regression gate, so a flagged slowdown can be told
    apart from a container change (cpu_count 1 vs N decides whether the
    overlap gates demand parity or no-loss — see
    ``benchmarks.common.overlap_wall_slack``)."""
    import platform

    fp = {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        import jaxlib
        fp["jax"] = jax.__version__
        fp["jaxlib"] = jaxlib.__version__
    except Exception:  # pragma: no cover - fingerprint best-effort
        pass
    return fp


def diff_records(old_payload: dict | None, records: list[dict],
                 threshold: float = REGRESSION_THRESHOLD) -> list[dict]:
    """Compare fresh records against a previous baseline payload; return
    the ops that regressed by more than ``threshold`` x."""
    if not old_payload:
        return []
    prev = {r["op"]: r.get("us_per_call", 0.0)
            for r in old_payload.get("records", [])}
    regressions = []
    for r in records:
        before = prev.get(r["op"], 0.0)
        if before > 0 and r["us_per_call"] > threshold * before:
            regressions.append({
                "op": r["op"],
                "before_us": before,
                "after_us": r["us_per_call"],
                "ratio": round(r["us_per_call"] / before, 2),
            })
    return regressions


def harden_regressions(regressions: list[dict], records: list[dict],
                       retimers: dict,
                       threshold: float = REGRESSION_THRESHOLD) -> list[dict]:
    """Re-time each flagged op (median of 3 fresh measurements) before
    recording it as a regression.

    The known flap: ``kernel/f32_dot`` (pure XLA, untouched across PRs)
    drifts >1.3x between back-to-back runs on this shared container
    (SPEEDUP_NOTES["host_noise"]) — a transient host-load spike during its
    original min-of-15 window.  A median re-measure moments later rejects
    the spike: the op keeps ``min(original, median)`` as its recorded
    time, and the regression survives only if that still clears the
    threshold (then it is stamped ``retimed: True`` so the baseline shows
    the flag was confirmed, not ambient).  Ops without a registered
    retimer (the multi-second emulation records) pass through unchanged —
    re-running those would double the bench wall time."""
    import statistics
    by_op = {r["op"]: r for r in records}
    confirmed = []
    for reg in regressions:
        retime = retimers.get(reg["op"])
        if retime is None:
            confirmed.append(reg)
            continue
        med = statistics.median([retime() for _ in range(3)])
        best = round(min(reg["after_us"], med), 2)
        rec = by_op.get(reg["op"])
        if rec is not None:
            rec["us_per_call"] = best
        if best > threshold * reg["before_us"]:
            confirmed.append(dict(reg, after_us=best,
                                  ratio=round(best / reg["before_us"], 2),
                                  retimed=True))
        else:
            print(f"# retime cleared {reg['op']}: flagged "
                  f"{reg['after_us']:.1f} us, median-of-3 {med:.1f} us "
                  f"(baseline {reg['before_us']:.1f} us)", file=sys.stderr)
    return confirmed


def _dump_kernel_records(ok: set | None = None) -> None:
    try:
        from benchmarks import kernel_bench
        records = list(kernel_bench.RECORDS)
        retimers = dict(kernel_bench.RETIMERS)
    except Exception:  # pragma: no cover - harness robustness
        return
    if not records:
        return
    # fold in the traffic-replay records (PR 9) only when that module ran
    # to completion — partial records must not masquerade as a baseline
    if ok is None or "benchmarks.traffic_replay" in ok:
        try:
            from benchmarks import traffic_replay
            records += traffic_replay.RECORDS
            retimers.update(traffic_replay.RETIMERS)
        except Exception:  # pragma: no cover - harness robustness
            pass
    try:
        previous = json.loads(BENCH_JSON.read_text())
    except Exception:
        previous = None
    regressions = harden_regressions(diff_records(previous, records),
                                     records, retimers)
    for reg in regressions:
        print(f"# PERF REGRESSION {reg['op']}: {reg['before_us']:.1f} us -> "
              f"{reg['after_us']:.1f} us ({reg['ratio']}x)", file=sys.stderr)
    host = host_fingerprint()
    notes = dict(SPEEDUP_NOTES, regressions=regressions, host=host)
    payload = {"records": records, "notes": notes}
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# host: {json.dumps(host, sort_keys=True)}", file=sys.stderr)
    print(f"# wrote {BENCH_JSON.name} ({len(records)} records, "
          f"{len(regressions)} regressions)", file=sys.stderr)


def _run_slow_gate() -> bool:
    """Exercise the `slow`-, `faults`- and `backends`-marked tests tier-1
    deselects."""
    if os.environ.get("BENCH_SKIP_SLOW"):
        print("# slow-test gate skipped (BENCH_SKIP_SLOW)", file=sys.stderr)
        return True
    repo = pathlib.Path(__file__).resolve().parent.parent
    cmd = [sys.executable, "-m", "pytest", "-q", "-m",
           "slow or faults or backends", "-o", "addopts=", "tests"]
    print(f"# slow-test gate: {' '.join(cmd[2:])}", file=sys.stderr)
    res = subprocess.run(cmd, cwd=repo)
    return res.returncode in (0, 5)  # 5: no slow tests collected


def _run_quick() -> int:
    """``--quick``: the subsecond ``kernel/*`` subset only, diffed against
    the committed ``BENCH_kernels.json`` with the same retime-hardened
    regression gate as a full run.  Never rewrites the baseline (a partial
    record set must not masquerade as one) and skips the slow-test gate —
    a CI pre-check that finishes in seconds."""
    from benchmarks import kernel_bench, traffic_replay
    print("name,us_per_call,derived")
    try:
        for line in kernel_bench.run_quick():
            print(line)
        # PR 9: the sub-second traffic-replay smoke rides along — it gates
        # the router-beats-round-robin claim and the accounting identities
        for line in traffic_replay.run_quick():
            print(line)
    except Exception:  # pragma: no cover - harness robustness
        traceback.print_exc(file=sys.stderr)
        return 1
    records = kernel_bench.RECORDS + traffic_replay.RECORDS
    retimers = dict(kernel_bench.RETIMERS, **traffic_replay.RETIMERS)
    try:
        previous = json.loads(BENCH_JSON.read_text())
    except Exception:
        previous = None
    regressions = harden_regressions(
        diff_records(previous, records), records, retimers)
    for reg in regressions:
        print(f"# PERF REGRESSION {reg['op']}: {reg['before_us']:.1f} us -> "
              f"{reg['after_us']:.1f} us ({reg['ratio']}x)", file=sys.stderr)
    print(f"# host: {json.dumps(host_fingerprint(), sort_keys=True)}",
          file=sys.stderr)
    print(f"# quick mode: {len(records)} records "
          f"diffed, {len(regressions)} regressions; baseline not "
          f"rewritten", file=sys.stderr)
    return 0


def _run_only(prefix: str) -> int:
    """``--only <record-prefix>``: re-time just the matching retimer-backed
    records (median of 3 fresh measurements through
    ``kernel_bench.RETIMERS``) and diff them against the committed
    baseline — the same retime-hardened gate semantics as ``--quick``,
    without the figure modules, the multi-second emulation records or the
    slow-test gate.  Never rewrites the baseline (a partial record set
    must not masquerade as one)."""
    import statistics

    from benchmarks import kernel_bench, traffic_replay
    from benchmarks.common import row
    try:
        # building the quick rows registers the retimers (and runs their
        # correctness gates); their first-pass timings are discarded —
        # only the fresh medians below are reported
        kernel_bench.run_quick()
        traffic_replay.run_quick()
    except Exception:  # pragma: no cover - harness robustness
        traceback.print_exc(file=sys.stderr)
        return 1
    retimers = dict(kernel_bench.RETIMERS, **traffic_replay.RETIMERS)
    matching = {op: rt for op, rt in retimers.items()
                if op.startswith(prefix)}
    if not matching:
        print(f"# --only {prefix!r} matches no retimer-backed record; "
              f"available: {', '.join(sorted(retimers))}",
              file=sys.stderr)
        return 1
    try:
        previous = json.loads(BENCH_JSON.read_text())
    except Exception:
        previous = None
    prev = {r["op"]: r.get("us_per_call", 0.0)
            for r in (previous or {}).get("records", [])}
    print("name,us_per_call,derived")
    records = []
    for op in sorted(matching):
        med = statistics.median([matching[op]() for _ in range(3)])
        records.append({"op": op, "us_per_call": round(med, 2)})
        base = prev.get(op, 0.0)
        print(row(op, med, f"baseline {base:.1f} us" if base
                 else "no baseline record"))
    regressions = diff_records(previous, records)
    for reg in regressions:
        print(f"# PERF REGRESSION {reg['op']}: {reg['before_us']:.1f} us -> "
              f"{reg['after_us']:.1f} us ({reg['ratio']}x)", file=sys.stderr)
    print(f"# host: {json.dumps(host_fingerprint(), sort_keys=True)}",
          file=sys.stderr)
    print(f"# only mode ({prefix!r}): {len(records)} records re-timed "
          f"(median of 3), {len(regressions)} regressions; baseline not "
          f"rewritten", file=sys.stderr)
    return 0


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="benchmark harness; see module docstring")
    ap.add_argument("--quick", action="store_true",
                    help="subsecond kernel/* subset with the same "
                         "diff-vs-baseline regression gate; no baseline "
                         "rewrite, no slow-test gate")
    ap.add_argument("--only", metavar="RECORD_PREFIX", default=None,
                    help="re-time just the records matching this prefix "
                         "(e.g. 'kernel/f32' or 'emulation/csr') through "
                         "kernel_bench.RETIMERS, median of 3, diffed "
                         "against the baseline; never rewrites it")
    args = ap.parse_args()
    if args.quick and args.only:
        ap.error("--quick and --only are mutually exclusive")
    if args.only:
        sys.exit(_run_only(args.only))
    if args.quick:
        sys.exit(_run_quick())
    print("name,us_per_call,derived")
    failures = 0
    ok = set()
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            for line in mod.run():
                print(line)
            ok.add(modname)
        except Exception as e:  # pragma: no cover - harness robustness
            failures += 1
            print(f"{modname},0,ERROR", file=sys.stdout)
            if type(e).__name__ == "BenchBaselineError":
                # diagnosable baseline problem (sched_breakdown): the
                # message names the fix — and THIS run refreshes the
                # baseline below (kernel_bench runs after), so a rerun
                # passes; no traceback needed
                print(f"# {modname}: {e}", file=sys.stderr)
            else:
                traceback.print_exc(file=sys.stderr)
    # only persist a baseline from a complete kernel_bench run — a partial
    # RECORDS list would masquerade as a full perf baseline
    if "benchmarks.kernel_bench" in ok:
        _dump_kernel_records(ok)
    if not _run_slow_gate():
        print("# slow-test gate FAILED", file=sys.stderr)
        failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
