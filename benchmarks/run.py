"""Benchmark harness — one module per paper table/figure, plus kernel and
LM-architecture benches.  Prints ``name,us_per_call,derived`` CSV and dumps
the kernel/emulation rows to ``BENCH_kernels.json`` (a machine-readable
perf baseline: op, shape, wall-time, plane-count scaling) so later PRs can
compare against this one."""
from __future__ import annotations

import importlib
import json
import pathlib
import sys
import traceback

MODULES = [
    "benchmarks.fig13_latency_by_layer",
    "benchmarks.fig14_breakdown",
    "benchmarks.fig15_total_latency",
    "benchmarks.fig16_throughput_batch",
    "benchmarks.tab3_energy",
    "benchmarks.tab4_cache_scaling",
    "benchmarks.kernel_bench",
    "benchmarks.lm_neural_cache",
]

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernels.json"

# Measured on the CI container for this PR (word-packed bit-plane engine
# vs the per-lane uint8 seed emulation); kept as provenance next to the
# fresh numbers dumped on every run.
SPEEDUP_NOTES = {
    "emulation_engine": "packed 32-lane uint32 words, numpy fast path / "
                        "lax.scan traced path",
    "emulation_suite_seed_s": 14.45,   # pytest tests/test_nc_layers.py @ seed
    "emulation_suite_now_s": 2.5,      # same module, packed engine
    "emulation_speedup_vs_seed": 5.8,  # wall; per-op bodies are >20x
}


def _dump_kernel_records() -> None:
    try:
        from benchmarks import kernel_bench
        records = kernel_bench.RECORDS
    except Exception:  # pragma: no cover - harness robustness
        return
    if not records:
        return
    payload = {"records": records, "notes": SPEEDUP_NOTES}
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {BENCH_JSON.name} ({len(records)} records)",
          file=sys.stderr)


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    ok = set()
    for modname in MODULES:
        try:
            mod = importlib.import_module(modname)
            for line in mod.run():
                print(line)
            ok.add(modname)
        except Exception:  # pragma: no cover - harness robustness
            failures += 1
            print(f"{modname},0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    # only persist a baseline from a complete kernel_bench run — a partial
    # RECORDS list would masquerade as a full perf baseline
    if "benchmarks.kernel_bench" in ok:
        _dump_kernel_records()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
