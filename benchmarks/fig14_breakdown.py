"""Figure 14: Neural Cache inference latency breakdown."""
from benchmarks.common import row, sim
from repro.core.simulator import PAPER


def run() -> list[str]:
    r = sim()
    rows = []
    for key, frac in r.breakdown().items():
        rows.append(
            row(f"fig14/{key}", frac * r.latency_s * 1e6,
                f"{frac*100:.2f}%% of total (paper {PAPER['breakdown'][key]*100:.2f}%%)")
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
