"""Figure-13-style per-layer latency breakdown FROM THE SCHEDULE, plus the
throughput-vs-batch sweep (Figure 16 shape) validated against the paper's
headline.

Both tables are priced off one :class:`~repro.core.schedule.NetworkSchedule`
— the same plan object the packed-engine emulation and the serving engine
execute — so the breakdown columns (filter/input/output/mac/reduce/quant)
and the batching curve cannot drift from what actually runs.  The sweep
raises if the scaling shape breaks (non-monotone, or the plateau leaves the
paper's 604 inf/s by more than 10%), making this module a perf-model gate,
not just a printer."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import row
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.schedule import plan_network
from repro.core.simulator import PAPER, simulate_network, throughput
from repro.models.inception import inception_v3_specs

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def run() -> list[str]:
    specs = inception_v3_specs()
    schedule = plan_network(specs, XEON_E5_35MB, batch=64)
    r = simulate_network(schedule)
    rows = []

    # per-block latency with the Figure-14 component split, per layer plan
    per_block = defaultdict(lambda: defaultdict(float))
    for l in r.layers:
        b = per_block[l.spec.block]
        b["filter"] += l.filter_s
        b["input"] += l.input_s
        b["output"] += l.output_s
        b["mac"] += l.mac_s
        b["reduce"] += l.reduce_s
        b["quant"] += l.quant_s
        b["pool"] += l.pool_s
    for block, parts in per_block.items():
        total = sum(parts.values())
        split = " ".join(f"{k}={v / total:.0%}" for k, v in parts.items()
                         if v / total >= 0.005)
        rows.append(row(f"sched13/{block}", total * 1e6, split))
    rows.append(row("sched13/TOTAL", r.latency_s * 1e6,
                    f"filters loaded once/batch: "
                    f"{r.filter_bytes_loaded / 1e6:.1f} MB"))

    # throughput-vs-batch sweep off the same schedule's spill decisions
    tps = [throughput(r, b) for b in BATCHES]
    for b, tp in zip(BATCHES, tps):
        rows.append(row(f"sched13/throughput_batch_{b}", 1e6 / tp,
                        f"{tp:.1f} inf/s (dual socket)"))
    # shape validation: monotone ramp to a plateau at the paper's headline
    if not all(b >= a for a, b in zip(tps, tps[1:])):
        raise RuntimeError(f"throughput-vs-batch not monotone: {tps}")
    plateau = tps[BATCHES.index(64)]
    err = abs(plateau - PAPER["nc_throughput"]) / PAPER["nc_throughput"]
    if err > 0.10:
        raise RuntimeError(
            f"batch-64 plateau {plateau:.1f} inf/s deviates {err:.1%} from "
            f"the paper's {PAPER['nc_throughput']}")
    if tps[-1] - plateau > 0.05 * plateau:
        raise RuntimeError("no plateau: batch 256 still gaining >5%")
    rows.append(row("sched13/throughput_shape", 0.0,
                    f"monotone, plateau {plateau:.1f} inf/s "
                    f"({err:.1%} vs paper), spill "
                    f"{schedule.spill_bytes_per_image / 1e6:.2f} MB/img"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
