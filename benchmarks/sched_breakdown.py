"""Figure-13-style per-layer latency breakdown FROM THE SCHEDULE, plus the
throughput-vs-batch sweep (Figure 16 shape) validated against the paper's
headline, plus the dense-vs-sparse cycle breakdown of the sparsity-aware
scheduler (fixed 50% filter pruning of the full paper network), plus the
SLO admission curve: predicted latency-vs-batch from the cycle model
(core/slo.py) next to the throughput curve, and the batch the admission
policy would pick per SLO budget, plus the overlap-on/off per-block table
(the PR 6 double-buffered pipeline's hidden-latency credit, gated both
modeled — per-layer overlapped <= serial — and measured, against the
serial/overlapped record pair in ``BENCH_kernels.json``).

All tables are priced off :class:`~repro.core.schedule.NetworkSchedule`
objects — the same plan the packed-engine emulation and the serving engine
execute — so the breakdown columns (filter/input/output/mac/reduce/quant),
the batching curve and the sparse credits cannot drift from what actually
runs.  The module raises if a shape breaks (non-monotone throughput,
plateau off the paper's 604 inf/s by >10%, a sparse layer whose modeled
cycles do not drop by the skipped-pass credit exactly, a predicted latency
curve that is not strictly increasing in the batch, or an SLO-chosen batch
past ``stream_batch_limit``), making it a perf-model gate, not just a
printer.  The compressed-residency section (PR 8) gates the CSR
bit-plane filter store on the full paper network: per-layer residency
credit exactness, ``stream_batch_limit`` strictly raised over the dense
plan (1 -> 2 at 50% pruning — every limit-1 stem bottleneck must stage
deeper), and the SLO-chosen batch actually following the raised ceiling.

The emulation-side SLO table calibrates its latency model from the
measured batch wall time recorded in ``BENCH_kernels.json``
(``emulation/nc_forward_b4_pruned50_dense``); a missing or stale-schema
baseline fails the run with a diagnosable message (exit 2 from the CLI,
``BenchBaselineError`` from :func:`run`) instead of a bare traceback —
regenerate with ``python -m benchmarks.run``."""
from __future__ import annotations

import json
import pathlib
from collections import defaultdict

from benchmarks.common import row
from benchmarks.run import BENCH_JSON  # one source for the baseline path
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.schedule import plan_network, prune_occupancy
from repro.core.simulator import (PAPER, modeled_layer_cycles,
                                  simulate_network, throughput)
from repro.core.slo import AdmissionPolicy, LatencyModel
from repro.models.inception import inception_v3_specs, reduced_config

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
PRUNE = 0.5  # the fixed dense-vs-sparse comparison point
SLO_BUDGETS_MS = (5, 10, 20, 50, 100)  # paper-scale (modeled hardware time)
SLO_BUDGETS_EMU_S = (1, 2, 4, 8)  # emulation wall-clock budgets
CALIBRATION_OP = "emulation/nc_forward_b4_pruned50_dense"  # batch-4 wall
# serial-vs-overlapped measured pair the overlap gate reads (kernel_bench
# records both on the batch-4 reduced config, logits asserted identical)
OVERLAP_OPS = ("emulation/nc_forward_b4_serial",
               "emulation/nc_forward_b4_overlap")
REQUIRED_OPS = (CALIBRATION_OP,) + OVERLAP_OPS


class BenchBaselineError(RuntimeError):
    """BENCH_kernels.json missing or not the expected schema."""


def load_bench_baseline(path: pathlib.Path = BENCH_JSON) -> dict:
    """Load the perf baseline, mapping op name -> us_per_call.

    Raises :class:`BenchBaselineError` with an actionable message when the
    file is absent or its schema is stale (no ``records`` list of
    ``{op, us_per_call}`` entries, or the calibration record the SLO table
    needs is gone) — the bench gate's failure mode must name its cause,
    not dump a KeyError traceback."""
    if not path.exists():
        raise BenchBaselineError(
            f"{path.name} not found at {path} — the perf baseline is "
            f"written by `python -m benchmarks.run`; run it once to "
            f"regenerate")
    try:
        payload = json.loads(path.read_text())
    except ValueError as e:
        raise BenchBaselineError(
            f"{path.name} is not valid JSON ({e}) — regenerate with "
            f"`python -m benchmarks.run`") from e
    records = payload.get("records") if isinstance(payload, dict) else None
    if not isinstance(records, list) or not all(
            isinstance(r, dict) and "op" in r and "us_per_call" in r
            for r in records):
        raise BenchBaselineError(
            f"{path.name} has a stale schema (expected a dict with a "
            f"'records' list of {{op, us_per_call}} entries) — regenerate "
            f"with `python -m benchmarks.run`")
    by_op = {r["op"]: float(r["us_per_call"]) for r in records}
    missing = [op for op in REQUIRED_OPS if op not in by_op]
    if missing:
        raise BenchBaselineError(
            f"{path.name} lacks the {missing} record(s) the SLO "
            f"calibration and overlap gate need — regenerate with "
            f"`python -m benchmarks.run`")
    return by_op


def run() -> list[str]:
    specs = inception_v3_specs()
    schedule = plan_network(specs, XEON_E5_35MB, batch=64)
    r = simulate_network(schedule)
    rows = []

    # per-block latency with the Figure-14 component split, per layer plan
    per_block = defaultdict(lambda: defaultdict(float))
    for l in r.layers:
        b = per_block[l.spec.block]
        b["filter"] += l.filter_s
        b["input"] += l.input_s
        b["output"] += l.output_s
        b["mac"] += l.mac_s
        b["reduce"] += l.reduce_s
        b["quant"] += l.quant_s
        b["pool"] += l.pool_s
    for block, parts in per_block.items():
        total = sum(parts.values())
        split = " ".join(f"{k}={v / total:.0%}" for k, v in parts.items()
                         if v / total >= 0.005)
        rows.append(row(f"sched13/{block}", total * 1e6, split))
    rows.append(row("sched13/TOTAL", r.latency_s * 1e6,
                    f"filters loaded once/batch: "
                    f"{r.filter_bytes_loaded / 1e6:.1f} MB"))

    # throughput-vs-batch sweep off the same schedule's spill decisions
    tps = [throughput(r, b) for b in BATCHES]
    for b, tp in zip(BATCHES, tps):
        rows.append(row(f"sched13/throughput_batch_{b}", 1e6 / tp,
                        f"{tp:.1f} inf/s (dual socket)"))
    # shape validation: monotone ramp to a plateau at the paper's headline
    if not all(b >= a for a, b in zip(tps, tps[1:])):
        raise RuntimeError(f"throughput-vs-batch not monotone: {tps}")
    plateau = tps[BATCHES.index(64)]
    err = abs(plateau - PAPER["nc_throughput"]) / PAPER["nc_throughput"]
    if err > 0.10:
        raise RuntimeError(
            f"batch-64 plateau {plateau:.1f} inf/s deviates {err:.1%} from "
            f"the paper's {PAPER['nc_throughput']}")
    if tps[-1] - plateau > 0.05 * plateau:
        raise RuntimeError("no plateau: batch 256 still gaining >5%")
    rows.append(row("sched13/throughput_shape", 0.0,
                    f"monotone, plateau {plateau:.1f} inf/s "
                    f"({err:.1%} vs paper), spill "
                    f"{schedule.spill_bytes_per_image / 1e6:.2f} MB/img"))

    # dense-vs-sparse modeled cycles per layer: the sparsity-aware scheduler
    # on the FULL paper network with a fixed 50% filter pruning (per-block
    # rows; exactness asserted per layer)
    occ = prune_occupancy(specs, PRUNE)
    sparse = plan_network(specs, XEON_E5_35MB, batch=64, occupancy=occ)
    per_block = defaultdict(lambda: [0.0, 0.0, 0])
    for pd, ps in zip(schedule.layers, sparse.layers):
        md = modeled_layer_cycles(pd)
        ms = modeled_layer_cycles(ps)
        if md["total_cycles"] - ms["total_cycles"] != ms["skip_credit_cycles"]:
            raise RuntimeError(
                f"{pd.spec.name}: sparse modeled cycles off the skipped-pass "
                f"credit ({md['total_cycles']} - {ms['total_cycles']} != "
                f"{ms['skip_credit_cycles']})")
        b = per_block[pd.spec.block]
        b[0] += md["total_cycles"]
        b[1] += ms["total_cycles"]
        b[2] += ms["skipped_passes"]
    for block, (cd, cs, skipped) in per_block.items():
        rows.append(row(f"sparsity/{block}", cd - cs,
                        f"dense {cd:.0f} -> sparse {cs:.0f} cycles "
                        f"({skipped} passes skipped at {PRUNE:.0%} pruning)"))
    total_d = sum(v[0] for v in per_block.values())
    total_s = sum(v[1] for v in per_block.values())
    rows.append(row("sparsity/TOTAL", total_d - total_s,
                    f"modeled cycles {total_d:.0f} -> {total_s:.0f} "
                    f"({1 - total_s / total_d:.1%} credited), filter bytes "
                    f"{schedule.filter_bytes_loaded / 1e6:.1f} -> "
                    f"{sparse.filter_bytes_loaded / 1e6:.1f} MB, "
                    f"{sparse.skipped_passes} passes/img skipped"))
    rows.extend(_compression_rows(specs))
    rows.extend(_overlap_rows(specs, r))
    rows.extend(_slo_rows(specs))
    return rows


def _compression_rows(specs) -> list[str]:
    """Compressed-residency table on the FULL paper network (PR 8),
    fixed 50% pruning at batch 64.  Gates:

    * per-layer exactness — sparse minus compressed modeled time must
      equal the residency credit to 1e-12 for every layer (the simulator
      prices compression as an exact additive credit, nothing else moves);
    * the network ``stream_batch_limit`` must be STRICTLY higher under
      compression (today's full-network limit is 1 — the stem's staged
      activations fill the reserved way; the compressed staging rule
      spills those outputs per image and stages the per-pass filter chunk
      instead), and every stem layer that was a limit-1 bottleneck must
      individually stage deeper;
    * the SLO-chosen batch at the widest budget must actually follow the
      raised ceiling — higher than the dense-planned choice and never
      past the compressed limit."""
    occ = prune_occupancy(specs, PRUNE)
    dense = plan_network(specs, XEON_E5_35MB, batch=64)
    sparse = plan_network(specs, XEON_E5_35MB, batch=64, occupancy=occ)
    comp = plan_network(specs, XEON_E5_35MB, batch=64, occupancy=occ,
                        compressed=True)
    rows = []
    rs, rc = simulate_network(sparse), simulate_network(comp)
    for ls, lc in zip(rs.layers, rc.layers):
        if abs((ls.total_s - lc.total_s) - lc.residency_credit_s) > 1e-12:
            raise RuntimeError(
                f"{ls.spec.name}: compressed modeled time off the "
                f"residency credit ({ls.total_s} - {lc.total_s} != "
                f"{lc.residency_credit_s})")
    ratio = comp.filter_bytes_loaded / dense.filter_bytes_loaded
    rows.append(row(
        "compression/residency", comp.residency_credit_bytes,
        f"filter bytes {dense.filter_bytes_loaded / 1e6:.1f} -> "
        f"{comp.filter_bytes_loaded / 1e6:.1f} MB resident "
        f"({ratio:.3f}x dense at {PRUNE:.0%} pruning); credit vs the "
        f"sparse dense-store plan {rc.residency_credit_s * 1e6:.1f} "
        f"us/batch (negative = CSR index overhead with all 8 bit-planes "
        f"live)"))

    d_limit, c_limit = dense.stream_batch_limit, comp.stream_batch_limit
    if c_limit <= d_limit:
        raise RuntimeError(
            f"compression gate: stream_batch_limit {c_limit} not raised "
            f"over the dense plan's {d_limit} on the full paper network — "
            f"the compressed staging rule stopped lifting the §VI-C "
            f"ceiling")
    io_way = XEON_E5_35MB.io_way_bytes
    for pd, pc in zip(dense.layers, comp.layers):
        if pd.spec.block or pd.spec.kind not in ("conv", "fc"):
            continue  # stem only: today's limit-1 bottleneck layers
        legacy = pd.input_bytes_per_image + pd.output_bytes_per_image
        if max(1, io_way // legacy) > 1:
            continue
        packed = (pc.input_bytes_per_image
                  + (0 if pc.spill_to_dram else pc.output_bytes_per_image)
                  + pc.filter_bytes_per_pass)
        if max(1, io_way // min(legacy, packed)) <= 1:
            raise RuntimeError(
                f"compression gate: stem bottleneck {pd.spec.name} still "
                f"stages only 1 image under compression")
    rows.append(row("compression/stream_limit", c_limit,
                    f"stream_batch_limit {d_limit} -> {c_limit} "
                    f"(stem spills outputs per image, stages compressed "
                    f"filter chunks instead)"))

    # the raised ceiling must reach the SLO admission policy
    model_d = LatencyModel(
        lambda b: plan_network(specs, XEON_E5_35MB, batch=b))
    model_c = LatencyModel(
        lambda b: plan_network(specs, XEON_E5_35MB, batch=b,
                               occupancy=occ, compressed=True))
    budget_s = max(SLO_BUDGETS_MS) / 1e3
    n_d = AdmissionPolicy(model_d, budget_s,
                          max_batch=max(BATCHES)).target_batch(budget_s)
    n_c = AdmissionPolicy(model_c, budget_s,
                          max_batch=max(BATCHES)).target_batch(budget_s)
    if n_c > model_c.stream_batch_limit:
        raise RuntimeError(
            f"compression gate: SLO-chosen batch {n_c} exceeds the "
            f"compressed stream_batch_limit {model_c.stream_batch_limit}")
    if n_c <= n_d:
        raise RuntimeError(
            f"compression gate: SLO-chosen batch under compression "
            f"({n_c}) does not exceed the dense choice ({n_d}) at "
            f"{max(SLO_BUDGETS_MS)} ms — the raised ceiling never "
            f"reached the admission policy")
    rows.append(row(
        "compression/slo_batch", n_c,
        f"SLO-chosen batch {n_d} -> {n_c} at {max(SLO_BUDGETS_MS)} ms "
        f"(p99 {model_c.predict_p99_s(n_c) * 1e3:.2f} ms, compressed "
        f"stream limit {model_c.stream_batch_limit})"))
    return rows


def _overlap_rows(specs, rs) -> list[str]:
    """Overlap-on/off per-block table: the hidden-latency credit of the
    double-buffered plan on the FULL paper network at batch 64.

    Gates (the PR 6 acceptance criteria):

    * every layer's overlapped modeled time (``total_s - hidden_s``) must
      be <= its serial time — overlap re-prices the filter load, never the
      compute, so a layer that got slower means the credit went negative;
    * the total hidden credit must be nonzero (the §IV-E headroom rule
      grants overlap on most paper layers; zero means the legality
      decision broke);
    * the batch-64 identity ``batch_time_s(overlap) == batch_time_s(serial)
      - hidden_s`` must hold — the credit the serving ``LatencyModel``
      calibrates against is exactly the per-layer sum;
    * the MEASURED pair from ``BENCH_kernels.json`` (batch-4 reduced
      stem, recorded by kernel_bench with logits asserted identical) must
      keep overlapped wall within ``overlap_wall_slack()`` of serial —
      no-loss with real core parallelism, parity-within-noise on a
      single-core container (the model's floor for the measured win is
      zero either way: overlap re-times the copies, never the computed
      values), so a baseline where the double buffer became a cost fails
      the run."""
    import math

    from benchmarks.common import overlap_wall_slack
    from repro.core.simulator import batch_time_s

    ov = plan_network(specs, XEON_E5_35MB, batch=64, overlap=True)
    ro = simulate_network(ov)
    rows = []
    per_block = defaultdict(lambda: [0.0, 0.0, 0])
    for ls, lo in zip(rs.layers, ro.layers):
        serial_t = ls.total_s
        ov_t = lo.total_s - lo.hidden_s
        if ov_t > serial_t + 1e-15:
            raise RuntimeError(
                f"{ls.spec.name}: overlapped modeled time {ov_t:.3e} s "
                f"exceeds serial {serial_t:.3e} s — negative hidden credit")
        b = per_block[ls.spec.block]
        b[0] += serial_t
        b[1] += ov_t
        b[2] += 1 if lo.overlap else 0
    for block, (ts, to, n) in per_block.items():
        rows.append(row(f"overlap/{block}", (ts - to) * 1e6,
                        f"serial {ts * 1e3:.3f} -> overlapped "
                        f"{to * 1e3:.3f} ms/img ({n} layers "
                        f"double-buffered)"))
    hidden = ro.hidden_s
    if hidden <= 0.0:
        raise RuntimeError(
            "overlap hides no filter-load time on the paper network — the "
            "§IV-E headroom rule should grant most conv layers")
    bt_s, bt_o = batch_time_s(rs, 64), batch_time_s(ro, 64)
    if not math.isclose(bt_o, bt_s - hidden, rel_tol=1e-9):
        raise RuntimeError(
            f"batch-64 overlap identity broken: {bt_o} != {bt_s} - {hidden}")
    rows.append(row(
        "overlap/TOTAL", hidden * 1e6,
        f"hidden {hidden * 1e3:.3f} of {ro.filter_s * 1e3:.3f} ms filter "
        f"time ({ov.overlapped_layers}/{len(ov.layers)} layers); "
        f"latency {rs.latency_s * 1e3:.2f} -> "
        f"{ro.overlapped_latency_s * 1e3:.2f} ms/img, batch-64 "
        f"{bt_s * 1e3:.2f} -> {bt_o * 1e3:.2f} ms"))

    # measured gate: the recorded batch-4 reduced-config pair
    baseline = load_bench_baseline()
    ws = baseline[OVERLAP_OPS[0]] / 1e6
    wo = baseline[OVERLAP_OPS[1]] / 1e6
    slack = overlap_wall_slack()
    if wo > slack * ws:
        raise RuntimeError(
            f"measured overlapped wall {wo:.2f} s exceeds {slack:.2f}x "
            f"serial {ws:.2f} s in {BENCH_JSON.name} (batch-4 reduced "
            f"stem) — the double buffer became a cost")
    rows.append(row("overlap/measured_b4", (ws - wo) * 1e6,
                    f"serial {ws:.2f} -> overlapped {wo:.2f} s wall "
                    f"({ws / wo:.2f}x, batch-4 reduced stem on the "
                    f"1/4-scale array, logits byte-identical per "
                    f"kernel_bench gate, slack {slack:.2f}x)"))
    return rows


def _slo_rows(specs) -> list[str]:
    """Latency-vs-batch curve + SLO-chosen batch, both gated.

    Paper scale: the uncalibrated model predicts modeled hardware time;
    the curve must be strictly increasing in the batch (the admission
    policy bisects it) and the chosen batch can never pass the §VI-C
    ``stream_batch_limit`` (1 at paper scale — the stem's activations
    fill the reserved way, so SLO admission there runs single images and
    the spill cost inside the curve is what batching would pay).

    Emulation scale: a reduced-config model calibrated from the measured
    batch-4 wall time in ``BENCH_kernels.json`` shows the policy actually
    walking batch sizes as the budget grows."""
    rows = []
    model = LatencyModel(lambda b: plan_network(specs, XEON_E5_35MB, batch=b))
    lat = [model.predict_p99_s(b) for b in BATCHES]
    for b, l, p in zip(BATCHES, lat, (model.predict_s(b) for b in BATCHES)):
        rows.append(row(f"slo/latency_batch_{b}", l * 1e6,
                        f"predicted {p * 1e3:.2f} ms, p99 {l * 1e3:.2f} ms "
                        f"(modeled hardware time)"))
    if not all(b > a for a, b in zip(lat, lat[1:])):
        raise RuntimeError(
            f"predicted latency not strictly increasing in batch: {lat}")
    limit = model.stream_batch_limit
    chosen = []
    # NOTE: the policy's batch_cap already clamps to the stream limit, so
    # these raises are TRIPWIRES for cap-logic regressions, not live
    # checks: at paper scale (limit 1, budgets up to 100 ms) any future
    # change that drops the stream clamp from AdmissionPolicy.batch_cap
    # immediately picks a multi-image batch here and fails the gate.
    for ms in SLO_BUDGETS_MS:
        pol = AdmissionPolicy(model, ms / 1e3, max_batch=max(BATCHES))
        n = pol.target_batch(ms / 1e3)
        chosen.append(n)
        if n > limit:
            raise RuntimeError(
                f"SLO-chosen batch {n} exceeds stream_batch_limit {limit} "
                f"at {ms} ms")
        cmp = "<=" if model.predict_p99_s(n) <= ms / 1e3 else "> (floor: miss)"
        rows.append(row(f"slo/batch_for_slo_{ms}ms", n,
                        f"p99 {model.predict_p99_s(n) * 1e3:.2f} ms {cmp} "
                        f"{ms} ms budget (stream limit {limit})"))
    if chosen != sorted(chosen):
        raise RuntimeError(f"SLO-chosen batch not monotone in budget: "
                           f"{chosen}")

    # emulation-side: calibrate from the recorded batch-4 wall time
    baseline = load_bench_baseline()
    wall4_s = baseline[CALIBRATION_OP] / 1e6
    cfg = reduced_config()
    rspecs = inception_v3_specs(cfg)
    emu = LatencyModel(lambda b: plan_network(rspecs, XEON_E5_35MB, batch=b))
    emu.observe(4, wall4_s)
    rlimit = emu.stream_batch_limit
    rows.append(row("slo/calibration", emu.scale,
                    f"reduced-config wall/modeled x{emu.scale:.0f} from "
                    f"{CALIBRATION_OP} ({wall4_s:.2f} s at batch 4)"))
    prev = 0
    for s in SLO_BUDGETS_EMU_S:
        pol = AdmissionPolicy(emu, float(s), max_batch=64)
        n = pol.target_batch(float(s))
        if n > rlimit:
            raise RuntimeError(
                f"SLO-chosen batch {n} exceeds stream_batch_limit "
                f"{rlimit} at {s} s (emulation)")
        if n < prev:
            raise RuntimeError(
                f"emulation SLO-chosen batch not monotone in budget at "
                f"{s} s: {n} < {prev}")
        prev = n
        rows.append(row(f"slo/batch_for_slo_{s}s_emulated", n,
                        f"calibrated p99 {emu.predict_p99_s(n):.2f} s <= "
                        f"{s} s budget (stream limit {rlimit}, cap 64)"))
    return rows


if __name__ == "__main__":
    import sys
    try:
        print("\n".join(run()))
    except BenchBaselineError as e:
        print(f"sched_breakdown: error: {e}", file=sys.stderr)
        sys.exit(2)
