"""Figure-13-style per-layer latency breakdown FROM THE SCHEDULE, plus the
throughput-vs-batch sweep (Figure 16 shape) validated against the paper's
headline, plus the dense-vs-sparse cycle breakdown of the sparsity-aware
scheduler (fixed 50% filter pruning of the full paper network).

All tables are priced off :class:`~repro.core.schedule.NetworkSchedule`
objects — the same plan the packed-engine emulation and the serving engine
execute — so the breakdown columns (filter/input/output/mac/reduce/quant),
the batching curve and the sparse credits cannot drift from what actually
runs.  The module raises if a shape breaks (non-monotone throughput,
plateau off the paper's 604 inf/s by >10%, or a sparse layer whose modeled
cycles do not drop by the skipped-pass credit exactly), making it a
perf-model gate, not just a printer."""
from __future__ import annotations

from collections import defaultdict

from benchmarks.common import row
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.schedule import plan_network, prune_occupancy
from repro.core.simulator import (PAPER, modeled_layer_cycles,
                                  simulate_network, throughput)
from repro.models.inception import inception_v3_specs

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
PRUNE = 0.5  # the fixed dense-vs-sparse comparison point


def run() -> list[str]:
    specs = inception_v3_specs()
    schedule = plan_network(specs, XEON_E5_35MB, batch=64)
    r = simulate_network(schedule)
    rows = []

    # per-block latency with the Figure-14 component split, per layer plan
    per_block = defaultdict(lambda: defaultdict(float))
    for l in r.layers:
        b = per_block[l.spec.block]
        b["filter"] += l.filter_s
        b["input"] += l.input_s
        b["output"] += l.output_s
        b["mac"] += l.mac_s
        b["reduce"] += l.reduce_s
        b["quant"] += l.quant_s
        b["pool"] += l.pool_s
    for block, parts in per_block.items():
        total = sum(parts.values())
        split = " ".join(f"{k}={v / total:.0%}" for k, v in parts.items()
                         if v / total >= 0.005)
        rows.append(row(f"sched13/{block}", total * 1e6, split))
    rows.append(row("sched13/TOTAL", r.latency_s * 1e6,
                    f"filters loaded once/batch: "
                    f"{r.filter_bytes_loaded / 1e6:.1f} MB"))

    # throughput-vs-batch sweep off the same schedule's spill decisions
    tps = [throughput(r, b) for b in BATCHES]
    for b, tp in zip(BATCHES, tps):
        rows.append(row(f"sched13/throughput_batch_{b}", 1e6 / tp,
                        f"{tp:.1f} inf/s (dual socket)"))
    # shape validation: monotone ramp to a plateau at the paper's headline
    if not all(b >= a for a, b in zip(tps, tps[1:])):
        raise RuntimeError(f"throughput-vs-batch not monotone: {tps}")
    plateau = tps[BATCHES.index(64)]
    err = abs(plateau - PAPER["nc_throughput"]) / PAPER["nc_throughput"]
    if err > 0.10:
        raise RuntimeError(
            f"batch-64 plateau {plateau:.1f} inf/s deviates {err:.1%} from "
            f"the paper's {PAPER['nc_throughput']}")
    if tps[-1] - plateau > 0.05 * plateau:
        raise RuntimeError("no plateau: batch 256 still gaining >5%")
    rows.append(row("sched13/throughput_shape", 0.0,
                    f"monotone, plateau {plateau:.1f} inf/s "
                    f"({err:.1%} vs paper), spill "
                    f"{schedule.spill_bytes_per_image / 1e6:.2f} MB/img"))

    # dense-vs-sparse modeled cycles per layer: the sparsity-aware scheduler
    # on the FULL paper network with a fixed 50% filter pruning (per-block
    # rows; exactness asserted per layer)
    occ = prune_occupancy(specs, PRUNE)
    sparse = plan_network(specs, XEON_E5_35MB, batch=64, occupancy=occ)
    per_block = defaultdict(lambda: [0.0, 0.0, 0])
    for pd, ps in zip(schedule.layers, sparse.layers):
        md = modeled_layer_cycles(pd)
        ms = modeled_layer_cycles(ps)
        if md["total_cycles"] - ms["total_cycles"] != ms["skip_credit_cycles"]:
            raise RuntimeError(
                f"{pd.spec.name}: sparse modeled cycles off the skipped-pass "
                f"credit ({md['total_cycles']} - {ms['total_cycles']} != "
                f"{ms['skip_credit_cycles']})")
        b = per_block[pd.spec.block]
        b[0] += md["total_cycles"]
        b[1] += ms["total_cycles"]
        b[2] += ms["skipped_passes"]
    for block, (cd, cs, skipped) in per_block.items():
        rows.append(row(f"sparsity/{block}", cd - cs,
                        f"dense {cd:.0f} -> sparse {cs:.0f} cycles "
                        f"({skipped} passes skipped at {PRUNE:.0%} pruning)"))
    total_d = sum(v[0] for v in per_block.values())
    total_s = sum(v[1] for v in per_block.values())
    rows.append(row("sparsity/TOTAL", total_d - total_s,
                    f"modeled cycles {total_d:.0f} -> {total_s:.0f} "
                    f"({1 - total_s / total_d:.1%} credited), filter bytes "
                    f"{schedule.filter_bytes_loaded / 1e6:.1f} -> "
                    f"{sparse.filter_bytes_loaded / 1e6:.1f} MB, "
                    f"{sparse.skipped_passes} passes/img skipped"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
