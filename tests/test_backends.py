"""Cross-backend differential conformance for the bit-serial hot path (PR 10).

``core/backends.py`` turns the old ad-hoc ``engine="host"|"jit"`` strings
into ONE registry of :class:`~repro.core.backends.Backend` entries — the
exact numpy host walk (the reference), the bucketed-jit decoded-lane
kernel, and the byte-packed Pallas bit-serial GEMM run through the
interpreter.  This suite is the registry's contract, enforced
differentially:

* **Byte-identity** — every registered backend must reproduce the host
  reference EXACTLY across the operating envelope: 8/4/2/1-bit operands
  (the 4-bit case exercises the W4A4 nibble kernel), SAME/VALID padding,
  stride 2, batch 1 and 4, non-dividing tiles, compressed (CSR bit-plane)
  and dense filter stores, integrity checking on and off, and 0/50/100%
  filter pruning.
* **Cycle invariance** — backends re-time EXECUTION, never the model:
  ``packed_dot_words`` charges §III cycles before dispatch, so every
  conformance case also asserts the modeled cycles are bit-identical to
  the host run's.
* **Selection is configuration** — the backend rides the plan
  (``plan_layer(..., backend=...)``), the ``NC_BACKEND`` environment
  variable, or an explicit ``engine=``; contradictions raise, unknown
  names raise a :class:`ValueError` listing the registered backends, and
  switching needs zero call-site edits (asserted via
  ``backends.dispatch_stats``).
* **Compile-cache reuse** — the bucketed-jit backend compiles exactly
  once per (planes, acc, K) bucket even when the same shapes flow
  through DIFFERENT layers (``engine_cache_info`` reporting matches).

Tier-1 runs the host+jit conformance; the ``pallas-interpret``
parametrizations carry the ``backends`` marker (the interpreter is slow)
and run under benchmarks/run.py's gate or
``pytest -m backends -o addopts=``.
"""
import numpy as np
import pytest

from repro.core import backends
from repro.core import bitserial as bs
from repro.core import nc_layers as nc
from repro.core import quantize as q
from repro.core import schedule as sched
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.mapper import LayerSpec

GEOM = XEON_E5_35MB

# host and jit conformance is tier-1; the interpret-mode sweep runs under
# the `backends` marker (satellite: pytest.ini addopts excludes it)
BACKENDS = ["host", "jit",
            pytest.param("pallas-interpret", marks=pytest.mark.backends)]


def _quantized_conv_case(seed, *, bits=8, M=6, C=3, R=3, prune=0.0,
                         batch=1, img=8):
    """Already-quantized integer operands for one conv case: unsigned
    ``bits``-plane activations/weights, ``round(M * prune)`` filters
    pinned to the weight zero point (dequantized exactly zero)."""
    rng = np.random.default_rng(seed)
    hi = 1 << bits
    zw = hi // 2
    wq = rng.integers(0, hi, size=(R, R, C, M)).astype(np.uint8)
    k = int(round(M * prune))
    if k:
        idx = rng.choice(M, size=k, replace=False)
        wq[..., idx] = zw
    shape = (batch, img, img, C) if batch > 1 else (img, img, C)
    xq = rng.integers(0, hi, size=shape).astype(np.uint8)
    x_qp = q.QuantParams(scale=np.float32(1 / hi), zero_point=1, bits=bits)
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=zw, bits=bits)
    qps = [x_qp] * batch if batch > 1 else x_qp
    return xq, wq, qps, w_qp


# one row per envelope corner: bits x padding x stride x batch x ragged
# tiles x compressed x integrity x pruning (the cross product is curated,
# not exhaustive — every dimension varies at least twice)
CONV_CASES = [
    pytest.param(dict(bits=8), id="w8a8-valid-dense"),
    pytest.param(dict(bits=8, padding="SAME", stride=2, batch=4,
                      tile_pixels=7, prune=0.5), id="w8a8-same-s2-b4-ragged-p50"),
    pytest.param(dict(bits=8, batch=4, compressed=True, integrity=True,
                      tile_filters=5, prune=0.5), id="w8a8-b4-csr-abft-p50"),
    pytest.param(dict(bits=4), id="w4a4-valid-dense"),
    pytest.param(dict(bits=4, padding="SAME", stride=2, batch=4,
                      compressed=True, prune=0.5), id="w4a4-same-s2-b4-csr-p50"),
    pytest.param(dict(bits=2, integrity=True), id="w2a2-abft"),
    pytest.param(dict(bits=1, padding="SAME", batch=4, prune=0.5),
                 id="w1a1-same-b4-p50"),
    pytest.param(dict(bits=8, batch=4, prune=1.0), id="w8a8-b4-p100"),
]


def _run_conv(case, engine):
    kw = dict(case)
    xq, wq, qps, w_qp = _quantized_conv_case(
        0xC0FFEE, bits=kw.pop("bits"), prune=kw.pop("prune", 0.0),
        batch=kw.setdefault("batch", 1))
    kw.pop("batch")
    stride = kw.pop("stride", 1)
    out, cycles = nc.nc_conv2d(xq, wq, qps, w_qp, stride, geom=GEOM,
                               occupancy="detect", engine=engine, **kw)
    return np.asarray(out), cycles


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", CONV_CASES)
def test_conv_conformance(case, backend):
    """Differential harness: every backend == host, byte for byte, with
    modeled cycles bit-identical (backends re-time, never re-model)."""
    ref, ref_cycles = _run_conv(case, "host")
    backends.dispatch_stats_clear()
    out, cycles = _run_conv(case, backend)
    np.testing.assert_array_equal(out, ref)
    assert out.dtype == ref.dtype
    assert cycles == ref_cycles
    st = backends.dispatch_stats()[backend]
    if case.get("prune") != 1.0:  # fully pruned layers run zero passes
        assert st["native"] + st["fallback"] > 0  # the backend actually ran


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("batch", [1, 4])
def test_fc_conformance(backend, batch):
    """nc_fc (the 1x1-conv FC path) through every backend, K large enough
    (144) that the Pallas adapter runs natively (one row per word line)."""
    rng = np.random.default_rng(7)
    K, M = 144, 10
    x = rng.integers(0, 256, size=(batch, K) if batch > 1 else (K,))
    w = rng.integers(0, 256, size=(K, M)).astype(np.uint8)
    w[:, ::3] = 11  # a third of the filters prune to the zero point
    x_qp = q.QuantParams(scale=np.float32(1 / 256), zero_point=0)
    w_qp = q.QuantParams(scale=np.float32(0.02), zero_point=11)
    qps = [x_qp] * batch if batch > 1 else x_qp
    ref, ref_cycles = nc.nc_fc(x.astype(np.uint8), w, qps, w_qp,
                               occupancy="detect", engine="host")
    out, cycles = nc.nc_fc(x.astype(np.uint8), w, qps, w_qp,
                           occupancy="detect", engine=backend)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert cycles == ref_cycles


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bits_x,bits_w", [(8, 8), (4, 4), (2, 4), (1, 8)])
@pytest.mark.parametrize("K", [144, 37, 9])
def test_dot_words_conformance(backend, bits_x, bits_w, K):
    """The hot-path entry itself: packed word grids through
    ``packed_dot_words`` on every backend — values AND cycles must match
    the host body bit for bit (K=9 puts rows sharing words, where the
    Pallas adapter must delegate to host, still exactly)."""
    rng = np.random.default_rng(K * 100 + bits_x * 10 + bits_w)
    T, M = 13, 5
    xw = nc._pack_x_rows(
        rng.integers(0, 1 << bits_x, size=(T, K)).astype(np.uint32), bits_x)
    ww = nc._pack_w_rows(
        rng.integers(0, 1 << bits_w, size=(M, K)).astype(np.uint32), bits_w)
    ref, ref_cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=32,
                                          engine="host")
    vals, cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=32,
                                       engine=backend)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref))
    assert cycles == ref_cycles


def test_pallas_interpret_dot_smoke():
    """Tier-1 keepalive for the Pallas adapter (the full sweep is
    `backends`-marked): one native interpret-mode dot, byte-identical,
    and the dispatch ledger proves the kernel path ran (no silent
    fallback-to-host conformance theater)."""
    rng = np.random.default_rng(3)
    K = 64
    xw = nc._pack_x_rows(rng.integers(0, 16, size=(4, K)).astype(np.uint32), 4)
    ww = nc._pack_w_rows(rng.integers(0, 16, size=(3, K)).astype(np.uint32), 4)
    ref, ref_cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=32,
                                          engine="host")
    backends.dispatch_stats_clear()
    vals, cycles = bs.packed_dot_words(xw, ww, K=K, acc_bits=32,
                                       engine="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref))
    assert cycles == ref_cycles
    assert backends.dispatch_stats()["pallas-interpret"]["native"] == 1


# ---------------------------------------------------------------------------
# Satellite: unknown backend names raise, naming the registry
# ---------------------------------------------------------------------------
def test_unknown_engine_string_raises():
    rng = np.random.default_rng(0)
    xw = nc._pack_x_rows(rng.integers(0, 256, size=(2, 64)), 8)
    ww = nc._pack_w_rows(rng.integers(0, 256, size=(2, 64)), 8)
    with pytest.raises(ValueError) as ei:
        bs.packed_dot_words(xw, ww, K=64, acc_bits=32, engine="tpu-v9")
    msg = str(ei.value)
    assert "tpu-v9" in msg
    for name in backends.registered_backends():
        assert name in msg  # the error lists every registered backend


def test_unknown_engine_in_conv_raises():
    xq, wq, qps, w_qp = _quantized_conv_case(1)
    with pytest.raises(ValueError, match="registered backends"):
        nc.nc_conv2d(xq, wq, qps, w_qp, engine="cuda")


def test_unknown_env_backend_raises(monkeypatch):
    """The same ValueError surfaces from NC_BACKEND, attributed to the
    environment variable."""
    monkeypatch.setenv(backends.ENV_VAR, "no-such-backend")
    xq, wq, qps, w_qp = _quantized_conv_case(1)
    with pytest.raises(ValueError, match="NC_BACKEND environment"):
        nc.nc_conv2d(xq, wq, qps, w_qp)


def test_unknown_plan_backend_raises():
    spec = LayerSpec(name="c", kind="conv", H=8, R=3, S=3, C=3, M=6, E=6,
                     stride=1)
    with pytest.raises(ValueError, match="plan_layer"):
        sched.plan_layer(spec, GEOM, batch=1, backend="fpga")
    with pytest.raises(ValueError, match="plan_network"):
        sched.plan_network([spec], GEOM, batch=1, backend="fpga")


# ---------------------------------------------------------------------------
# Satellite: selection is pure configuration (plan pin / env var), with
# contradictions raised
# ---------------------------------------------------------------------------
def _conv_spec(M=6, C=3, R=3, img=8, stride=1):
    E = (img - R) // stride + 1
    return LayerSpec(name="c", kind="conv", H=img, R=R, S=R, C=C, M=M, E=E,
                     stride=stride)


def test_plan_backend_is_pure_config():
    """plan_layer(backend=...) routes execution with ZERO call-site edits:
    the same nc_conv2d call, no engine argument, runs whichever backend
    the plan pinned."""
    xq, wq, qps, w_qp = _quantized_conv_case(2)
    ref, ref_cycles = nc.nc_conv2d(xq, wq, qps, w_qp, engine="host")
    for name in ("jit", "host"):
        plan = sched.plan_layer(_conv_spec(), GEOM, batch=1, backend=name)
        assert plan.backend == name
        backends.dispatch_stats_clear()
        out, cycles = nc.nc_conv2d(xq, wq, qps, w_qp, plan=plan)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert cycles == ref_cycles
        assert backends.dispatch_stats()[name]["native"] > 0


def test_env_backend_is_pure_config(monkeypatch):
    """NC_BACKEND=jit flips the default engine with zero code changes."""
    xq, wq, qps, w_qp = _quantized_conv_case(3)
    ref, _ = nc.nc_conv2d(xq, wq, qps, w_qp, engine="host")
    monkeypatch.setenv(backends.ENV_VAR, "jit")
    backends.dispatch_stats_clear()
    out, _ = nc.nc_conv2d(xq, wq, qps, w_qp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert backends.dispatch_stats()["jit"]["native"] > 0
    assert backends.dispatch_stats()["host"]["native"] == 0


def test_explicit_engine_beats_env(monkeypatch):
    monkeypatch.setenv(backends.ENV_VAR, "jit")
    xq, wq, qps, w_qp = _quantized_conv_case(4)
    backends.dispatch_stats_clear()
    nc.nc_conv2d(xq, wq, qps, w_qp, engine="host")
    assert backends.dispatch_stats()["jit"]["native"] == 0
    assert backends.dispatch_stats()["host"]["native"] > 0


def test_engine_contradicting_plan_raises():
    xq, wq, qps, w_qp = _quantized_conv_case(5)
    plan = sched.plan_layer(_conv_spec(), GEOM, batch=1, backend="jit")
    with pytest.raises(ValueError, match="ambiguous"):
        nc.nc_conv2d(xq, wq, qps, w_qp, plan=plan, engine="host")
    # agreement is NOT ambiguous (nc_forward hands matched engine + plans
    # down the layer loop)
    out, _ = nc.nc_conv2d(xq, wq, qps, w_qp, plan=plan, engine="jit")
    ref, _ = nc.nc_conv2d(xq, wq, qps, w_qp, engine="host")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_backend_pin_survives_tile_override_replan():
    """Tile-size overrides replan but must not drop the plan's backend pin
    (same carry rule as sparsity/overlap/integrity/compression)."""
    xq, wq, qps, w_qp = _quantized_conv_case(6)
    plan = sched.plan_layer(_conv_spec(), GEOM, batch=1, backend="jit")
    backends.dispatch_stats_clear()
    nc.nc_conv2d(xq, wq, qps, w_qp, plan=plan, tile_pixels=7)
    assert backends.dispatch_stats()["jit"]["native"] > 0


# ---------------------------------------------------------------------------
# Satellite: bucketed-jit compile-cache reuse across layers and backends
# ---------------------------------------------------------------------------
def test_jit_compile_cache_one_entry_per_bucket():
    """Exactly ONE engine-cache entry (and one compiled executable) per
    (x planes, w planes, acc, K) bucket, even when the same shapes flow
    through a conv and an FC layer: conv 3x3x16 on a 5x5 image and a
    9-row FC over 144 features land on identical padded tile shapes
    (rows 9 -> bucket 16, filters 6 -> bucket 8)."""
    rng = np.random.default_rng(11)
    bs.engine_cache_clear()
    xq = rng.integers(0, 256, size=(5, 5, 16)).astype(np.uint8)
    wq = rng.integers(0, 256, size=(3, 3, 16, 6)).astype(np.uint8)
    x_qp = q.QuantParams(scale=np.float32(1 / 256), zero_point=0)
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=128)
    nc.nc_conv2d(xq, wq, x_qp, w_qp, engine="jit")
    info = bs.engine_cache_info()
    assert info["entries"] == 1
    assert info["keys"] == [(8, 8, 32, 144)]
    compiled_after_conv = info["compiled"]

    xf = rng.integers(0, 256, size=(9, 144)).astype(np.uint8)
    wf = rng.integers(0, 256, size=(144, 6)).astype(np.uint8)
    nc.nc_fc(xf, wf, [x_qp] * 9, w_qp, engine="jit")
    info = bs.engine_cache_info()
    assert info["entries"] == 1  # the FC reused the conv's bucket
    assert info["keys"] == [(8, 8, 32, 144)]
    # identical padded operand shapes -> the SAME executable served both
    # layers (``compiled`` is best-effort: 0 if jax hides _cache_size)
    assert info["compiled"] == compiled_after_conv

    # the host backend never touches the compile cache
    nc.nc_conv2d(xq, wq, x_qp, w_qp, engine="host")
    assert bs.engine_cache_info() == info


def test_engine_cache_distinct_buckets():
    """Different (planes, acc, K) tuples get their own entry — the cache
    key is the bucket, nothing finer."""
    rng = np.random.default_rng(12)
    bs.engine_cache_clear()
    for bits, K in ((8, 64), (4, 64), (8, 96)):
        xw = nc._pack_x_rows(
            rng.integers(0, 1 << bits, size=(8, K)).astype(np.uint32), bits)
        ww = nc._pack_w_rows(
            rng.integers(0, 1 << bits, size=(4, K)).astype(np.uint32), bits)
        bs.packed_dot_words(xw, ww, K=K, acc_bits=32, engine="jit")
        bs.packed_dot_words(xw, ww, K=K, acc_bits=32, engine="jit")  # reuse
    info = bs.engine_cache_info()
    assert info["entries"] == 3
    assert set(info["keys"]) == {(8, 8, 32, 64), (4, 4, 32, 64),
                                 (8, 8, 32, 96)}


# ---------------------------------------------------------------------------
# Registry surface: capability flags and dispatch accounting
# ---------------------------------------------------------------------------
def test_registry_capability_flags():
    assert backends.registered_backends() == ("host", "jit",
                                              "pallas-interpret")
    host = backends.get_backend("host")
    assert host.acc_bits is None and host.supports_acc(24)
    assert host.max_lane_words is None
    pal = backends.get_backend("pallas-interpret")
    assert pal.supports_acc(32) and pal.supports_acc(24)
    assert not pal.supports_acc(16)
    assert pal.w4a4 and pal.compressed_planes and pal.integrity
    assert pal.max_lane_words is not None
    for name in backends.registered_backends():
        assert callable(backends.get_backend(name).dot_words)


def test_dispatch_stats_count_fallbacks():
    """Inputs outside the Pallas native envelope (rows sharing words,
    K <= 16) delegate to host and are COUNTED — the conformance suite's
    proof that 'native' assertions mean what they say."""
    rng = np.random.default_rng(13)
    backends.dispatch_stats_clear()
    xw = nc._pack_x_rows(rng.integers(0, 256, size=(3, 9)), 8)
    ww = nc._pack_w_rows(rng.integers(0, 256, size=(2, 9)), 8)
    ref, _ = bs.packed_dot_words(xw, ww, K=9, acc_bits=32, engine="host")
    vals, _ = bs.packed_dot_words(xw, ww, K=9, acc_bits=32,
                                  engine="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref))
    st = backends.dispatch_stats()["pallas-interpret"]
    assert st == {"native": 0, "fallback": 1}


# ---------------------------------------------------------------------------
# Serving: backend names validated at deployment, calibration per backend
# ---------------------------------------------------------------------------
def test_serving_engine_backend_validation_and_switch():
    """NCServingEngine validates ``engine=`` against the registry at
    construction (a typo fails at deployment, not mid-traffic), and
    ``set_engine`` resets BOTH the priced-plan memo and the measured
    calibration — wall/modeled scale is a property of the execution body
    (docs/SERVING.md)."""
    import jax

    from repro.launch import serve
    from repro.models import inception

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    with pytest.raises(ValueError, match="registered backends"):
        serve.NCServingEngine(params, cfg, engine="warp-drive")

    eng = serve.NCServingEngine(params, cfg, engine="host")
    eng.latency_model.observe(1, 0.5)
    assert eng.latency_model.calibrated
    eng.set_engine("host")  # same backend: calibration survives
    assert eng.latency_model.calibrated
    eng.set_engine("jit")  # backend switch: recalibrate from scratch
    assert eng.engine == "jit"
    assert not eng.latency_model.calibrated
    assert eng.latency_model.scale == 1.0
    with pytest.raises(ValueError, match="registered backends"):
        eng.set_engine("warp-drive")


# ---------------------------------------------------------------------------
# Satellite: interpret-mode Pallas inside the full network (slow + backends)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.backends
def test_nc_forward_pallas_interpret_end_to_end():
    """One reduced-Inception forward routed through ``pallas-interpret``
    as a pure config change (``plan_network(backend=...)``): logits and
    modeled cycles byte-identical to the host run, with the dispatch
    ledger showing the Pallas kernel natively served the large-K layers
    (small-K layers legitimately delegate)."""
    import jax
    import jax.numpy as jnp

    from repro.models import inception

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    key = jax.random.PRNGKey(0)
    params = inception.init_params(key, config=cfg)
    x = jax.random.uniform(key, (47, 47, 3), jnp.float32)

    ref, ref_report = inception.nc_forward(params, x, config=cfg,
                                           engine="host")
    specs = inception.inception_v3_specs(cfg)
    schedule = sched.plan_network(specs, GEOM, batch=1,
                                  backend="pallas-interpret")
    assert schedule.backend == "pallas-interpret"
    backends.dispatch_stats_clear()
    out, report = inception.nc_forward(params, x, config=cfg,
                                       schedule=schedule)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert report.total_emulated_cycles == ref_report.total_emulated_cycles
    assert report.total_modeled_cycles == ref_report.total_modeled_cycles
    st = backends.dispatch_stats()["pallas-interpret"]
    assert st["native"] > 0

    # contradicting the schedule's pin raises (the plan already decided)
    with pytest.raises(ValueError, match="ambiguous"):
        inception.nc_forward(params, x, config=cfg, schedule=schedule,
                             engine="host")
