"""Pallas flash attention vs the naive oracle — shape/dtype/GQA sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels import ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,H,Hkv,Tq,Tk,D", [
    (1, 4, 4, 256, 256, 64),       # MHA square
    (2, 8, 2, 256, 512, 64),       # GQA, rectangular
    (1, 2, 1, 512, 512, 128),      # MQA, bigger head
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(B, H, Hkv, Tq, Tk, D, causal):
    if causal and Tq != Tk:
        pytest.skip("causal oracle assumes aligned ends")
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, H, Tq, D), jnp.float32)
    k = _rand(ks[1], (B, Hkv, Tk, D), jnp.float32)
    v = _rand(ks[2], (B, Hkv, Tk, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], (1, 2, 256, 64), dtype)
    k = _rand(ks[1], (1, 2, 256, 64), dtype)
    v = _rand(ks[2], (1, 2, 256, 64), dtype)
    out = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_flash_tile_shapes_sweep():
    ks = jax.random.split(jax.random.key(2), 3)
    q = _rand(ks[0], (1, 2, 512, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 512, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 512, 64), jnp.float32)
    want = ref.flash_attention_ref(q, k, v)
    for bq, bk in [(128, 128), (256, 128), (128, 512), (512, 512)]:
        out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=f"{bq},{bk}")
