"""Bit-exactness + cycle-formula tests for the in-SRAM arithmetic emulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitserial as bs

jax.config.update("jax_enable_x64", True)


def _rand(rng, n_bits, shape):
    return rng.integers(0, 1 << n_bits, size=shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# pack / unpack roundtrip
# ---------------------------------------------------------------------------
@given(
    n_bits=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(n_bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n_bits, (17,))
    planes = bs.bitplane_pack(jnp.asarray(x), n_bits)
    assert planes.shape == (n_bits, 17)
    back = np.asarray(bs.bitplane_unpack(planes))
    np.testing.assert_array_equal(back, x)


def test_pack_unpack_signed():
    x = jnp.asarray([-128, -1, 0, 1, 127], jnp.int32)
    planes = bs.bitplane_pack(x.astype(jnp.uint32) & 0xFF, 8)
    back = np.asarray(bs.bitplane_unpack(planes, signed=True))
    np.testing.assert_array_equal(back, np.asarray(x))


# ---------------------------------------------------------------------------
# addition (§III-B): bit-exact, n+1 cycles
# ---------------------------------------------------------------------------
@given(
    n_bits=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_add_exact(n_bits, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, n_bits, (64,)), _rand(rng, n_bits, (64,))
    pa, pb = bs.bitplane_pack(jnp.asarray(a), n_bits), bs.bitplane_pack(jnp.asarray(b), n_bits)
    out, cycles = bs.bitserial_add(pa, pb)
    assert cycles == n_bits + 1
    assert out.shape[0] == n_bits + 1
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(out)), a.astype(np.uint64) + b)


def test_add_mixed_width():
    pa = bs.bitplane_pack(jnp.asarray([250, 3], jnp.uint32), 8)
    pb = bs.bitplane_pack(jnp.asarray([7, 1], jnp.uint32), 3)
    out, cycles = bs.bitserial_add(pa, pb)
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(out)), [257, 4])
    assert cycles == 9


# ---------------------------------------------------------------------------
# subtraction: two's complement, sign plane correct
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_sub_exact(seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, 8, (64,)), _rand(rng, 8, (64,))
    pa, pb = bs.bitplane_pack(jnp.asarray(a), 8), bs.bitplane_pack(jnp.asarray(b), 8)
    out, cycles = bs.bitserial_sub(pa, pb)
    got = np.asarray(bs.bitplane_unpack(out, signed=True))
    np.testing.assert_array_equal(got, a.astype(np.int64) - b.astype(np.int64))
    assert cycles == 9


# ---------------------------------------------------------------------------
# multiplication (§III-C): bit-exact, n^2+5n-2 cycles
# ---------------------------------------------------------------------------
@given(
    n_bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_mul_exact(n_bits, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, n_bits, (32,)), _rand(rng, n_bits, (32,))
    pa, pb = bs.bitplane_pack(jnp.asarray(a), n_bits), bs.bitplane_pack(jnp.asarray(b), n_bits)
    out, cycles = bs.bitserial_multiply(pa, pb)
    assert cycles == n_bits * n_bits + 5 * n_bits - 2
    assert out.shape[0] == 2 * n_bits
    np.testing.assert_array_equal(
        np.asarray(bs.bitplane_unpack(out)), a.astype(np.uint64) * b.astype(np.uint64)
    )


def test_mul_paper_example_cycles():
    # §III-C: 8-bit multiply = 102 cycles; §VI-A quotes 236 cycles per 8-bit MAC
    assert bs.mul_cycles(8) == 102
    card = bs.OpCycles()
    assert card.mac_floor == 102 + 25
    assert card.mac8 == 236
    assert card.mac_overhead == 236 - 127


# ---------------------------------------------------------------------------
# MAC: acc += a*b with fixed accumulator width
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_mac_exact(seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, 8, (16,)), _rand(rng, 8, (16,))
    acc0 = _rand(rng, 20, (16,))
    acc = bs.bitplane_pack(jnp.asarray(acc0), 24)
    pa, pb = bs.bitplane_pack(jnp.asarray(a), 8), bs.bitplane_pack(jnp.asarray(b), 8)
    out, _ = bs.bitserial_mac(acc, pa, pb)
    want = (acc0.astype(np.uint64) + a.astype(np.uint64) * b) % (1 << 24)
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(out)), want)


# ---------------------------------------------------------------------------
# reduction (§III-D): log-tree, exact sum, widening widths
# ---------------------------------------------------------------------------
@given(
    k=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_reduce_exact(k, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 8, (k,))
    planes = bs.bitplane_pack(jnp.asarray(x), 8)
    out, cycles = bs.bitserial_reduce(planes)
    assert out.shape[-1] == 1
    got = int(np.asarray(bs.bitplane_unpack(out))[0])
    assert got == int(x.astype(np.uint64).sum())
    assert cycles == bs.reduce_cycles(k, 8)


def test_reduce_cycles_growth():
    # each of the log2(k) steps costs (move w) + (add w+1) with w growing by 1
    assert bs.reduce_cycles(2, 8) == 8 + 9
    assert bs.reduce_cycles(4, 8) == (8 + 9) + (9 + 10)
    assert bs.reduce_cycles(32, 8) == sum((8 + i) + (9 + i) for i in range(5))


# ---------------------------------------------------------------------------
# predicated ops: ReLU / max (§IV-D)
# ---------------------------------------------------------------------------
def test_relu():
    vals = jnp.asarray([-120, -1, 0, 5, 127], jnp.int32)
    planes = bs.bitplane_pack(vals.astype(jnp.uint32) & 0xFF, 8)
    out, _ = bs.bitserial_relu(planes)
    got = np.asarray(bs.bitplane_unpack(out, signed=True))
    np.testing.assert_array_equal(got, np.maximum(np.asarray(vals), 0))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_max(seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, 8, (33,)), _rand(rng, 8, (33,))
    pa, pb = bs.bitplane_pack(jnp.asarray(a), 8), bs.bitplane_pack(jnp.asarray(b), 8)
    out, _ = bs.bitserial_max(pa, pb)
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(out))[: len(a)], np.maximum(a, b))


# ---------------------------------------------------------------------------
# end-to-end dot product through the array
# ---------------------------------------------------------------------------
@given(k=st.sampled_from([4, 9, 16, 32]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_dot(k, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 8, (k,))
    w = _rand(rng, 8, (k,))
    got, cycles = bs.bitserial_dot(jnp.asarray(x), jnp.asarray(w))
    assert int(got) == int((x.astype(np.uint64) * w).sum())
    assert cycles > 0


# ---------------------------------------------------------------------------
# packed bit-lane layout (32 lanes per uint32 word)
# ---------------------------------------------------------------------------
@given(n_bits=st.integers(1, 16), lanes=st.sampled_from([1, 7, 31, 32, 33, 64, 100]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_lanes_roundtrip(n_bits, lanes, seed):
    """pack_lanes <-> unpack_lanes round-trips any lane count, including
    non-multiples of 32 (zero-padded into the last word)."""
    rng = np.random.default_rng(seed)
    planes = (rng.integers(0, 2, size=(n_bits, lanes))).astype(np.uint8)
    pp = bs.pack_lanes(planes)
    assert pp.n_planes == n_bits
    assert pp.lane_shape == (lanes,)
    assert pp.n_words == -(-lanes // 32)
    np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(pp)), planes)


def test_pack_lanes_multidim_roundtrip():
    rng = np.random.default_rng(0)
    planes = rng.integers(0, 2, size=(9, 3, 5, 7)).astype(np.uint8)  # 105 lanes
    pp = bs.pack_lanes(planes)
    assert pp.lane_shape == (3, 5, 7)
    assert pp.n_words == 4  # 105 lanes -> 4 words, 23 pad lanes
    np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(pp)), planes)


@given(lanes=st.sampled_from([1, 5, 31, 33, 63, 97]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_packed_matches_unpacked_ops(lanes, seed):
    """Ops fed PackedPlanes must agree bit-for-bit with the raw-plane path,
    at every lane count (padding lanes must never leak)."""
    rng = np.random.default_rng(seed)
    a = _rand(rng, 8, (lanes,))
    b = _rand(rng, 8, (lanes,))
    pa, pb = bs.bitplane_pack(jnp.asarray(a), 8), bs.bitplane_pack(jnp.asarray(b), 8)
    qa, qb = bs.pack_lanes(pa), bs.pack_lanes(pb)

    for op in (bs.bitserial_add, bs.bitserial_sub, bs.bitserial_multiply,
               bs.bitserial_max):
        raw, c_raw = op(pa, pb)
        packed, c_packed = op(qa, qb)
        assert isinstance(packed, bs.PackedPlanes)
        assert c_raw == c_packed
        np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(packed)),
                                      np.asarray(raw))

    raw, c_raw = bs.bitserial_relu(pa)
    packed, c_packed = bs.bitserial_relu(qa)
    assert c_raw == c_packed
    np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(packed)),
                                  np.asarray(raw))

    raw, c_raw = bs.bitserial_reduce(pa)
    packed, c_packed = bs.bitserial_reduce(qa)
    assert c_raw == c_packed
    np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(packed)),
                                  np.asarray(raw))


def test_packed_ops_under_jit():
    """The scan-based traced path (inside jax.jit) matches the host path."""
    rng = np.random.default_rng(11)
    a = _rand(rng, 8, (45,))
    b = _rand(rng, 8, (45,))

    @jax.jit
    def pipeline(av, bv):
        pa = bs.bitplane_pack(av, 8)
        pb = bs.bitplane_pack(bv, 8)
        s, _ = bs.bitserial_add(pa, pb)
        p, _ = bs.bitserial_multiply(pa, pb)
        r, _ = bs.bitserial_reduce(p)
        return s, p, r

    s, p, r = pipeline(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(s)),
                                  a.astype(np.uint64) + b)
    np.testing.assert_array_equal(np.asarray(bs.bitplane_unpack(p)),
                                  a.astype(np.uint64) * b)
    assert int(np.asarray(bs.bitplane_unpack(r))[0]) == int(
        (a.astype(np.uint64) * b).sum())


def test_selective_copy_packed_mask():
    rng = np.random.default_rng(4)
    dst = bs.bitplane_pack(jnp.asarray(_rand(rng, 8, (40,))), 8)
    src = bs.bitplane_pack(jnp.asarray(_rand(rng, 8, (40,))), 8)
    mask = rng.integers(0, 2, size=(40,)).astype(np.uint8)
    out, cyc = bs.selective_copy(dst, src, mask)
    want = np.where(mask[None, :].astype(bool), np.asarray(src), np.asarray(dst))
    np.testing.assert_array_equal(np.asarray(out), want)
    assert cyc == 9


# ---------------------------------------------------------------------------
# Packed-resident format: direct value packing, the in-packed lane shuffle,
# row-aligned ops, and the fused dot engine (PR 2)
# ---------------------------------------------------------------------------
@given(n_bits=st.integers(1, 16), k=st.sampled_from([1, 5, 9, 32, 72, 100]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_pack_values_roundtrip(n_bits, k, seed):
    """pack_values/unpack_values round-trip both layouts without ever
    materializing raw planes."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, n_bits, (7, k))
    for row_align in (False, True):
        pp = bs.pack_values(x, n_bits, row_align=row_align)
        assert (pp.row_lanes > 0) == row_align
        np.testing.assert_array_equal(np.asarray(bs.unpack_values(pp)), x)
        # matches the plane-tensor path bit for bit
        np.testing.assert_array_equal(
            np.asarray(bs.unpack_lanes(pp)), bs.bitplane_pack(x, n_bits))


@given(k=st.sampled_from([1, 4, 9, 31, 32, 72]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_lane_shuffle_roundtrip(k, seed):
    """shuffle_to_rows/shuffle_to_flat convert layouts in packed space."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, 8, (6, k))
    flat = bs.pack_values(x, 8)
    rows = bs.shuffle_to_rows(flat)
    assert rows.row_lanes == bs._row_layout(k)[0]
    np.testing.assert_array_equal(rows.words,
                                  bs.pack_values(x, 8, row_align=True).words)
    back = bs.shuffle_to_flat(rows)
    assert back.row_lanes == 0
    np.testing.assert_array_equal(back.words, flat.words)


def test_row_aligned_ops_match_flat():
    """Element-wise ops agree bit-for-bit across layouts, and mixed-layout
    operands are aligned via the packed-space shuffle."""
    rng = np.random.default_rng(21)
    a = _rand(rng, 8, (5, 9))
    b = _rand(rng, 8, (5, 9))
    fa, fb = bs.pack_values(a, 8), bs.pack_values(b, 8)
    ra, rb = (bs.pack_values(v, 8, row_align=True) for v in (a, b))
    for op in (bs.bitserial_add, bs.bitserial_sub, bs.bitserial_multiply,
               bs.bitserial_max):
        flat_out, c1 = op(fa, fb)
        rows_out, c2 = op(ra, rb)
        mixed_out, c3 = op(ra, fb)  # flat operand shuffled to rows
        assert c1 == c2 == c3
        np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(rows_out)),
                                      np.asarray(bs.unpack_lanes(flat_out)))
        np.testing.assert_array_equal(np.asarray(bs.unpack_lanes(mixed_out)),
                                      np.asarray(bs.unpack_lanes(flat_out)))


def test_reduce_stays_packed():
    """A packed MAC -> reduce chain never leaves word space and returns a
    flat-packed result with the unchanged cycle formula."""
    rng = np.random.default_rng(22)
    a = _rand(rng, 8, (5, 72))
    b = _rand(rng, 8, (5, 72))
    ra, rb = (bs.pack_values(v, 8, row_align=True) for v in (a, b))
    prod, c_mul = bs.bitserial_multiply(ra, rb)
    assert isinstance(prod, bs.PackedPlanes) and prod.row_lanes == 128
    red, c_red = bs.bitserial_reduce(prod)
    assert isinstance(red, bs.PackedPlanes) and red.row_lanes == 0
    assert red.lane_shape == (5, 1)
    want = (a.astype(np.uint64) * b).sum(axis=1)
    np.testing.assert_array_equal(
        np.asarray(bs.unpack_values(red))[:, 0], want)
    assert c_red == bs.reduce_cycles(72, 16)


@given(k=st.sampled_from([3, 9, 32, 72]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_packed_dot_words_exact(k, seed):
    from repro.core.nc_layers import nc_dot
    rng = np.random.default_rng(seed)
    x = _rand(rng, 8, (6, k))
    w = _rand(rng, 8, (6, k))
    got, cyc = nc_dot(x, w, acc_bits=32)
    want = (x.astype(np.int64) * w).sum(axis=-1)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert cyc == bs.dot_cycles(k, 8, 32)


def test_engine_cache_buckets():
    """The bucketed jit engine compiles once per (planes, acc, K) key."""
    rng = np.random.default_rng(23)
    bs.engine_cache_clear()
    k = 40
    for rows in (8, 8, 8):  # same bucket -> one compile
        x = _rand(rng, 8, (rows, k))
        w = _rand(rng, 8, (rows, k))
        xw = bs.pack_values(x, 8, row_align=True).words.reshape(8, -1, 2)
        ww = bs.pack_values(w, 8, row_align=True).words.reshape(8, -1, 2)
        vals, _ = bs.packed_dot_words(xw, ww, K=k, acc_bits=32, engine="jit")
        np.testing.assert_array_equal(
            np.asarray(vals), (x.astype(np.int64) * w).sum(axis=-1))
    info = bs.engine_cache_info()
    assert info["entries"] == 1
    if info["compiled"]:  # executable count is best-effort (private JAX API)
        assert info["compiled"] == 1


def test_zero_skip_stats_account_and_preserve_results():
    """Host multiply elides all-zero-operand words; results and cycles are
    untouched, the elision is visible in SKIP_STATS."""
    rng = np.random.default_rng(24)
    a = _rand(rng, 8, (200,))
    b = np.zeros((200,), np.uint32)
    b[:3] = rng.integers(1, 256, 3)
    pa = bs.pack_values(a, 8)
    pb = bs.pack_values(b, 8)
    bs.SKIP_STATS.reset()
    out, cyc = bs.bitserial_multiply(pa, pb)
    np.testing.assert_array_equal(
        np.asarray(bs.unpack_values(out)), a.astype(np.int64) * b)
    assert cyc == bs.mul_cycles(8)  # modeled cycles unchanged by skipping
    snap = bs.SKIP_STATS.snapshot()
    assert snap["words_total"] == 7  # 200 lanes -> 7 words
    assert snap["words_skipped"] == 6  # only the first word has live pairs
    assert snap["lanes_zero"] >= 197
