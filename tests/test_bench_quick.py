"""``python -m benchmarks.run --quick`` stays working.

Slow-marked (subprocess + jit warmup): tier-1 deselects it, the
``benchmarks/run.py`` slow-test gate runs it on every full bench run —
so the CI pre-check mode can't silently rot between PRs.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_bench_quick_mode_exits_clean():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick"],
        cwd=REPO, capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    # CSV header + at least the kernel/* rows
    lines = [l for l in res.stdout.splitlines() if l.strip()]
    assert lines[0] == "name,us_per_call,derived"
    kernel_rows = [l for l in lines[1:] if l.startswith("kernel/")]
    assert len(kernel_rows) >= 6, res.stdout
    # PR 9: the traffic-replay smoke rides along (router + accounting gates)
    replay_rows = [l for l in lines[1:] if l.startswith("replay/")]
    assert len(replay_rows) >= 1, res.stdout
    # quick mode must never rewrite the committed baseline
    assert "baseline not" in res.stderr and "rewritten" in res.stderr
