"""Packed-resident tiled layer pipeline: tiled-vs-untiled bit-exactness,
SAME padding, pools, wordline-budget enforcement, the bucketed jit engine,
batched-vs-single bit-exactness (batch folded into the packed lane axis),
the §IV-D in-cache nc_minmax reduction, and the end-to-end (slow-marked)
reduced Inception v3 forward through the emulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitserial as bs
from repro.core import nc_layers as nc
from repro.core import quantize as q
from repro.core.cache_geometry import CacheGeometry, XEON_E5_35MB
from repro.core.mapper import LayerSpec
from repro.models import inception

jax.config.update("jax_enable_x64", True)


def _conv_case(rng, H, C, R, M, n_bits):
    x = rng.normal(size=(H, H, C)).astype(np.float32)
    w = rng.normal(size=(R, R, C, M)).astype(np.float32) * 0.5
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()),
                            bits=n_bits)
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()),
                            bits=n_bits)
    return jnp.asarray(x), jnp.asarray(w), x_qp, w_qp


# ---------------------------------------------------------------------------
# Tentpole: tiled + packed-resident conv is bit-exact vs the untiled oracle
# across strides, tile sizes (incl. non-dividing), and plane counts.
# ---------------------------------------------------------------------------
@given(
    stride=st.sampled_from([1, 2]),
    n_bits=st.sampled_from([4, 6, 8]),
    tile_pixels=st.sampled_from([1, 3, 5, 49, 1000]),
    tile_filters=st.sampled_from([1, 2, 5, 1000]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=8, deadline=None)
def test_tiled_conv_bit_exact_vs_untiled(stride, n_bits, tile_pixels,
                                         tile_filters, seed):
    rng = np.random.default_rng(seed)
    x, w, x_qp, w_qp = _conv_case(rng, H=8, C=3, R=3, M=5, n_bits=n_bits)
    ref, cyc_ref = nc.nc_conv2d(x, w, x_qp, w_qp, stride)
    out, cyc = nc.nc_conv2d(x, w, x_qp, w_qp, stride,
                            tile_pixels=tile_pixels,
                            tile_filters=tile_filters)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert cyc == cyc_ref  # tiling must not change modeled cycles


@given(
    k=st.sampled_from([4, 9, 31, 40]),
    tile_filters=st.sampled_from([1, 3, 1000]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=6, deadline=None)
def test_tiled_fc_bit_exact(k, tile_filters, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(k,)).astype(np.float32)
    w = rng.normal(size=(k, 7)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    ref, cyc_ref = nc.nc_fc(jnp.asarray(x), jnp.asarray(w), x_qp, w_qp)
    out, cyc = nc.nc_fc(jnp.asarray(x), jnp.asarray(w), x_qp, w_qp,
                        tile_filters=tile_filters)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert cyc == cyc_ref


def test_conv_cycles_match_formula():
    """Per-dot cycles are the unchanged §III composition (mul + acc-add +
    log-tree), independent of tiling/packing."""
    rng = np.random.default_rng(0)
    x, w, x_qp, w_qp = _conv_case(rng, H=6, C=2, R=3, M=4, n_bits=8)
    _, cyc = nc.nc_conv2d(x, w, x_qp, w_qp, tile_pixels=3, tile_filters=2)
    K = 3 * 3 * 2
    per_dot = bs.mul_cycles(8) + bs.add_cycles(32) + bs.reduce_cycles(K, 32)
    assert cyc == per_dot * 4 * 4 * 4  # E*F*M dots


def test_conv_same_padding_exact():
    rng = np.random.default_rng(3)
    xq = rng.integers(0, 256, size=(8, 8, 3), dtype=np.uint8)
    wq = rng.integers(0, 256, size=(3, 3, 3, 4), dtype=np.uint8)
    qp0 = q.QuantParams(scale=1.0, zero_point=0)
    for stride in (1, 2):
        acc, _ = nc.nc_conv2d(jnp.asarray(xq, jnp.float32),
                              jnp.asarray(wq, jnp.float32), qp0, qp0, stride,
                              padding="SAME")
        ref = jax.lax.conv_general_dilated(
            jnp.asarray(xq, jnp.int64)[None], jnp.asarray(wq, jnp.int64),
            (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
        np.testing.assert_array_equal(np.asarray(acc),
                                      np.asarray(ref, np.int32))


def test_conv_same_padding_nonzero_zp():
    """SAME padding uses the quantized zero point, so the affine identity
    stays exact: dequantized padding contributes exactly zero."""
    rng = np.random.default_rng(4)
    x = rng.normal(size=(6, 6, 2)).astype(np.float32) + 1.5  # nonzero zp
    w = rng.normal(size=(3, 3, 2, 3)).astype(np.float32) * 0.5
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    assert int(x_qp.zero_point) != 0
    acc, _ = nc.nc_conv2d(jnp.asarray(x), jnp.asarray(w), x_qp, w_qp,
                          padding="SAME")
    got = np.asarray(acc, np.float64) * float(x_qp.scale) * float(w_qp.scale)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    assert np.abs(got - np.asarray(ref)).max() < 0.5


# ---------------------------------------------------------------------------
# Pools
# ---------------------------------------------------------------------------
def test_maxpool_same():
    rng = np.random.default_rng(5)
    xq = rng.integers(0, 256, size=(9, 9, 4), dtype=np.uint8)
    got, _ = nc.nc_maxpool2d(jnp.asarray(xq), 3, 2, padding="SAME")
    ref = jax.lax.reduce_window(
        jnp.asarray(xq, jnp.int32), jnp.int32(0), jax.lax.max,
        (3, 3, 1), (2, 2, 1), "SAME")
    np.testing.assert_array_equal(np.asarray(got, np.int32), np.asarray(ref))


@pytest.mark.parametrize("pad", ["VALID", "SAME"])
def test_avgpool_matches_float(pad):
    rng = np.random.default_rng(6)
    xq = rng.integers(0, 256, size=(9, 9, 4), dtype=np.uint8)
    got, cyc = nc.nc_avgpool2d(jnp.asarray(xq), 3, 1, padding=pad)
    ones = jax.lax.reduce_window(jnp.ones((9, 9, 4), jnp.float32), 0.0,
                                 jax.lax.add, (3, 3, 1), (1, 1, 1), pad)
    s = jax.lax.reduce_window(jnp.asarray(xq, jnp.float32), 0.0, jax.lax.add,
                              (3, 3, 1), (1, 1, 1), pad)
    ref = np.asarray(s / ones)
    assert np.abs(np.asarray(got, np.float64) - ref).max() <= 0.51
    assert cyc > 0


# ---------------------------------------------------------------------------
# Batched-vs-single bit-exactness: the batch folds into the packed lane
# axis, quantization is per-image, outputs must match N independent runs.
# ---------------------------------------------------------------------------
@given(
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["VALID", "SAME"]),
    batch=st.sampled_from([2, 3, 5]),
    tile_pixels=st.sampled_from([None, 7, 1000]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=8, deadline=None)
def test_batched_conv_bit_exact_vs_singles(stride, padding, batch,
                                           tile_pixels, seed):
    rng = np.random.default_rng(seed)
    # per-image data AND per-image quantization ranges
    xs = [rng.normal(size=(8, 8, 3)).astype(np.float32) * s
          for s in rng.uniform(0.3, 3.0, batch)]
    w = rng.normal(size=(3, 3, 3, 5)).astype(np.float32) * 0.5
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    qps = [q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
           for x in xs]
    out, cyc = nc.nc_conv2d(np.stack(xs), jnp.asarray(w), qps, w_qp, stride,
                            padding=padding, tile_pixels=tile_pixels)
    singles = [nc.nc_conv2d(jnp.asarray(x), jnp.asarray(w), qp, w_qp, stride,
                            padding=padding) for x, qp in zip(xs, qps)]
    for b in range(batch):
        np.testing.assert_array_equal(np.asarray(out[b]),
                                      np.asarray(singles[b][0]))
    # cycles are per lane group — batching never discounts the §III charge
    assert cyc == sum(s[1] for s in singles)


def test_batched_conv_non_dividing_batch_tile():
    """A tile_pixels that does not divide E*F forces tiles spanning image
    boundaries AND ragged tails — results must stay bit-identical."""
    rng = np.random.default_rng(11)
    xs = [rng.normal(size=(7, 7, 2)).astype(np.float32) for _ in range(3)]
    w = rng.normal(size=(3, 3, 2, 4)).astype(np.float32)
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    qps = [q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
           for x in xs]
    # E*F = 9 per image (SAME stride 2 -> 4x4=16); rows total 3*16, tile 7
    out, _ = nc.nc_conv2d(np.stack(xs), jnp.asarray(w), qps, w_qp, 2,
                          padding="SAME", tile_pixels=7, tile_filters=3)
    for b in range(3):
        ref, _ = nc.nc_conv2d(jnp.asarray(xs[b]), jnp.asarray(w), qps[b],
                              w_qp, 2, padding="SAME")
        np.testing.assert_array_equal(np.asarray(out[b]), np.asarray(ref))


def test_batched_pools_and_fc_bit_exact():
    rng = np.random.default_rng(12)
    xq = rng.integers(0, 256, size=(3, 9, 9, 4), dtype=np.uint8)
    for pad in ("VALID", "SAME"):
        mb, cm = nc.nc_maxpool2d(xq, 3, 2, padding=pad)
        ab, ca = nc.nc_avgpool2d(xq, 3, 1, padding=pad)
        for b in range(3):
            m1, c1 = nc.nc_maxpool2d(xq[b], 3, 2, padding=pad)
            a1, c2 = nc.nc_avgpool2d(xq[b], 3, 1, padding=pad)
            np.testing.assert_array_equal(np.asarray(mb[b]), np.asarray(m1))
            np.testing.assert_array_equal(np.asarray(ab[b]), np.asarray(a1))
        assert cm == 3 * c1 and ca == 3 * c2
    xs = rng.normal(size=(3, 23)).astype(np.float32)
    w = rng.normal(size=(23, 6)).astype(np.float32)
    w_qp = q.choose_qparams(jnp.float32(w.min()), jnp.float32(w.max()))
    qps = [q.choose_qparams(jnp.float32(v.min()), jnp.float32(v.max()))
           for v in xs]
    ob, _ = nc.nc_fc(xs, w, qps, w_qp)
    for b in range(3):
        o1, _ = nc.nc_fc(xs[b], w, qps[b], w_qp)
        np.testing.assert_array_equal(np.asarray(ob[b]), np.asarray(o1))


def test_batched_conv_prequantized_resident_inputs():
    """Integer inputs skip the quantize step (the resident-uint8 path)."""
    rng = np.random.default_rng(13)
    xq = rng.integers(0, 256, size=(2, 6, 6, 2), dtype=np.uint8)
    wq = rng.integers(0, 256, size=(3, 3, 2, 3), dtype=np.uint8)
    qp0 = q.QuantParams(scale=1.0, zero_point=0)
    acc, _ = nc.nc_conv2d(xq, wq, [qp0, qp0], qp0)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(xq, jnp.int64), jnp.asarray(wq, jnp.int64), (1, 1),
        "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(ref, np.int32))


# ---------------------------------------------------------------------------
# §IV-D in-cache min/max (nc_minmax): exact vs np.min/np.max, log-tree cycles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 3, 5, 16, 31, 32, 33, 100, 257, 1024])
def test_nc_minmax_matches_numpy_int8(k):
    rng = np.random.default_rng(k)
    v = rng.integers(-128, 128, size=(k,)).astype(np.int8)
    mn, mx, cyc = nc.nc_minmax(v, bits=8, signed=True)
    assert int(mn) == int(v.min()) and int(mx) == int(v.max())
    assert cyc == bs.minmax_cycles(k, 8) + 2  # +2: sign-plane bias in/out


@pytest.mark.parametrize("k", [1, 7, 64, 500])
def test_nc_minmax_batched_rows_and_int32(k):
    rng = np.random.default_rng(k)
    v = rng.integers(-2**31, 2**31, size=(5, k), dtype=np.int64)
    mn, mx, cyc = nc.nc_minmax(v, bits=32, signed=True)
    np.testing.assert_array_equal(mn, v.min(axis=1))
    np.testing.assert_array_equal(mx, v.max(axis=1))
    # all rows advance in lockstep: one tree's worth of cycles
    assert cyc == bs.minmax_cycles(k, 32) + 2


def test_nc_minmax_unsigned_and_formula():
    rng = np.random.default_rng(99)
    v = rng.integers(0, 256, size=(40,)).astype(np.uint8)
    mn, mx, cyc = nc.nc_minmax(v, bits=8)
    assert int(mn) == int(v.min()) and int(mx) == int(v.max())
    # the closed form: ceil(log2 k) steps of subtract + masked copy + tag
    steps = int(np.ceil(np.log2(40)))
    assert cyc == steps * (bs.add_cycles(8) + 9 + 1) == bs.minmax_cycles(40, 8)


def test_bitserial_minmax_packed_roundtrip():
    """Packed-in/packed-out: the op stays below the value-plane API."""
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 1 << 12, size=(6, 32)).astype(np.uint64)
    pp = bs.pack_values(rows, 12, row_align=True)
    (mn, mx), cyc = bs.bitserial_minmax(pp)
    assert isinstance(mn, bs.PackedPlanes) and isinstance(mx, bs.PackedPlanes)
    np.testing.assert_array_equal(
        bs.unpack_values(mx).reshape(-1), rows.max(axis=1))
    np.testing.assert_array_equal(
        bs.unpack_values(mn).reshape(-1), rows.min(axis=1))
    assert cyc == bs.minmax_cycles(32, 12)


# ---------------------------------------------------------------------------
# Mapper wordline-budget enforcement (satellite: clear error with the spec)
# ---------------------------------------------------------------------------
def test_conv_tiler_raises_on_wordline_budget():
    rng = np.random.default_rng(7)
    x, w, x_qp, w_qp = _conv_case(rng, H=6, C=2, R=3, M=4, n_bits=8)
    tiny = dataclasses.replace(XEON_E5_35MB, array_rows=120, name="tiny-rows")
    with pytest.raises(ValueError, match="word-line budget"):
        nc.nc_conv2d(x, w, x_qp, w_qp, geom=tiny,
                     layer_spec=LayerSpec(name="offending_conv", kind="conv",
                                          H=6, R=3, S=3, C=2, M=4, E=4))
    try:
        nc.nc_conv2d(x, w, x_qp, w_qp, geom=tiny,
                     layer_spec=LayerSpec(name="offending_conv", kind="conv",
                                          H=6, R=3, S=3, C=2, M=4, E=4))
    except ValueError as e:
        assert "offending_conv" in str(e)  # the spec rides in the error


# ---------------------------------------------------------------------------
# Bucketed jit engine: parity + compilation reuse across tiles
# ---------------------------------------------------------------------------
def test_jit_engine_parity_and_cache_reuse():
    rng = np.random.default_rng(8)
    x, w, x_qp, w_qp = _conv_case(rng, H=8, C=3, R=3, M=5, n_bits=8)
    ref, _ = nc.nc_conv2d(x, w, x_qp, w_qp)
    bs.engine_cache_clear()
    out, _ = nc.nc_conv2d(x, w, x_qp, w_qp, tile_pixels=7, tile_filters=2,
                          engine="jit")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    info = bs.engine_cache_info()
    # one engine entry for the layer's (planes, acc, K) bucket, and the
    # ragged tail tiles were padded onto the same compiled shape
    assert info["entries"] == 1
    if info["compiled"]:  # executable count is best-effort (private JAX API)
        assert info["compiled"] <= 2  # full tile shape (+ at most one variant)
    # a second layer with the same K/planes reuses the same entry
    out2, _ = nc.nc_conv2d(x, w, x_qp, w_qp, tile_pixels=9, tile_filters=5,
                           engine="jit")
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    assert bs.engine_cache_info()["entries"] == 1


# ---------------------------------------------------------------------------
# EIE-style zero-operand skipping: accounting only, never results
# ---------------------------------------------------------------------------
def test_zero_operand_stats_and_exactness():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(6, 6, 2)).astype(np.float32)
    w = np.zeros((3, 3, 2, 4), np.float32)
    w[0, 0, 0, 0] = 1.0  # a single live weight: almost every lane skippable
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.QuantParams(scale=1.0, zero_point=0)
    out, cyc, stats = nc.nc_conv2d(jnp.asarray(x), jnp.asarray(w), x_qp, w_qp,
                                   return_stats=True)
    # the accumulator holds the affine-corrected integer conv: (xq - zx) * w
    xq_centered = nc._quantize_np(x, x_qp) - int(x_qp.zero_point)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(xq_centered, jnp.int64)[None],
        jnp.asarray(w, jnp.int64), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref, np.int32))
    K = 3 * 3 * 2
    assert stats.lanes == 4 * 4 * 4 * K
    # only one of the K weight positions is live, in one of the 4 filters
    live_windows = int((nc._quantize_np(x, x_qp)[:4, :4, 0] != 0).sum())
    assert stats.zero_operand_lanes == stats.lanes - live_windows
    assert stats.engine_words_skipped > 0
    # cycles never change: the SRAM clocks every bit-slice
    _, cyc_dense = nc.nc_conv2d(jnp.asarray(x), jnp.asarray(np.ones_like(w)),
                                x_qp, w_qp)
    assert cyc == cyc_dense


# ---------------------------------------------------------------------------
# End-to-end: reduced Inception v3 through the emulation (slow-marked; the
# tier-1 run skips these — benchmarks/run.py's gate exercises `-m slow`)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_forward():
    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    key = jax.random.PRNGKey(0)
    params = inception.init_params(key, config=cfg)
    x = jax.random.uniform(key, (47, 47, 3), jnp.float32)
    logits, report = inception.nc_forward(params, x, config=cfg)
    return cfg, params, x, logits, report


@pytest.mark.slow
def test_nc_forward_runs_and_reports(tiny_forward):
    cfg, params, x, logits, report = tiny_forward
    assert logits.shape == (8,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    specs = inception.inception_v3_specs(cfg)
    assert len(report.layers) == len(specs)  # one report row per layer
    assert report.total_emulated_cycles > 0
    assert report.total_modeled_cycles > 0
    assert report.total_modeled_s > 0
    for l in report.layers:
        assert l.emulated_cycles >= 0 and l.serial_passes >= 1
    # §IV-D: every conv layer's dynamic range came from the in-cache tree
    for l in report.layers:
        if l.kind == "conv":
            assert l.minmax_cycles > 0
    text = report.summary()
    assert "TOTAL" in text and "modeled latency" in text


@pytest.mark.slow
def test_nc_forward_tracks_float_model(tiny_forward):
    cfg, params, x, logits, report = tiny_forward
    ref = inception.apply(params, x[None], quant=True, config=cfg)[0]
    corr = np.corrcoef(np.asarray(ref), np.asarray(logits))[0, 1]
    assert corr > 0.95, corr


@pytest.mark.slow
def test_nc_forward_batched_bit_identical(tiny_forward):
    """nc_forward(batch=N) rows == N independent single-image runs, bit for
    bit (per-image in-cache quantization makes batching invisible)."""
    cfg, params, _, _, _ = tiny_forward
    xb = jax.random.uniform(jax.random.PRNGKey(7), (3, 47, 47, 3),
                            jnp.float32)
    lb, rb = inception.nc_forward(params, xb, config=cfg)
    assert rb.batch == 3
    for b in range(3):
        ls, _ = inception.nc_forward(params, xb[b], config=cfg)
        np.testing.assert_array_equal(np.asarray(lb[b]), np.asarray(ls))


@pytest.mark.slow
def test_nc_forward_batch4_acceptance():
    """The PR acceptance run: reduced_config() at batch=4, end to end.

    - in-cache nc_minmax quantization on every conv (no CPU float min/max
      in the layer loop),
    - filters packed once per layer per batch (§VI-C residency),
    - per-image wall time lower than the batch=1 path,
    - simulate_network consuming the SAME NetworkSchedule reports filter
      bytes loaded once per layer per batch."""
    import time

    from repro.core import schedule as sched
    from repro.core import simulator as sim

    cfg = inception.reduced_config()
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    xb = jax.random.uniform(jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3),
                            jnp.float32)
    specs = inception.inception_v3_specs(cfg)
    schedule = sched.plan_network(specs, XEON_E5_35MB, batch=4)

    # two runs each, min taken: the first batched run also warms the
    # bucketed-jit engine cache, which is the steady state a serving run
    # amortizes to (and what "per-image wall time" means under load noise)
    wall1, wall4 = float("inf"), float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        l1, r1 = inception.nc_forward(params, xb[0], config=cfg)
        wall1 = min(wall1, time.perf_counter() - t0)
        t0 = time.perf_counter()
        l4, r4 = inception.nc_forward(params, xb, config=cfg,
                                      schedule=schedule)
        wall4 = min(wall4, time.perf_counter() - t0)

    assert l4.shape == (4, cfg.classes)
    assert bool(jnp.all(jnp.isfinite(l4)))
    np.testing.assert_array_equal(np.asarray(l4[0]), np.asarray(l1))
    # §IV-D: dynamic ranges from the in-cache tree, filters resident
    for l in r4.layers:
        if l.kind in ("conv", "fc"):
            assert l.filter_loads == 1  # packed once per layer per batch
        if l.kind == "conv":
            assert l.minmax_cycles > 0
    # batching amortizes: per-image wall time beats the single-image path
    assert wall4 / 4 < wall1, (wall4 / 4, wall1)
    # the same plan object prices the run: filter bytes once per layer
    # per batch, independent of the batch size
    res = sim.simulate_network(schedule)
    assert res.schedule is schedule
    assert res.filter_bytes_loaded == sum(s.filter_bytes for s in specs)
    assert res.filter_bytes_loaded == sim.simulate_network(
        specs).filter_bytes_loaded


@pytest.mark.slow
def test_nc_serving_engine_batches_requests():
    """Serving routes admitted request batches through the schedule: the
    per-request logits equal standalone single-image runs bit for bit."""
    from repro.launch.serve import NCRequest, NCServingEngine

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    eng = NCServingEngine(params, cfg, max_batch=2)
    rng = np.random.default_rng(0)
    imgs = rng.random((5, 47, 47, 3)).astype(np.float32)
    for r in range(5):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    done = eng.run()
    assert len(done) == 5 and all(r.done for r in done)
    assert eng.steps == 3  # 2 + 2 + 1: ragged final batch
    assert eng.schedule.batch == 2  # planned once for the admission size
    for r in done:
        ref, _ = inception.nc_forward(params, imgs[r.rid], config=cfg)
        np.testing.assert_array_equal(r.logits, np.asarray(ref))


def test_reduced_config_specs_map():
    from repro.core.mapper import map_network
    cfg = inception.reduced_config()
    specs = inception.inception_v3_specs(cfg)
    assert specs[-1].M == cfg.classes
    mapped = map_network(specs)  # must fit the budget
    assert len(mapped) == len(specs)
    kinds = {s.kind for s in specs}
    assert kinds == {"conv", "maxpool", "avgpool", "fc"}
    # every mixed stage type survives the reduction
    names = {s.block for s in specs}
    for b in ("Mixed_5b", "Mixed_6a", "Mixed_6b", "Mixed_7a", "Mixed_7b"):
        assert b in names


def test_full_config_unchanged():
    """The FULL config must still reproduce the paper's Table-I network."""
    assert inception.FULL.img == 299
    assert inception.FULL.classes == 1001
    specs_default = inception.inception_v3_specs()
    specs_full = inception.inception_v3_specs(inception.FULL)
    assert specs_default == specs_full
