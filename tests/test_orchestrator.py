"""Multi-engine orchestrator (PR 9): engine API, latency routing,
fleet-level accounting.

Covers the :class:`~repro.launch.engine_api.Engine` contract (real +
simulated implementations), per-engine calibration isolation, the
latency router's preference for the calibrated-faster socket, the
``wait-better`` hold (waiting for a busy fast engine beats dispatching
to a free slow one), the arrival-rate-bounded hold at fleet level,
round-robin as the baseline foil, drain-with-flush leaving nothing
stranded, and bit-identity of routed results vs standalone
``nc_forward`` whichever real engine serves."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.schedule import plan_network
from repro.launch.engine_api import Engine, SimRequest, SimulatedEngine
from repro.launch.orchestrator import Orchestrator
from repro.models import inception


@pytest.fixture(scope="module")
def sched_for():
    """Per-geometry plan caches over the full Inception specs (compressed
    plans: the 14-slice socket streams 2 images, smaller sockets 1)."""
    specs = inception.inception_v3_specs()
    caches: dict = {}

    def for_slices(n_slices: int):
        geom = (XEON_E5_35MB if n_slices == XEON_E5_35MB.n_slices
                else XEON_E5_35MB.scaled(n_slices))
        cache = caches.setdefault(n_slices, {})

        def f(n):
            if n not in cache:
                cache[n] = plan_network(specs, geom, batch=n,
                                        compressed=True)
            return cache[n]
        return f
    return for_slices


def _drain(orch, clock, tick=1e-4):
    """Drive a fake-clock fleet to empty: step, then jump the clock to
    the next engine-free instant (or nudge it when holding)."""
    guard = 0
    while orch.pending:
        while orch.step(now=clock["t"], flush=True):
            pass
        if not orch.pending:
            break
        nxt = orch.next_event_s(clock["t"])
        clock["t"] = nxt if nxt > clock["t"] else clock["t"] + tick
        guard += 1
        assert guard < 100_000, "fleet failed to drain"
    return orch


# ---------------------------------------------------------------------------
# Engine API contract
# ---------------------------------------------------------------------------
def test_simulated_engine_implements_engine_api(sched_for):
    e = SimulatedEngine("sock", sched_for(14), max_batch=4)
    assert isinstance(e, Engine)
    assert e.queue_depth == 0 and e.ready_in(0.0) == 0.0
    # compressed 14-slice plan streams 2 images; max_batch doesn't bite
    assert e.batch_cap == min(4, e.latency_model.stream_batch_limit)
    e.submit(SimRequest(rid=0), now=0.0)
    assert e.queue_depth == 1
    assert e.step(now=0.0) is True
    # fake-clock execution: busy until the simulated wall elapses
    assert e.busy_until > 0.0 and e.ready_in(0.0) > 0.0
    assert e.step(now=0.0) is False  # busy engines admit nothing
    assert e.queue_depth == 0 and len(e.completed) == 1
    assert e.completed[0].done and e.completed[0].latency_s > 0.0
    # the simulated wall calibrated the model like a measured one
    assert e.latency_model.samples == 1


def test_orchestrator_validates_fleet():
    with pytest.raises(ValueError, match="at least one"):
        Orchestrator([])
    fake = [SimRequest(rid=0), SimRequest(rid=1)]  # not engines, same name
    for r in fake:
        r.name = "dup"
    with pytest.raises(ValueError, match="unique"):
        Orchestrator(fake)
    fake[1].name = "other"
    with pytest.raises(ValueError, match="router"):
        Orchestrator(fake, router="fastest")


# ---------------------------------------------------------------------------
# Calibration isolation + routing preference
# ---------------------------------------------------------------------------
def test_per_engine_calibration_isolation(sched_for):
    """Each engine's LatencyModel learns its OWN true speed from its own
    batches — a slow socket never contaminates a fast one's curve."""
    fast = SimulatedEngine("fast", sched_for(14), max_batch=2,
                           true_scale=1.0)
    slow = SimulatedEngine("slow", sched_for(14), max_batch=2,
                           true_scale=3.0)
    clock = {"t": 0.0}
    orch = Orchestrator([fast, slow], now_fn=lambda: clock["t"])
    for i in range(8):
        orch.submit(SimRequest(rid=i), now=0.0)
    _drain(orch, clock)
    assert len(orch.completed) == 8 and orch.pending == 0
    # jitter=0: every observed ratio is exactly the engine's true scale
    assert fast.latency_model.scale == pytest.approx(1.0)
    assert slow.latency_model.scale == pytest.approx(3.0)
    # each model saw exactly its own engine's batches
    assert fast.latency_model.samples == fast.steps
    assert slow.latency_model.samples == slow.steps
    assert fast.steps + slow.steps == sum(
        orch.stats()["batch_histogram"].values())


def test_latency_router_prefers_calibrated_faster_engine(sched_for):
    """Same geometry, different true speeds, both meeting the deadline:
    the router's -p99 tie-break sends every unloaded dispatch to the
    calibrated-faster socket."""
    fast = SimulatedEngine("fast", sched_for(14), max_batch=1,
                           true_scale=1.0)
    slow = SimulatedEngine("slow", sched_for(14), max_batch=1,
                           true_scale=4.0)
    m = fast.latency_model.modeled_batch_s(1)
    for e in (fast, slow):  # pre-calibrate both curves
        e.latency_model.observe(1, e.true_scale * m)
    clock = {"t": 0.0}
    orch = Orchestrator([fast, slow], slo_ms=100 * m * 1e3,
                        now_fn=lambda: clock["t"])
    for i in range(5):
        # arrivals spaced so the fast engine is always free again
        t = i * 2.0 * m
        clock["t"] = t
        orch.submit(SimRequest(rid=i), now=t)
        orch.step(now=t)
    _drain(orch, clock)
    assert orch.dispatched == {"fast": 5, "slow": 0}
    assert orch.slo_hits == 5 and orch.slo_misses == 0


def test_wait_better_holds_for_busy_fast_engine(sched_for):
    """No free engine makes the deadline, but the busy fast one would
    after freeing: the router waits for it instead of burning the
    request on the free slow socket — the call a latency-blind router
    cannot make."""
    fast = SimulatedEngine("fast", sched_for(14), max_batch=1,
                           true_scale=1.0)
    slow = SimulatedEngine("slow", sched_for(14), max_batch=1,
                           true_scale=4.0)
    m = fast.latency_model.modeled_batch_s(1)
    for e in (fast, slow):
        e.latency_model.observe(1, e.true_scale * m)
    # p99 = 1.25 x scale x modeled: fast 1.25m, slow 5m.  SLO 3m: the
    # slow socket can never meet it.
    clock = {"t": 0.0}
    orch = Orchestrator([fast, slow], slo_ms=3 * m * 1e3,
                        now_fn=lambda: clock["t"])
    orch.submit(SimRequest(rid=0), now=0.0)
    assert orch.step(now=0.0)  # dispatched to fast; busy until m
    assert orch.dispatched["fast"] == 1 and fast.ready_in(0.0) > 0.0
    orch.submit(SimRequest(rid=1), now=0.0)
    assert orch.step(now=0.0) is False  # slow is free but would miss
    assert orch.decisions[-1].reason == "wait-better"
    assert orch.dispatched["slow"] == 0 and len(orch.queue) == 1
    clock["t"] = fast.busy_until
    assert orch.step(now=clock["t"])  # fast freed: dispatch there
    assert orch.dispatched == {"fast": 2, "slow": 0}
    _drain(orch, clock)
    assert orch.slo_hits == 2 and orch.slo_misses == 0


def test_orchestrator_hold_bounded_by_arrival_rate(sched_for):
    """Fleet-level ragged-tail hold: unknown rate falls back to the
    slack rule (hold), sparse traffic flushes immediately."""
    eng = SimulatedEngine("sock", sched_for(14), max_batch=2,
                          true_scale=1.0)
    eng.latency_model.observe(1, eng.latency_model.modeled_batch_s(1))
    m = eng.latency_model.modeled_batch_s(1)
    clock = {"t": 0.0}
    # SLO 3m: slack after a single-image batch is ~1.75m (above the
    # 0.75m default hold slack, so the slack-only rule alone would hold)
    orch = Orchestrator([eng], slo_ms=3 * m * 1e3,
                        now_fn=lambda: clock["t"])
    assert eng.batch_cap == 2  # compressed 14-slice plan streams 2
    orch.submit(SimRequest(rid=0), now=0.0)
    # one arrival: rate unknown, plenty of slack -> hold for a 2-batch
    assert orch.step(now=0.0) is False
    assert orch.decisions[-1].reason == "hold"
    orch.step(now=0.0, flush=True)  # drain it
    clock["t"] = 40 * m
    orch.submit(SimRequest(rid=1), now=clock["t"])
    # two arrivals 40m apart: filling the 2-batch is expected to take
    # ~40m, far beyond the ~1.75m slack -> flush the ragged tail NOW
    assert orch.step(now=clock["t"]) is True
    assert orch.decisions[-1].reason == "ragged-early"
    assert orch.decisions[-1].admit == 1
    _drain(orch, clock)
    assert orch.pending == 0


# ---------------------------------------------------------------------------
# Round-robin foil + drain accounting
# ---------------------------------------------------------------------------
def test_round_robin_cycles_free_engines(sched_for):
    engines = [SimulatedEngine(f"s{i}", sched_for(14), max_batch=2)
               for i in range(3)]
    clock = {"t": 0.0}
    orch = Orchestrator(engines, router="round-robin",
                        now_fn=lambda: clock["t"])
    for i in range(6):
        orch.submit(SimRequest(rid=i), now=0.0)
    for _ in range(3):  # three dispatches at t=0, one per engine in order
        orch.step(now=0.0)
    assert orch.dispatched == {"s0": 1, "s1": 1, "s2": 1}
    assert all(d.reason == "round-robin" for d in orch.decisions)
    _drain(orch, clock)
    assert len(orch.completed) == 6 and orch.pending == 0


def test_drain_flush_no_stranded_requests(sched_for):
    """A heterogeneous 3-socket fleet under a burst of arrivals drains
    completely: every request ends in completed/failed, hits + misses
    cover them exactly, and the batch histogram admit-sum matches."""
    engines = [
        SimulatedEngine("socket-35MB", sched_for(14), max_batch=4,
                        true_scale=1.0, jitter=0.05, seed=1),
        SimulatedEngine("socket-17MB", sched_for(7), max_batch=4,
                        true_scale=1.25, jitter=0.05, seed=2),
        SimulatedEngine("socket-10MB", sched_for(4), max_batch=4,
                        true_scale=1.6, jitter=0.05, seed=3),
    ]
    m = engines[0].latency_model.modeled_batch_s(1)
    clock = {"t": 0.0}
    orch = Orchestrator(engines, slo_ms=3 * m * 1e3,
                        now_fn=lambda: clock["t"])
    rng = np.random.default_rng(0)
    times = np.sort(rng.uniform(0.0, 5 * m, size=40))
    for i, t in enumerate(times):
        clock["t"] = float(t)
        orch.submit(SimRequest(rid=i), now=float(t))
        orch.step(now=float(t))
    _drain(orch, clock)
    s = orch.stats()
    assert s["completed"] + s["failed"] == 40 and orch.pending == 0
    assert s["slo_hits"] + s["slo_misses"] == s["completed"] + s["failed"]
    assert sum(n * c for n, c in s["batch_histogram"].items()) == 40
    assert all(e.queue_depth == 0 for e in engines)
    # every socket's internal ledger agrees with the fleet's
    assert sum(len(e.completed) for e in engines) == s["completed"]
    # with an SLO tight enough to pressure the fleet, the stats carry a
    # well-formed hit rate
    assert 0.0 <= s["slo_hit_rate"] <= 1.0
    assert not math.isnan(s["slo_hit_rate"])


# ---------------------------------------------------------------------------
# Real engines behind the router: bit-identity + Engine contract
# ---------------------------------------------------------------------------
def test_real_fleet_routing_bit_identical_to_standalone():
    """Two real NCServingEngine sockets (different geometries) behind the
    latency router: all requests complete, the orchestrator-level SLO
    identity holds, and every routed logit row is byte-identical to a
    standalone nc_forward — the router changes placement, never
    results."""
    import jax

    from repro.launch.serve import NCRequest, NCServingEngine

    cfg = inception.reduced_config(img=47, width_div=8, classes=8, stages=())
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    clock = {"t": 0.0}
    now = lambda: clock["t"]  # noqa: E731
    engines = [
        NCServingEngine(params, cfg, max_batch=2, now_fn=now,
                        name="socket-35MB"),
        NCServingEngine(params, cfg, max_batch=2, now_fn=now,
                        name="socket-10MB",
                        geom=XEON_E5_35MB.scaled(4, "xeon-10MB")),
    ]
    assert all(isinstance(e, Engine) for e in engines)
    assert all(e.queue_depth == 0 and e.ready_in(0.0) == 0.0
               for e in engines)
    orch = Orchestrator(engines, slo_ms=1e7, now_fn=now)
    rng = np.random.default_rng(0)
    imgs = rng.random((5, cfg.img, cfg.img, 3)).astype(np.float32)
    for i in range(5):
        orch.submit(NCRequest(rid=i, image=imgs[i]))
    done = orch.run()
    assert len(done) == 5 and orch.pending == 0
    s = orch.stats()
    assert s["slo_hits"] + s["slo_misses"] == s["completed"] + s["failed"]
    assert sum(s["dispatched"].values()) == sum(
        s["batch_histogram"].values())
    # requests keep their GLOBAL arrival stamp through dispatch
    assert all(r.latency_s is not None and r.slo_ok is not None
               for r in done)
    for r in done:
        ref, _ = inception.nc_forward(params, imgs[r.rid], config=cfg)
        np.testing.assert_array_equal(r.logits, np.asarray(ref))
