"""Quantization pipeline tests (paper §IV-D requantization)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import quantize as q

jax.config.update("jax_enable_x64", True)


def test_qparams_includes_zero():
    qp = q.choose_qparams(jnp.float32(2.0), jnp.float32(10.0))
    # min is pulled to 0 -> zero exactly representable
    assert int(qp.zero_point) == 0
    x = jnp.asarray([0.0, 5.0, 10.0])
    back = q.dequantize(q.quantize(x, qp), qp)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=float(qp.scale))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(256,)).astype(np.float32) * rng.uniform(0.1, 10)
    qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    back = np.asarray(q.dequantize(q.quantize(jnp.asarray(x), qp), qp))
    assert np.max(np.abs(back - x)) <= float(qp.scale) * 0.501 + 1e-6


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_per_channel_symmetric(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    qw, scale = q.quantize_per_channel(jnp.asarray(w), axis=-1)
    assert qw.dtype == jnp.int8
    back = np.asarray(qw, np.float32) * np.asarray(scale)
    assert np.max(np.abs(back - w)) <= np.max(np.abs(w), axis=0).max() / 127 * 0.51 + 1e-6


@given(seed=st.integers(0, 2**31 - 1), mult=st.floats(1e-4, 0.99))
@settings(max_examples=50, deadline=None)
def test_fixedpoint_requant_matches_float(seed, mult):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(1 << 20), 1 << 20, size=(512,), dtype=np.int32)
    m, s = q.fixed_point_multiplier(jnp.float32(mult))
    got = np.asarray(q.requantize_fixedpoint(jnp.asarray(acc), m, s, zero_point=3))
    want = np.asarray(q.requantize_reference(jnp.asarray(acc), jnp.float32(mult), zero_point=3))
    # integer fixed-point vs float rounding may differ by 1 LSB at ties
    assert np.max(np.abs(got - want)) <= 1


def test_quantized_matmul_pipeline():
    """Float matmul vs int8 W8A8 + fixed-point requant: error ~ quant noise."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    ref = x @ w

    xq_p = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    xq = q.quantize(jnp.asarray(x), xq_p)
    wq, wscale = q.quantize_per_channel(jnp.asarray(w), axis=-1)

    acc = jnp.einsum(
        "mk,kn->mn",
        (xq.astype(jnp.int32) - xq_p.zero_point),
        wq.astype(jnp.int32),
    )
    out = acc.astype(jnp.float32) * xq_p.scale * wscale[0]
    err = np.abs(np.asarray(out) - ref)
    # quant-noise bound: per-product err <= (s_x/2)|w| + (s_w/2)|x|, K=64 accum
    assert err.max() < 0.4, err.max()
    assert err.mean() < 0.08, err.mean()
