"""Doc-rot guard: the docs may only reference code that exists.

Extracts from ``README.md`` and ``docs/*.md``:

* backticked dotted references (`` `schedule.plan_layer` ``,
  `` `repro.core.slo.LatencyModel` ``) — resolved by importing the
  longest module prefix and walking the remaining attributes.  Bare
  ``module.symbol`` forms are tried under the repo's package roots
  (``repro.core``, ``repro.models``, ...); tokens whose first component
  matches none of our modules (``np.stack``, ``e.g``) are ignored, but a
  token that names one of our modules with a missing attribute FAILS,
* backticked file paths (`` `core/schedule.py` ``,
  `` `tests/golden/modeled_cycles.json` ``) — must exist at the repo
  root or under ``src/repro/``,
* fenced command lines — every ``*.py`` argument must exist and every
  ``python -m <module>`` target must import.

This keeps the satellite docs (docs/ARCHITECTURE.md, docs/SERVING.md,
README.md) from silently rotting as the code moves."""
from __future__ import annotations

import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted([REPO / "README.md", *(REPO / "docs").glob("*.md")])

# bare dotted tokens are tried under these roots (order matters)
MODULE_ROOTS = ("repro.core", "repro.models", "repro.launch",
                "repro.kernels", "repro.quant", "repro.distributed",
                "repro.data", "repro.optim", "repro.configs", "repro")

DOTTED = re.compile(r"^[A-Za-z_]\w*(\.[A-Za-z_]\w*)+$")
PATHLIKE = re.compile(r"^[\w./-]+\.(py|md|json|ini|txt)$")
BACKTICK = re.compile(r"`([^`\n]+)`")


def _doc_text(path: pathlib.Path) -> str:
    return path.read_text(encoding="utf-8")


def _fences(text: str) -> list[str]:
    """Lines inside ``` fenced blocks."""
    lines, out, infence = text.splitlines(), [], False
    for ln in lines:
        if ln.strip().startswith("```"):
            infence = not infence
            continue
        if infence:
            out.append(ln.strip())
    return out


def _try_resolve(candidate: str, roots_depth: dict) -> bool | None:
    """Resolve ``candidate`` as module-prefix + attribute chain.

    Returns True on success, False when a module beyond a bare root
    imported but the attribute chain broke (doc rot), None when no
    module prefix of ours imports (not a code reference)."""
    parts = candidate.split(".")
    for i in range(len(parts), 0, -1):
        modname = ".".join(parts[:i])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        if modname in roots_depth:
            # only the bare root imported (e.g. repro.core for `np.x`
            # tried as repro.core.np.x): says nothing about the token
            return None
        for attr in parts[i:]:
            if not hasattr(obj, attr):
                return False
            obj = getattr(obj, attr)
        return True
    return None


def resolve_dotted(token: str) -> bool | None:
    """True = resolves, False = names our code but is rotten, None =
    not a reference to our code (ignored)."""
    roots_depth = set(MODULE_ROOTS)
    first = token.split(".")[0]
    if first in ("repro", "benchmarks", "tests"):
        # explicit package path: must resolve outright
        return _try_resolve(token, set()) is True
    verdicts = [_try_resolve(f"{root}.{token}", roots_depth)
                for root in MODULE_ROOTS]
    verdicts.append(_try_resolve(token, roots_depth))
    if any(v is True for v in verdicts):
        return True
    if any(v is False for v in verdicts):
        return False
    return None


def _path_exists(token: str) -> bool:
    token = token.lstrip("./")
    return ((REPO / token).exists()
            or (REPO / "src" / "repro" / token).exists())


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_doc_exists(doc):
    assert doc.exists(), f"{doc} referenced by the doc suite is missing"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_backticked_references_resolve(doc):
    text = _doc_text(doc)
    rotten = []
    for token in BACKTICK.findall(text):
        token = token.strip()
        if PATHLIKE.match(token):
            if not _path_exists(token):
                rotten.append(f"{token} (file not found)")
        elif DOTTED.match(token):
            if resolve_dotted(token) is False:
                rotten.append(f"{token} (symbol does not resolve)")
    assert not rotten, (
        f"{doc.relative_to(REPO)} references rotten symbols/paths:\n  "
        + "\n  ".join(rotten))


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_fenced_commands_runnable(doc):
    """Every *.py in a fenced command exists; every `python -m mod`
    target imports (with the repo root importable, as the README's
    PYTHONPATH=src invocations assume)."""
    import sys
    if str(REPO) not in sys.path:  # benchmarks.* lives at the repo root
        sys.path.insert(0, str(REPO))
    bad = []
    for line in _fences(_doc_text(doc)):
        toks = line.split()
        for j, t in enumerate(toks):
            if t.endswith(".py") and not _path_exists(t):
                bad.append(f"{t} (from: {line})")
            # `-m` names a python module only right after the interpreter
            # (pytest's `-m <marker>` expression is not an import target)
            if (t == "-m" and j + 1 < len(toks) and j > 0
                    and toks[j - 1].rsplit("/", 1)[-1].startswith("python")):
                mod = toks[j + 1]
                try:
                    importlib.import_module(mod)
                except ImportError as e:
                    bad.append(f"-m {mod} ({e})")
    assert not bad, (
        f"{doc.relative_to(REPO)} fenced commands reference missing "
        f"targets:\n  " + "\n  ".join(bad))


def test_docs_cover_required_pages():
    """The PR-5 docs subsystem (+ the PR-7 reliability page):
    architecture + serving + reliability + README."""
    names = {d.name for d in DOCS}
    assert {"README.md", "ARCHITECTURE.md", "SERVING.md",
            "RELIABILITY.md"} <= names


def test_resolver_catches_rot():
    """The guard itself must flag a misspelled symbol on a real module
    (otherwise every 'passing' doc check is vacuous)."""
    assert resolve_dotted("schedule.plan_layer") is True
    assert resolve_dotted("repro.core.slo.LatencyModel") is True
    assert resolve_dotted("schedule.plan_leyer") is False
    assert resolve_dotted("repro.core.slo.NoSuchThing") is False
    assert resolve_dotted("np.stack") is None  # not our code: ignored
