"""GPipe schedule: bit-exact vs the unpipelined layer stack.

Needs >1 device, so the check runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (the parent pytest
process has already locked jax to 1 CPU device).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import gpipe_apply, split_stages, bubble_fraction
    from repro.launch.mesh import make_mesh_compat

    S, L, M, MB, D = 4, 8, 6, 2, 16
    mesh = make_mesh_compat((S,), ("stage",))
    k = jax.random.key(0)
    Ws = jax.random.normal(k, (L, D, D), jnp.float32) / jnp.sqrt(D)
    x = jax.random.normal(jax.random.key(1), (M, MB, D), jnp.float32)

    def layer_scan(W_stack, h):
        def body(c, W):
            return jnp.tanh(c @ W), None
        out, _ = jax.lax.scan(body, h, W_stack)
        return out

    # reference: all layers, no pipeline
    ref = jax.vmap(lambda xm: layer_scan(Ws, xm))(x)

    staged = split_stages({"W": Ws}, S)["W"]   # [S, L/S, D, D]
    out = gpipe_apply(lambda p, h: layer_scan(p, h), staged, x, mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert abs(bubble_fraction(S, M) - 3/9) < 1e-9
    print("GPIPE_OK")
""")


def test_gpipe_matches_unpipelined():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "GPIPE_OK" in r.stdout, r.stdout + r.stderr
