"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import quantize as q
from repro.kernels import ref
from repro.kernels.bitserial_matmul import bitserial_matmul, plane_block_mask
from repro.kernels.quant_matmul import quant_matmul


def _rand_q(rng, m, k, n):
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    w = rng.integers(-128, 128, size=(k, n)).astype(np.int8)
    xs = np.float32(rng.uniform(0.001, 0.1))
    ws = rng.uniform(0.001, 0.1, size=(n,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), xs, jnp.asarray(ws)


SHAPES = [
    (1, 8, 8), (4, 16, 32), (128, 128, 128), (100, 130, 60),  # ragged
    (256, 512, 128), (3, 1024, 5), (128, 256, 256),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_quant_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    x, w, xs, ws = _rand_q(rng, m, k, n)
    bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    got = quant_matmul(x, w, xs, ws, bias, interpret=True)
    want = ref.quant_matmul_ref(x, w, xs, ws, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_bitserial_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k * 31 + n)
    x, w, xs, ws = _rand_q(rng, m, k, n)
    planes = ref.pack_bitplanes(w, 8)
    got = bitserial_matmul(x, planes, xs, ws, interpret=True)
    want = ref.bitserial_matmul_ref(x, planes, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


def test_bitserial_equals_int8_gemm():
    """Plane decomposition must be bit-exact with the int8 GEMM."""
    rng = np.random.default_rng(7)
    x, w, xs, ws = _rand_q(rng, 64, 96, 48)
    planes = ref.pack_bitplanes(w, 8)
    a = ref.bitserial_matmul_ref(x, planes, xs, ws)
    b = ref.quant_matmul_ref(x, w, xs, ws)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n_bits", [1, 2, 3, 4, 6, 8])
def test_flexible_precision(n_bits):
    """Paper §III-A: flexible operand width — n-bit weights use n planes."""
    rng = np.random.default_rng(n_bits)
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1)) - 1
    w = rng.integers(lo, hi + 1, size=(32, 16)).astype(np.int8)
    x = rng.integers(-128, 128, size=(8, 32)).astype(np.int8)
    planes = ref.pack_bitplanes(jnp.asarray(w), n_bits)
    assert planes.shape[0] == n_bits
    got = bitserial_matmul(jnp.asarray(x), planes, jnp.float32(1.0),
                           jnp.ones(16, jnp.float32), interpret=True)
    want = jnp.dot(jnp.asarray(x, jnp.int32), jnp.asarray(w, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.int64), np.asarray(want, np.int64))


def test_zero_plane_mask_skips():
    """Weights with only low-order bits set leave high planes empty."""
    rng = np.random.default_rng(3)
    w = rng.integers(0, 4, size=(256, 128)).astype(np.int8)  # 2 live planes
    planes = ref.pack_bitplanes(jnp.asarray(w), 8)
    mask = plane_block_mask(planes, bk=128, bn=128)
    m = np.asarray(mask)
    assert m[:2].all()
    assert not m[2:].any()  # planes 2..7 skipped entirely
    x = rng.integers(-128, 128, size=(16, 256)).astype(np.int8)
    got = bitserial_matmul(jnp.asarray(x), planes, jnp.float32(1.0),
                           jnp.ones(128, jnp.float32), interpret=True)
    want = jnp.dot(jnp.asarray(x, jnp.int32), jnp.asarray(w, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.int64), np.asarray(want, np.int64))


@given(
    m=st.integers(1, 64), k=st.integers(1, 128), n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_quant_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, xs, ws = _rand_q(rng, m, k, n)
    got = quant_matmul(x, w, xs, ws, interpret=True)
    want = ref.quant_matmul_ref(x, w, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 256), (128, 64, 64)])
def test_quant_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    rng = np.random.default_rng(bm)
    x, w, xs, ws = _rand_q(rng, 200, 300, 100)
    got = quant_matmul(x, w, xs, ws, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.quant_matmul_ref(x, w, xs, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


def test_quantize_then_matmul_end_to_end():
    """Float -> per-channel int8 -> kernel ~= float matmul."""
    rng = np.random.default_rng(11)
    x = rng.normal(size=(32, 256)).astype(np.float32)
    w = rng.normal(size=(256, 64)).astype(np.float32)
    xq_p = q.choose_qparams_symmetric(jnp.float32(np.abs(x).max()))
    xq = q.quantize(jnp.asarray(x), xq_p)
    wq, wscale = q.quantize_per_channel(jnp.asarray(w), axis=-1)
    got = quant_matmul(xq, wq, jnp.float32(xq_p.scale), wscale[0], interpret=True)
    err = np.abs(np.asarray(got) - x @ w)
    # K=256 accumulation of int8 quant noise on N(0,1) operands
    assert err.mean() < 0.6, err.mean()


def test_flash_attention_ref_gqa_shapes():
    rng = np.random.default_rng(0)
    q_ = jnp.asarray(rng.normal(size=(2, 8, 16, 32)).astype(np.float32))
    k_ = jnp.asarray(rng.normal(size=(2, 2, 16, 32)).astype(np.float32))
    v_ = jnp.asarray(rng.normal(size=(2, 2, 16, 32)).astype(np.float32))
    out = ref.flash_attention_ref(q_, k_, v_, causal=True)
    assert out.shape == (2, 8, 16, 32)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# byte-packed plane format (8 planes per uint8, unpacked in-kernel)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_bits", [1, 2, 4, 8])
def test_byte_packed_roundtrip(n_bits):
    rng = np.random.default_rng(n_bits)
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1)) - 1
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(64, 32)).astype(np.int8))
    packed = ref.pack_bitplanes_bytes(w, n_bits)
    assert packed.shape == (64, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_bitplanes_bytes(packed, n_bits)),
        np.asarray(ref.pack_bitplanes(w, n_bits)))


@pytest.mark.parametrize("m,k,n", [(4, 16, 32), (100, 130, 60), (128, 256, 256)])
def test_bitserial_matmul_byte_packed_matches_unpacked(m, k, n):
    """The kernel must produce identical results from the byte-packed
    [K, N] uint8 format (8x less VMEM traffic) and the legacy plane stack."""
    rng = np.random.default_rng(m + k * 31 + n)
    x, w, xs, ws = _rand_q(rng, m, k, n)
    planes = ref.pack_bitplanes(w, 8)
    packed = ref.pack_bitplanes_bytes(w, 8)
    a = bitserial_matmul(x, planes, xs, ws, interpret=True)
    b = bitserial_matmul(x, packed, xs, ws, n_bits=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    want = ref.quant_matmul_ref(x, w, xs, ws)
    np.testing.assert_allclose(np.asarray(b), np.asarray(want), rtol=1e-6,
                               atol=1e-5)


@pytest.mark.parametrize("n_bits", [2, 4, 6])
def test_byte_packed_sub8_sign_exact(n_bits):
    """MSB plane carries -2^(n-1): negative sub-8-bit weights must survive
    the byte-packed round trip through the kernel."""
    rng = np.random.default_rng(40 + n_bits)
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1)) - 1
    w = rng.integers(lo, hi + 1, size=(32, 16)).astype(np.int8)
    x = rng.integers(-128, 128, size=(8, 32)).astype(np.int8)
    packed = ref.pack_bitplanes_bytes(jnp.asarray(w), n_bits)
    got = bitserial_matmul(jnp.asarray(x), packed, jnp.float32(1.0),
                           jnp.ones(16, jnp.float32), n_bits=n_bits,
                           interpret=True)
    want = jnp.dot(jnp.asarray(x, jnp.int32), jnp.asarray(w, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.int64), np.asarray(want))


# ---------------------------------------------------------------------------
# W4A4: byte-packed *activations* (2 elements/byte, 2 MXU passes per plane)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (100, 130, 60), (16, 13, 8)])
def test_a4_packed_activations_exact(m, k, n):
    """Nibble-packed activations must be bit-exact with the int GEMM,
    including odd K (dangling nibble padded with zero)."""
    rng = np.random.default_rng(m + k + n)
    x = rng.integers(-8, 8, size=(m, k)).astype(np.int8)
    w = rng.integers(-8, 8, size=(k, n)).astype(np.int8)
    xp = ref.pack_activation_nibbles(jnp.asarray(x))
    assert xp.shape == (m, (k + 1) // 2) and xp.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_activation_nibbles(xp, k)), x)
    from repro.kernels.bitserial_matmul import bitserial_matmul_a4
    got = bitserial_matmul_a4(xp, ref.pack_bitplanes_bytes(jnp.asarray(w), 4),
                              jnp.float32(1.0), jnp.ones(n, jnp.float32),
                              interpret=True)
    want = x.astype(np.int64) @ w.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_a4_matches_w8a8_dequant():
    """Same dequant epilogue semantics as the W8A8 kernel."""
    rng = np.random.default_rng(77)
    x = rng.integers(-8, 8, size=(32, 64)).astype(np.int8)
    w = rng.integers(-8, 8, size=(64, 24)).astype(np.int8)
    xs = np.float32(0.031)
    ws = rng.uniform(0.001, 0.1, size=(24,)).astype(np.float32)
    from repro.kernels.bitserial_matmul import bitserial_matmul_a4
    got = bitserial_matmul_a4(
        ref.pack_activation_nibbles(jnp.asarray(x)),
        ref.pack_bitplanes_bytes(jnp.asarray(w), 4),
        xs, jnp.asarray(ws), interpret=True)
    want = ref.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), xs,
                                jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_a4_hlo_flops_scale_with_planes():
    """Packing activations must not break precision-proportional FLOPs:
    the W4A4 kernel lowers to ~half the MXU work of the 8-plane kernel
    (2 half-K passes x 4 planes vs 1 full-K pass x 8 planes)."""
    from repro.distributed.hlo_analysis import xla_cost_analysis
    from repro.kernels.bitserial_matmul import (bitserial_matmul,
                                                bitserial_matmul_a4)
    rng = np.random.default_rng(5)
    M, K, N = 128, 256, 128
    x8 = jnp.asarray(rng.integers(-128, 128, size=(M, K)).astype(np.int8))
    w8 = ref.pack_bitplanes_bytes(
        jnp.asarray(rng.integers(-128, 128, size=(K, N)).astype(np.int8)), 8)
    f8 = jax.jit(lambda a, p: bitserial_matmul(a, p, 1.0, jnp.ones(N),
                                               n_bits=8))
    fl8 = xla_cost_analysis(f8.lower(x8, w8).compile()).get("flops", 0)
    x4 = ref.pack_activation_nibbles(
        jnp.asarray(rng.integers(-8, 8, size=(M, K)).astype(np.int8)))
    w4 = ref.pack_bitplanes_bytes(
        jnp.asarray(rng.integers(-8, 8, size=(K, N)).astype(np.int8)), 4)
    f4 = jax.jit(lambda a, p: bitserial_matmul_a4(a, p, 1.0, jnp.ones(N),
                                                  n_bits=4))
    fl4 = xla_cost_analysis(f4.lower(x4, w4).compile()).get("flops", 0)
    assert fl8 > 0 and fl4 > 0
    assert 0.35 < fl4 / fl8 < 0.65, (fl4, fl8)


def test_a4_ops_wrapper_fallback_matches_kernel():
    """ops.bitserial_matmul_a4's XLA fallback equals the Pallas kernel."""
    from repro.kernels import ops as K
    rng = np.random.default_rng(8)
    x = rng.integers(-8, 8, size=(16, 40)).astype(np.int8)
    w = rng.integers(-8, 8, size=(40, 12)).astype(np.int8)
    xp = K.pack_activations(jnp.asarray(x))
    wp = K.pack_weights(jnp.asarray(w, jnp.int32), 4)
    a = K.bitserial_matmul_a4(xp, wp, jnp.float32(1.0),
                              jnp.ones(12, jnp.float32), k=40)
    b = K.bitserial_matmul_a4(xp, wp, jnp.float32(1.0),
                              jnp.ones(12, jnp.float32), k=40,
                              prefer_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
