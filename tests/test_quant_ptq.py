"""PTQ pipeline: calibration, weight conversion, quantized serving ops."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import choose_qparams, choose_qparams_symmetric
from repro.quant import (CalibrationStats, QuantizedLinear, bitserial_linear,
                         quantize_lm_params, quantized_matmul)


def _wq(key, k=64, n=48, bits=8):
    from repro.core.quantize import quantize_per_channel
    from repro.kernels import ops as K
    w = jax.random.normal(key, (k, n), jnp.float32) * 0.3
    q, scale = quantize_per_channel(w, axis=-1, bits=bits)
    out = {"q": q, "scale": scale.reshape(-1)}
    if bits < 8:
        out["planes"] = K.pack_weights(q.astype(jnp.int32), bits)
        out["plane_bits"] = bits
    return w, out


def test_weight_only_matmul_close_to_fp():
    w, wq = _wq(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (8, 64), jnp.float32)
    y = quantized_matmul(x, wq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=0.05, atol=0.05)


@pytest.mark.parametrize("signed", [True, False])
def test_w8a8_matmul_with_zero_point(signed):
    w, wq = _wq(jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (8, 64), jnp.float32) + 0.7
    qp = choose_qparams(jnp.min(x), jnp.max(x), bits=8, signed=signed)
    y = quantized_matmul(x, wq, qp)
    ref = x @ w
    err = np.abs(np.asarray(y) - np.asarray(ref))
    assert err.mean() < 0.06, err.mean()


@pytest.mark.parametrize("bits", [2, 4, 6])
def test_bitserial_linear_matches_quant_path(bits):
    w, wq = _wq(jax.random.key(4), bits=bits)
    x = jax.random.normal(jax.random.key(5), (4, 64), jnp.float32)
    qp = choose_qparams_symmetric(jnp.max(jnp.abs(x)))
    y_planes = bitserial_linear(x, wq, qp)
    # oracle: dequantized weights through the same activation quantization
    from repro.core.quantize import quantize
    xq = quantize(x, qp).astype(jnp.float32) * qp.scale
    ref = xq @ (wq["q"].astype(jnp.float32) * wq["scale"][None, :])
    np.testing.assert_allclose(np.asarray(y_planes), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_quantize_lm_params_structure():
    from repro.configs import get_config, reduced_config
    from repro.models import transformer as T
    cfg = reduced_config(get_config("qwen2-7b"))
    params = T.init_lm(cfg, jax.random.key(0))
    qparams = quantize_lm_params(params)
    wq = qparams["stages"][0]["attn"]["wq"]
    assert wq["q"].dtype == jnp.int8
    assert wq["q"].shape == params["stages"][0]["attn"]["wq"].shape
    # norms untouched (same leaf objects)
    assert qparams["stages"][0]["norm1"]["w"] is \
        params["stages"][0]["norm1"]["w"]
    # embeddings skipped by default
    assert not isinstance(qparams["embed"], dict)


def test_calibration_stats_ema():
    st = CalibrationStats(momentum=0.5)
    st.observe("h", jnp.array([-1.0, 2.0]))
    st.observe("h", jnp.array([-3.0, 0.5]))
    qp = st.qparams("h")
    assert float(st.mins["h"]) == pytest.approx(-2.0)
    assert float(st.maxs["h"]) == pytest.approx(1.25)
    assert qp.scale > 0
