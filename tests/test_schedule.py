"""Slice-scheduler: one plan object from mapper -> packed engine -> serving.

Covers SlicePlan/NetworkSchedule invariants, batch tiling against the cache
geometry, §VI-C filter residency (bytes loaded once per layer per batch),
the §IV-E spill decision as the simulator's single source of truth, and
simulate_network parity when consuming a schedule."""
import math

import numpy as np
import pytest

from repro.core import bitserial as bs
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.mapper import LayerSpec, map_layer
from repro.core.schedule import (NetworkSchedule, SlicePlan, conv_tiles,
                                 plan_layer, plan_network)
from repro.core.simulator import PAPER, simulate_network, throughput
from repro.models.inception import inception_v3_specs

GEOM = XEON_E5_35MB


def _conv_spec(name="c", H=16, R=3, C=8, M=16, E=14, stride=1):
    return LayerSpec(name=name, kind="conv", H=H, R=R, S=R, C=C, M=M, E=E,
                     stride=stride)


# ---------------------------------------------------------------------------
# plan_layer invariants
# ---------------------------------------------------------------------------
def test_plan_matches_mapper():
    spec = _conv_spec()
    plan = plan_layer(spec, GEOM)
    m = map_layer(spec, GEOM)
    assert plan.mapped == m
    assert plan.serial_passes == m.serial_passes
    assert plan.filter_bytes == spec.filter_bytes
    assert plan.K == spec.R * spec.S * spec.C
    assert plan.row_bits == 1 << (plan.K - 1).bit_length()
    assert plan.quant_passes == math.ceil(spec.output_bytes / GEOM.compute_slots)
    assert plan.minmax_cycles == bs.minmax_cycles(spec.output_bytes, 32)


def test_plan_tile_bound_by_compute_slots():
    """A tile's bit lines (rows x P x filters) never exceed the geometry."""
    for batch in (1, 4, 16):
        for spec in (_conv_spec(), _conv_spec(C=128, M=64, E=35, R=3, H=37),
                     _conv_spec(C=3, M=8, E=39, H=79, stride=2)):
            plan = plan_layer(spec, GEOM, batch)
            used = plan.row_bits * plan.tile_rows * plan.tile_filters
            assert used <= max(GEOM.compute_slots, plan.row_bits), (batch, spec)
            # tiles cover all the work
            pixels = spec.E * spec.E
            assert (plan.tiles >= math.ceil(batch * pixels / plan.tile_rows)
                    * math.ceil(spec.M / plan.tile_filters) - 0)


def test_batch_tiling_folds_images():
    """Small layers fold whole images into one MAC+reduce tile; the fold
    grows with the batch until the geometry cap bites."""
    spec = _conv_spec(H=6, R=3, C=4, M=4, E=4)
    p1 = plan_layer(spec, GEOM, batch=1)
    p8 = plan_layer(spec, GEOM, batch=8)
    assert p1.batch_tile == 1
    assert p8.batch_tile == 8  # tiny layer: all 8 images in one tile
    assert p8.tile_rows == 8 * 16
    assert p8.total_passes == 8 * p1.total_passes


def test_batch_tile_caps_at_geometry():
    spec = _conv_spec(H=149, R=3, C=32, M=32, E=147)  # big: P*E*E ~ 5.5M
    plan = plan_layer(spec, GEOM, batch=8)
    assert plan.batch_tile == 1  # a single image already overflows a tile
    assert plan.row_bits * plan.tile_rows * plan.tile_filters <= GEOM.compute_slots


def test_conv_tiles_batch1_matches_legacy_semantics():
    """At batch=1 the planner's tiles equal the pre-schedule tiler's."""
    E = F = 12
    tr, tf = conv_tiles(E, F, 16, 72, GEOM, batch=1)
    assert tr == E * F and tf == 16  # fits: P(128)*144*16 < compute_slots
    # caller overrides clamp to the work
    tr, tf = conv_tiles(E, F, 16, 72, GEOM, batch=1, tile_pixels=10 ** 6)
    assert tr == E * F


def test_pool_plan_fields():
    spec = LayerSpec("p", "maxpool", H=28, R=3, S=3, C=0, M=8, E=13, stride=2)
    plan = plan_layer(spec, GEOM, batch=3)
    assert plan.filter_bytes == 0 and plan.quant_passes == 0
    assert plan.minmax_cycles == 0
    assert plan.total_passes == 3 * plan.serial_passes


# ---------------------------------------------------------------------------
# NetworkSchedule: §VI-C residency + §IV-E spill, one source of truth
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def paper_specs():
    return inception_v3_specs()


def test_filter_bytes_loaded_once_per_layer_per_batch(paper_specs):
    """§VI-C: filters stay resident while the batch streams — the loaded
    bytes are independent of batch size."""
    s1 = plan_network(paper_specs, GEOM, batch=1)
    s64 = plan_network(paper_specs, GEOM, batch=64)
    want = sum(s.filter_bytes for s in paper_specs)
    assert s1.filter_bytes_loaded == s64.filter_bytes_loaded == want
    # but the pass count does scale with the batch (layer-serial §IV-E)
    assert s64.total_passes == 64 * s1.total_passes


def test_spill_decision_matches_simulator_model(paper_specs):
    sched = plan_network(paper_specs, GEOM, batch=4)
    cap = GEOM.io_way_bytes / 2
    for plan in sched.layers:
        assert plan.spill_to_dram == (plan.spec.output_bytes > cap / 2)
        if plan.spill_to_dram:
            assert plan.spill_bytes_per_image == 2 * plan.spec.output_bytes
    # Inception v3 spills only its earliest, widest layers (§IV-E prose:
    # "the first five layers")
    spilling = [p.spec.name for p in sched.layers if p.spill_to_dram]
    assert 0 < len(spilling) <= 6
    assert all(s in {p.spec.name for p in sched.layers[:7]} for s in spilling)


def test_stream_batch_limit(paper_specs):
    sched = plan_network(paper_specs, GEOM, batch=1)
    assert sched.stream_batch_limit >= 1
    # the widest layer dominates; a 60MB-class part streams deeper batches
    bigger = plan_network(paper_specs, GEOM.scaled(24), batch=1)
    assert bigger.stream_batch_limit >= sched.stream_batch_limit


# ---------------------------------------------------------------------------
# simulate_network consumes the schedule (no residency re-derivation)
# ---------------------------------------------------------------------------
def test_simulate_network_schedule_parity(paper_specs):
    r_specs = simulate_network(paper_specs)
    r_sched = simulate_network(plan_network(paper_specs, GEOM, batch=42))
    assert r_sched.latency_s == pytest.approx(r_specs.latency_s, rel=1e-12)
    assert r_sched.energy_j == pytest.approx(r_specs.energy_j, rel=1e-12)
    assert r_sched.spill_s_per_image() == pytest.approx(
        r_specs.spill_s_per_image(), rel=1e-12)
    # every layer result carries the plan it priced
    assert all(l.plan is not None for l in r_sched.layers)
    assert r_sched.schedule.batch == 42
    # §VI-C assert: filter bytes loaded once per layer per batch
    assert (r_sched.filter_bytes_loaded == r_specs.filter_bytes_loaded
            == sum(s.filter_bytes for s in paper_specs))
    assert r_sched.filter_s == pytest.approx(r_specs.filter_s, rel=1e-12)


def test_schedule_throughput_still_hits_paper(paper_specs):
    r = simulate_network(plan_network(paper_specs, GEOM, batch=64))
    assert throughput(r, 64) == pytest.approx(PAPER["nc_throughput"], rel=0.05)


def test_schedule_lookup():
    specs = inception_v3_specs()
    sched = plan_network(specs, GEOM, batch=2)
    p = sched.plan("Conv2d_2b_3x3")
    assert isinstance(p, SlicePlan) and p.spec.name == "Conv2d_2b_3x3"
    assert p.serial_passes == PAPER["conv2d_2b_serial"]
    with pytest.raises(KeyError):
        sched.plan("nope")
