"""Sparsity-aware slice scheduling, locked down by a differential harness.

Three layers of defense:

* **Differential property sweep** — random per-filter prunings (0%, 25%,
  75%, 100% zero filters) and per-plane prunings through ``nc_conv2d`` /
  ``nc_fc``: the sparse run (pruned pass list) must return BYTE-IDENTICAL
  outputs to the dense run on the same weights, across SAME/VALID padding,
  stride 2, batch 1 and 4, and non-dividing tiles.
* **Schedule invariants** — skipped-pass credits monotone in sparsity,
  zero-sparsity plans structurally equal to dense plans,
  ``stream_batch_limit`` pruning-independent, and the simulator's
  dense-vs-sparse delta equal to the skip credit TO THE CYCLE.
* **Golden cycle-model regression** — ``tests/golden/modeled_cycles.json``
  freezes ``simulate_network``'s per-layer modeled cycles for
  ``reduced_config`` (dense, a fixed 50% pruning, and the §IV-E
  overlapped plan's per-layer hidden-load credits, on the paper
  geometry and a 1-slice scale-down where passes actually serialize).
  Any cycle-model drift fails tier-1; regenerate deliberately with
  ``REGEN_GOLDEN=1 pytest tests/test_sparsity.py``.
"""
import dataclasses
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitserial as bs
from repro.core import nc_layers as nc
from repro.core import quantize as q
from repro.core import schedule as sched
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.mapper import LayerSpec, serial_passes_for
from repro.core.simulator import modeled_layer_cycles, simulate_network
from repro.models import inception

GEOM = XEON_E5_35MB
GEOM_1SLICE = XEON_E5_35MB.scaled(1)
GOLDEN = pathlib.Path(__file__).parent / "golden" / "modeled_cycles.json"
PRUNE_FRACTIONS = (0.0, 0.25, 0.75, 1.0)


def _pruned_case(rng, M=8, C=3, R=3, frac=0.5, zp=7):
    """Integer (already-quantized) weights with round(M*frac) random
    filters set to the zero point — dequantized exactly zero."""
    wq = rng.integers(0, 256, size=(R, R, C, M)).astype(np.uint8)
    k = int(round(M * frac))
    idx = rng.choice(M, size=k, replace=False)
    wq[..., idx] = zp
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=zp)
    return wq, w_qp, np.sort(idx)


# ---------------------------------------------------------------------------
# Differential property sweep: sparse == dense, byte for byte
# ---------------------------------------------------------------------------
@given(
    frac=st.sampled_from(PRUNE_FRACTIONS),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["VALID", "SAME"]),
    batch=st.sampled_from([1, 4]),
    tile_pixels=st.sampled_from([None, 7]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_sparse_conv_bit_exact_vs_dense(frac, stride, padding, batch,
                                        tile_pixels, seed):
    rng = np.random.default_rng(seed)
    wq, w_qp, idx = _pruned_case(rng, frac=frac)
    shape = (batch, 8, 8, 3) if batch > 1 else (8, 8, 3)
    x = rng.normal(size=shape).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    qps = [x_qp] * batch if batch > 1 else x_qp
    dense, cyc_d = nc.nc_conv2d(x, wq, qps, w_qp, stride, padding=padding,
                                tile_pixels=tile_pixels)
    sparse, cyc_s, stats = nc.nc_conv2d(
        x, wq, qps, w_qp, stride, padding=padding, tile_pixels=tile_pixels,
        occupancy="detect", return_stats=True)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(dense))
    assert stats.zero_filters == len(idx)
    # the engine charges §III cycles only for the executed (live) lanes
    assert cyc_s <= cyc_d
    if frac == 0.0:
        assert cyc_s == cyc_d
    if frac == 1.0:
        assert cyc_s == 0


@given(
    frac=st.sampled_from(PRUNE_FRACTIONS),
    k=st.sampled_from([9, 23, 40]),
    tile_filters=st.sampled_from([None, 3]),
    batch=st.sampled_from([1, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=8, deadline=None)
def test_sparse_fc_bit_exact_vs_dense(frac, k, tile_filters, batch, seed):
    rng = np.random.default_rng(seed)
    M, zp = 8, 3
    wq = rng.integers(0, 256, size=(k, M)).astype(np.uint8)
    idx = rng.choice(M, size=int(round(M * frac)), replace=False)
    wq[:, idx] = zp
    w_qp = q.QuantParams(scale=np.float32(0.1), zero_point=zp)
    shape = (batch, k) if batch > 1 else (k,)
    x = rng.normal(size=shape).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    qps = [x_qp] * batch if batch > 1 else x_qp
    dense, cyc_d = nc.nc_fc(x, wq, qps, w_qp, tile_filters=tile_filters)
    sparse, cyc_s = nc.nc_fc(x, wq, qps, w_qp, tile_filters=tile_filters,
                             occupancy="detect")
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(dense))
    assert cyc_s <= cyc_d


@given(
    planes=st.sampled_from([(0,), (3,), (0, 1), (2, 5, 7), (7,)]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=6, deadline=None)
def test_per_plane_pruning_elides_dead_planes_bit_exact(planes, stride, seed):
    """Per-plane pruning: weights whose live bits sit in a few planes.  The
    host multiply elides the dead shifted-add steps (an all-zero tag word
    is an identity) — results must be bit-identical with elision disabled,
    and the elision must show in SKIP_STATS."""
    rng = np.random.default_rng(seed)
    keep = 0
    for p in planes:
        keep |= 1 << p
    wq = (rng.integers(0, 256, size=(3, 3, 2, 4)) & keep).astype(np.uint8)
    x = rng.normal(size=(7, 7, 2)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=0)
    bs.ZERO_SKIP = False
    ref, cyc_ref = nc.nc_conv2d(x, wq, x_qp, w_qp, stride)
    bs.ZERO_SKIP = True
    bs.SKIP_STATS.reset()
    out, cyc = nc.nc_conv2d(x, wq, x_qp, w_qp, stride)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert cyc == cyc_ref  # modeled cycles never change from elision
    snap = bs.SKIP_STATS.snapshot()
    assert snap["planes_total"] > 0
    # every weight bit outside `planes` is dead in every tile's multiply
    assert snap["planes_skipped"] >= snap["planes_total"] // 8 * (8 - len(planes))


def test_tile_override_keeps_plan_occupancy():
    """Replanning for a caller's tile override must carry the sparse
    plan's occupancy along, not silently fall back to dense."""
    rng = np.random.default_rng(8)
    wq, w_qp, idx = _pruned_case(rng, frac=0.5)
    x = rng.normal(size=(8, 8, 3)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    spec = LayerSpec(name="nc_conv2d", kind="conv", H=8, R=3, S=3, C=3, M=8,
                     E=6)
    occ = sched.LayerOccupancy.from_filter_rows(
        wq.reshape(-1, 8).T, 8, int(w_qp.zero_point))
    plan = sched.plan_layer(spec, GEOM, occupancy=occ)
    dense, _ = nc.nc_conv2d(x, wq, x_qp, w_qp)
    out, _, stats = nc.nc_conv2d(x, wq, x_qp, w_qp, plan=plan,
                                 tile_filters=2, return_stats=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dense))
    assert stats.zero_filters == len(idx)


def test_occupancy_detection_matches_weights():
    rng = np.random.default_rng(5)
    wq, w_qp, idx = _pruned_case(rng, M=16, frac=0.5, zp=9)
    rows = wq.reshape(-1, 16).T
    zero_mask, plane_live = bs.filter_occupancy(rows, 8, 9)
    np.testing.assert_array_equal(np.flatnonzero(zero_mask), idx)
    occ = sched.LayerOccupancy.from_filter_rows(rows, 8, 9)
    assert occ.zero_filters == tuple(int(i) for i in idx)
    assert occ.n_live == 16 - len(idx)
    assert 0 <= occ.dead_planes < 8


def test_overclaiming_occupancy_raises_underclaiming_allowed():
    """An occupancy marking a LIVE filter as zero would corrupt results —
    the engine validates against the actual weights.  Marking fewer
    filters than are actually zero is safe (they just run dense)."""
    rng = np.random.default_rng(6)
    wq, w_qp, idx = _pruned_case(rng, M=8, frac=0.5, zp=7)
    x = rng.normal(size=(6, 6, 3)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    live = [m for m in range(8) if m not in idx]
    with pytest.raises(ValueError, match="stale plan"):
        nc.nc_conv2d(x, wq, x_qp, w_qp,
                     occupancy=sched.LayerOccupancy(8, (live[0],)))
    dense, _ = nc.nc_conv2d(x, wq, x_qp, w_qp)
    under, _ = nc.nc_conv2d(x, wq, x_qp, w_qp,
                            occupancy=sched.LayerOccupancy(8, (int(idx[0]),)))
    np.testing.assert_array_equal(np.asarray(under), np.asarray(dense))


# ---------------------------------------------------------------------------
# Schedule invariants
# ---------------------------------------------------------------------------
def _spec(M=64, E=35, C=32, R=3):
    return LayerSpec(name="s", kind="conv", H=E + R - 1, R=R, S=R, C=C, M=M,
                     E=E)


def test_skip_credit_monotone_in_sparsity():
    spec = _spec()
    dense = sched.plan_layer(spec, GEOM_1SLICE, batch=2)
    prev_skipped, prev_bytes = 0, dense.filter_bytes + 1
    for zf in range(spec.M + 1):
        occ = sched.LayerOccupancy(spec.M, tuple(range(zf)))
        p = sched.plan_layer(spec, GEOM_1SLICE, batch=2, occupancy=occ)
        assert p.skipped_passes >= prev_skipped  # monotone credit
        assert 0 <= p.skipped_passes <= dense.serial_passes
        assert p.executed_passes == dense.serial_passes - p.skipped_passes
        assert p.filter_bytes < prev_bytes or zf == 0
        prev_skipped, prev_bytes = p.skipped_passes, p.filter_bytes
    # full pruning skips every pass and loads no filters
    assert p.executed_passes == 0 and p.filter_bytes == 0
    # the sparse pass count follows the mapper's ONE serialization rule
    occ = sched.LayerOccupancy(spec.M, tuple(range(24)))
    p = sched.plan_layer(spec, GEOM_1SLICE, occupancy=occ)
    assert p.executed_passes == serial_passes_for(
        (spec.M - 24) * spec.E * spec.E, p.mapped.parallel_convs)


def test_degenerate_spec_still_maps_to_one_idle_pass():
    """The shared serial_passes_for rule must not regress map_layer's
    handling of zero-work specs (serial=1, utilization=0)."""
    from repro.core.mapper import map_layer
    m = map_layer(LayerSpec(name="d", kind="conv", H=3, R=3, S=3, C=2, M=8,
                            E=0))
    assert m.serial_passes == 1 and m.utilization == 0.0


def test_zero_sparsity_plan_structurally_equal_to_dense():
    """A plan with zero detected sparsity is the PR-3 plan, field for
    field (occupancy metadata aside)."""
    for spec in (_spec(), _spec(M=8, E=4, C=4)):
        for batch in (1, 4):
            dense = sched.plan_layer(spec, GEOM, batch=batch)
            zocc = sched.LayerOccupancy(spec.M, ())
            sparse = sched.plan_layer(spec, GEOM, batch=batch, occupancy=zocc)
            assert sparse.skipped_passes == 0
            assert dataclasses.replace(sparse, occupancy=None) == dense


@pytest.fixture(scope="module")
def reduced_specs():
    return inception.inception_v3_specs(inception.reduced_config())


def test_network_invariants_under_pruning(reduced_specs):
    occ = sched.prune_occupancy(reduced_specs, 0.75)
    dense = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4)
    sparse = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                                occupancy=occ)
    # stream_batch_limit depends on activation bytes only — pruning-proof
    assert sparse.stream_batch_limit == dense.stream_batch_limit
    # spill decisions unchanged (outputs stream at full width)
    assert [p.spill_to_dram for p in sparse.layers] == \
        [p.spill_to_dram for p in dense.layers]
    assert sparse.skipped_passes > 0
    assert sparse.filter_bytes_loaded < dense.filter_bytes_loaded
    # monotone at the network level too
    lighter = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                                 occupancy=sched.prune_occupancy(
                                     reduced_specs, 0.25))
    assert lighter.skipped_passes <= sparse.skipped_passes


def test_modeled_cycles_drop_by_skip_credit_exactly(reduced_specs):
    """Acceptance: sparse modeled cycles == dense - credit, per layer, on
    both the paper geometry and the 1-slice scale-down."""
    occ = sched.prune_occupancy(reduced_specs, 0.5)
    for geom in (GEOM, GEOM_1SLICE):
        dense = sched.plan_network(reduced_specs, geom, batch=1)
        sparse = sched.plan_network(reduced_specs, geom, batch=1,
                                    occupancy=occ)
        for pd, ps in zip(dense.layers, sparse.layers):
            md = modeled_layer_cycles(pd, geom)
            ms = modeled_layer_cycles(ps, geom)
            assert ms["per_pass_cycles"] == md["per_pass_cycles"]
            assert md["total_cycles"] - ms["total_cycles"] == \
                ms["skip_credit_cycles"]
            assert ms["skip_credit_cycles"] == \
                ms["per_pass_cycles"] * ms["skipped_passes"]


def test_simulator_dense_bit_identical_with_sparsity_off(reduced_specs):
    """Sparsity off (no occupancy / zero occupancy) must not move a single
    bit of the dense model's numbers."""
    r_dense = simulate_network(sched.plan_network(reduced_specs, GEOM))
    zocc = {s.name: sched.LayerOccupancy(s.M, ())
            for s in reduced_specs if s.kind in ("conv", "fc")}
    r_zero = simulate_network(sched.plan_network(reduced_specs, GEOM,
                                                 occupancy=zocc))
    assert r_zero.latency_s == r_dense.latency_s
    assert r_zero.energy_j == r_dense.energy_j
    assert r_zero.filter_s == r_dense.filter_s
    for ld, lz in zip(r_dense.layers, r_zero.layers):
        assert (lz.mac_s, lz.reduce_s, lz.quant_s, lz.pool_s) == \
            (ld.mac_s, ld.reduce_s, ld.quant_s, ld.pool_s)


# ---------------------------------------------------------------------------
# Golden cycle-model regression (regenerate with REGEN_GOLDEN=1)
# ---------------------------------------------------------------------------
def _golden_payload():
    cfg = inception.reduced_config()
    specs = inception.inception_v3_specs(cfg)
    occ = sched.prune_occupancy(specs, 0.5)

    def table(schedule, geom):
        out = {}
        for p in schedule.layers:
            m = modeled_layer_cycles(p, geom)
            out[p.spec.name] = {
                "per_pass_cycles": float(m["per_pass_cycles"]),
                "serial_passes": int(m["serial_passes"]),
                "skipped_passes": int(m["skipped_passes"]),
                "total_cycles": float(m["total_cycles"]),
            }
        return out

    def overlap_table(schedule, geom):
        """§IV-E double buffering: freeze which layers are granted the
        overlap and the seconds each hides — total_cycles stays the
        dense table's (overlap re-times copies, never compute)."""
        out = {}
        for p in schedule.layers:
            m = modeled_layer_cycles(p, geom)
            out[p.spec.name] = {
                "overlap": bool(m["overlap"]),
                "hidden_s": float(m["hidden_s"]),
                "overlapped_total_s": float(m["overlapped_total_s"]),
                "total_cycles": float(m["total_cycles"]),
            }
        return out

    payload = {"config": cfg.name, "pruning": 0.5, "geometries": {}}
    for geom in (GEOM, GEOM_1SLICE):
        payload["geometries"][geom.name] = {
            "dense": table(sched.plan_network(specs, geom), geom),
            "pruned": table(
                sched.plan_network(specs, geom, occupancy=occ), geom),
            "overlapped": overlap_table(
                sched.plan_network(specs, geom, overlap=True), geom),
        }
    return payload


def test_golden_modeled_cycles_frozen():
    """Per-layer modeled cycles (dense AND fixed 50% pruning) must match
    tests/golden/modeled_cycles.json bit for bit.  Cycle-model changes are
    allowed only deliberately: rerun with ``REGEN_GOLDEN=1`` and commit
    the refreshed file."""
    payload = _golden_payload()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.parent.mkdir(exist_ok=True)
        GOLDEN.write_text(json.dumps(payload, indent=1, sort_keys=True)
                          + "\n")
    want = json.loads(GOLDEN.read_text())
    assert payload == want, (
        "modeled cycle drift vs tests/golden/modeled_cycles.json — if the "
        "cycle model changed on purpose, regenerate with REGEN_GOLDEN=1")


# ---------------------------------------------------------------------------
# End-to-end acceptance (slow-marked; exercised by benchmarks/run.py's gate)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_nc_forward_sparse_acceptance_batch4():
    """reduced_config + fixed 50% filter pruning at batch 4: sparse
    nc_forward is byte-identical to dense on the same pruned weights,
    modeled cycles drop by the skip credit, and warm wall time per image
    is below the dense run's."""
    import time

    cfg = inception.reduced_config()
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    wpack = inception.prune_wpack(
        inception.prepare_conv_weights(params, cfg), 0.5)
    xb = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (4, cfg.img, cfg.img, 3), jnp.float32))

    wall_d = wall_s = float("inf")
    for _ in range(2):  # first pass warms the bucketed-jit caches
        t0 = time.perf_counter()
        ld, rd = inception.nc_forward(params, xb, config=cfg, wpack=wpack)
        wall_d = min(wall_d, time.perf_counter() - t0)
        t0 = time.perf_counter()
        ls, rs = inception.nc_forward(params, xb, config=cfg, wpack=wpack,
                                      sparse=True)
        wall_s = min(wall_s, time.perf_counter() - t0)

    np.testing.assert_array_equal(np.asarray(ls), np.asarray(ld))
    assert rs.total_emulated_cycles < rd.total_emulated_cycles
    # modeled credit: exact per layer (pass counts are 1 at paper scale for
    # this miniature, so assert on the 1-slice geometry where they bite)
    specs = inception.inception_v3_specs(cfg)
    occ = inception.network_occupancy(wpack, cfg)
    dense1 = sched.plan_network(specs, GEOM_1SLICE, batch=4)
    sparse1 = sched.plan_network(specs, GEOM_1SLICE, batch=4, occupancy=occ)
    assert sparse1.skipped_passes > 0
    for pd, ps in zip(dense1.layers, sparse1.layers):
        md = modeled_layer_cycles(pd, GEOM_1SLICE)
        ms = modeled_layer_cycles(ps, GEOM_1SLICE)
        assert md["total_cycles"] - ms["total_cycles"] == \
            ms["skip_credit_cycles"]
    # every conv pruned half its filters and the engine never ran them
    for l in rs.layers:
        if l.kind in ("conv", "fc"):
            assert l.zero_filters == round(0.5 * l.out_shape[-1])
    # wall time: half the filter columns never enter the packed engine
    assert wall_s < wall_d, (wall_s, wall_d)


@pytest.mark.slow
def test_nc_serving_engine_sparse_bit_exact():
    """A serving deployment of a PRUNED model (half of every conv's output
    channels zeroed in the float weights — they quantize exactly to the
    zero point) plans sparse by default and still answers byte-identically
    to dense standalone runs."""
    from repro.launch.serve import NCRequest, NCServingEngine

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    for name, p in params.items():  # prune: last half of output channels
        w = np.array(p["w"], copy=True)
        w[..., w.shape[-1] // 2:] = 0.0
        p["w"] = jnp.asarray(w)
    eng = NCServingEngine(params, cfg, max_batch=2)
    assert eng.occupancy is not None
    assert sum(o.n_zero for o in eng.occupancy.values()) > 0
    rng = np.random.default_rng(0)
    imgs = rng.random((3, 47, 47, 3)).astype(np.float32)
    for r in range(3):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    done = eng.run()
    assert len(done) == 3
    for r in done:  # dense standalone reference: byte-identical
        ref, _ = inception.nc_forward(params, imgs[r.rid], config=cfg)
        np.testing.assert_array_equal(r.logits, np.asarray(ref))
    # every batch report saw the pruned filters skipped by the engine
    for rep in eng.reports:
        assert sum(l.zero_filters for l in rep.layers) > 0


# ---------------------------------------------------------------------------
# Compressed filter residency (PR 8): CSR bit-plane store + plan flag
# ---------------------------------------------------------------------------
@given(
    frac=st.sampled_from((0.0, 0.25, 0.5, 0.75, 1.0)),
    tail=st.sampled_from([(1,), (1, 3), (2,)]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_compressed_planes_roundtrip(frac, tail, seed):
    """CompressedPlanes pack/unpack is byte-identical to the dense grid,
    whole and per column range, and the footprint shrinks with pruning."""
    rng = np.random.default_rng(seed)
    n, M = 8, 12
    grid = rng.integers(0, 2**32, size=(n, M) + tail, dtype=np.uint32)
    k = int(round(M * frac))
    if k:
        grid[:, rng.choice(M, size=k, replace=False)] = 0
    cp = bs.CompressedPlanes.compress(grid)
    np.testing.assert_array_equal(cp.dense(), grid)
    for m0, m1 in ((0, M), (0, 1), (3, 7), (M - 1, M), (5, 5)):
        np.testing.assert_array_equal(cp.dense_columns(m0, m1),
                                      grid[:, m0:m1])
    assert cp.n_columns == M and cp.tail_shape == tuple(tail)
    assert cp.payload_bytes + cp.index_bytes == cp.nbytes
    if frac >= 0.5:
        assert cp.nbytes < grid.nbytes
    if frac == 1.0:
        assert cp.payload_bytes == 0 and cp.live_planes == 0


@given(
    frac=st.sampled_from((0.0, 0.25, 0.5, 0.75, 1.0)),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["VALID", "SAME"]),
    batch=st.sampled_from([1, 4]),
    tile_pixels=st.sampled_from([None, 7]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_compressed_conv_bit_exact_vs_dense(frac, stride, padding, batch,
                                            tile_pixels, seed):
    """The differential harness, compressed: executing from the CSR
    bit-plane store must be byte-identical to the dense store at every
    pruning level, across padding/stride/batch/tiling."""
    rng = np.random.default_rng(seed)
    wq, w_qp, _ = _pruned_case(rng, frac=frac)
    shape = (batch, 8, 8, 3) if batch > 1 else (8, 8, 3)
    x = rng.normal(size=shape).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    qps = [x_qp] * batch if batch > 1 else x_qp
    dense, cyc_d = nc.nc_conv2d(x, wq, qps, w_qp, stride, padding=padding,
                                tile_pixels=tile_pixels)
    comp, cyc_c, stats = nc.nc_conv2d(
        x, wq, qps, w_qp, stride, padding=padding, tile_pixels=tile_pixels,
        occupancy="detect", compressed=True, return_stats=True)
    np.testing.assert_array_equal(np.asarray(comp), np.asarray(dense))
    assert stats.compressed
    if frac < 1.0:
        assert stats.csr_payload_bytes > 0


def test_compressed_fc_bit_exact_vs_dense():
    rng = np.random.default_rng(7)
    M, zp, k = 8, 3, 23
    wq = rng.integers(0, 256, size=(k, M)).astype(np.uint8)
    wq[:, [1, 4]] = zp
    w_qp = q.QuantParams(scale=np.float32(0.1), zero_point=zp)
    x = rng.normal(size=(4, k)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    dense, _ = nc.nc_fc(x, wq, [x_qp] * 4, w_qp)
    comp, _, stats = nc.nc_fc(x, wq, [x_qp] * 4, w_qp, occupancy="detect",
                              compressed=True, return_stats=True)
    np.testing.assert_array_equal(np.asarray(comp), np.asarray(dense))
    assert stats.compressed


def test_compressed_with_explicit_plan_raises():
    spec = _spec(M=8, E=4, C=4)
    plan = sched.plan_layer(spec, GEOM)
    rng = np.random.default_rng(0)
    wq, w_qp, _ = _pruned_case(rng, M=8, C=4, frac=0.0)
    x = rng.normal(size=(4, 4, 4)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    with pytest.raises(ValueError, match="ambiguous"):
        nc.nc_conv2d(x, wq, x_qp, w_qp, layer_spec=spec, plan=plan,
                     compressed=True)


def test_compression_off_plan_equal_and_carryover(reduced_specs):
    """compressed=False is the PR 7 plan, field for field; a compressed
    plan round-trips its flag through schedules and carries the exact
    residency bookkeeping."""
    base = sched.plan_network(reduced_specs, GEOM, batch=4)
    off = sched.plan_network(reduced_specs, GEOM, batch=4, compressed=False)
    assert base == off
    comp = sched.plan_network(reduced_specs, GEOM, batch=4, compressed=True)
    assert comp.compressed
    for pb, pc in zip(base.layers, comp.layers):
        if pc.spec.kind in ("conv", "fc") and pb.filter_bytes:
            assert pc.compressed
            assert pc.dense_filter_bytes == pb.filter_bytes
            assert pc.residency_credit_bytes == \
                pb.filter_bytes - pc.filter_bytes
        else:
            assert pc.residency_credit_bytes == 0


def test_residency_credit_exact_per_layer_and_batch(reduced_specs):
    """Acceptance: dense minus compressed modeled time equals the
    residency credit to 1e-12, per layer and per batch (overlap-off plans
    — overlap re-times hidden loads and is gated separately)."""
    from repro.core.simulator import batch_time_s

    for occ in (None, sched.prune_occupancy(reduced_specs, 0.5)):
        dense = sched.plan_network(reduced_specs, GEOM, batch=4,
                                   occupancy=occ)
        comp = sched.plan_network(reduced_specs, GEOM, batch=4,
                                  occupancy=occ, compressed=True)
        rd, rc = simulate_network(dense), simulate_network(comp)
        for ld, lc in zip(rd.layers, rc.layers):
            assert abs((ld.total_s - lc.total_s)
                       - lc.residency_credit_s) < 1e-12
        for n in (1, 2, 4, 8, 16):
            assert abs((batch_time_s(rd, n) - batch_time_s(rc, n))
                       - rc.residency_credit_s) < 1e-12
        assert abs(rc.residency_credit_s
                   - comp.residency_credit_bytes / 10.96e9) < 1e-12


def test_stream_limit_and_spill_monotone_under_compression(reduced_specs):
    """Property sweep (PR 8 satellite): as residency shrinks (pruning
    0 -> 100%, compressed on/off), ``stream_batch_limit`` is monotone
    non-decreasing, never below the uncompressed plan's, and spill
    decisions never move (outputs are pruning- and compression-blind)."""
    fracs = (0.0, 0.25, 0.5, 0.75, 1.0)
    for geom in (GEOM, GEOM_1SLICE):
        dense = sched.plan_network(reduced_specs, geom, batch=4)
        spills = [p.spill_to_dram for p in dense.layers]
        prev = {True: 0, False: 0}
        for frac in fracs:
            occ = sched.prune_occupancy(reduced_specs, frac)
            for compressed in (False, True):
                s = sched.plan_network(reduced_specs, geom, batch=4,
                                       occupancy=occ, compressed=compressed)
                assert [p.spill_to_dram for p in s.layers] == spills
                assert s.stream_batch_limit >= dense.stream_batch_limit
                assert s.stream_batch_limit >= prev[compressed], \
                    (geom.name, frac, compressed)
                prev[compressed] = s.stream_batch_limit
                if not compressed:  # uncompressed: pinned exactly
                    assert s.stream_batch_limit == dense.stream_batch_limit


def test_compressed_residency_ratio_at_half_pruning(reduced_specs):
    """50% filter pruning + compression keeps no more than 0.55x the dense
    filter bytes resident (the kernel_bench gate's modeled side)."""
    occ = sched.prune_occupancy(reduced_specs, 0.5)
    dense = sched.plan_network(reduced_specs, GEOM, batch=4)
    comp = sched.plan_network(reduced_specs, GEOM, batch=4, occupancy=occ,
                              compressed=True)
    assert comp.filter_bytes_loaded <= 0.55 * dense.filter_bytes_loaded


@pytest.mark.slow
def test_nc_serving_engine_compressed_ragged_bit_exact():
    """Compressed serving with a ragged tail (3 requests, max_batch=2):
    every request's logits byte-identical to the dense standalone
    forward, and the engine's schedules all carry the compressed flag."""
    from repro.launch.serve import NCRequest, NCServingEngine

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    eng = NCServingEngine(params, cfg, max_batch=2, compressed=True)
    assert eng.schedule.compressed
    rng = np.random.default_rng(0)
    imgs = rng.random((3, 47, 47, 3)).astype(np.float32)
    for r in range(3):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    done = eng.run()
    assert len(done) == 3 and not eng.failed
    assert sorted(eng._schedules) == [1, 2]  # ragged tail planned its own
    assert all(s.compressed for s in eng._schedules.values())
    for r in done:
        ref, _ = inception.nc_forward(params, imgs[r.rid], config=cfg)
        np.testing.assert_array_equal(r.logits, np.asarray(ref))
    assert eng.stats()["residency_credit_bytes"] == \
        eng.schedule.residency_credit_bytes


@pytest.mark.slow
def test_warmup_replan_shrinks_quant_passes_logits_unchanged():
    """Warmup re-planning on a synthetically sparse model (first conv
    biased so far negative its post-ReLU outputs are all zero): the
    re-planned quant passes drop below the estimate-planned count, logits
    stay byte-identical, and the calibration curve excludes exactly the
    warmup batch."""
    from repro.launch.serve import NCRequest, NCServingEngine

    cfg = inception.reduced_config(img=47, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    first = cfg.stem[0][0]
    params[first]["bias"] = jnp.full_like(params[first]["bias"], -100.0)

    eng = NCServingEngine(params, cfg, max_batch=2, compressed=True,
                          warmup_replan=True)
    est_quant = sum(p.quant_passes for p in eng.schedule.layers)
    rng = np.random.default_rng(3)
    imgs = rng.random((4, 47, 47, 3)).astype(np.float32)
    for r in range(4):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    done = eng.run()
    assert len(done) == 4 and not eng.failed
    s = eng.stats()
    assert s["warmup_replans"] == 1
    # measured: the dead conv's outputs requantize to the known zero
    # point, so its §IV-D passes vanish from the re-planned schedule
    obs_quant = sum(p.quant_passes for p in eng.schedule.layers)
    assert obs_quant < est_quant, (obs_quant, est_quant)
    assert eng.occupancy[first].live_outputs == 0
    # calibration honest across the re-plan: the warmup batch (executed
    # under the retired estimate plan) is excluded, the rest observed
    assert s["calibration_excluded"] == 1
    assert s["calibration_samples"] == eng.steps - 1
    # logits byte-identical to the estimate-planned standalone forward
    ld, _ = inception.nc_forward(params, imgs, config=cfg)
    got = np.stack([r.logits for r in sorted(done, key=lambda r: r.rid)])
    np.testing.assert_array_equal(got, np.asarray(ld))
