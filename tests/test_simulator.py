"""Simulator validation against every published Neural Cache number."""
import math

import pytest

from repro.core.cache_geometry import XEON_E5_35MB, XEON_45MB, XEON_60MB
from repro.core.mapper import LayerSpec, map_layer
from repro.core.simulator import PAPER, simulate_network, throughput
from repro.models.inception import inception_v3_specs


@pytest.fixture(scope="module")
def result():
    return simulate_network(inception_v3_specs())


# ---------------------------------------------------------------------------
# geometry (paper §II-C / §III-A)
# ---------------------------------------------------------------------------
def test_geometry_constants():
    g = XEON_E5_35MB
    assert g.total_arrays == 4480
    assert g.alu_slots == 1_146_880
    assert g.compute_arrays == 4032
    assert g.arrays_per_slice == 320
    assert g.capacity_bytes == 35 * (1 << 20)
    assert g.io_way_bytes == 14 * 128 * 1024


# ---------------------------------------------------------------------------
# mapping worked examples (§IV-B, §VI-A)
# ---------------------------------------------------------------------------
def test_mapping_conv2d_2b():
    spec = LayerSpec("2b", "conv", H=147, R=3, S=3, C=32, M=64, E=147)
    m = map_layer(spec)
    assert m.filters_per_array == 8
    assert m.parallel_convs == 32_256
    assert m.serial_passes == 43
    assert m.utilization > 0.99


def test_mapping_figure9_example():
    spec = LayerSpec("fig9", "conv", H=32, R=3, S=3, C=128, M=32, E=32)
    m = map_layer(spec)
    assert m.filters_per_array == 2  # two complete filters per array
    # 18x32 convs per slice, 32768/8064 = 4.06 -> paper prose says 'about 4';
    # the schedule needs the ceiling.
    assert spec.conv_count / m.parallel_convs == pytest.approx(4.06, abs=0.01)
    assert m.serial_passes == 5


def test_filter_splitting_5x5():
    spec = LayerSpec("5x5", "conv", H=35, R=5, S=5, C=48, M=64, E=35)
    m = map_layer(spec)
    assert m.split_factor == 3  # 25B > 9B
    assert m.eff_channels == 144
    assert m.channels_rounded == 256


def test_filter_packing_1x1():
    spec = LayerSpec("1x1", "conv", H=73, R=1, S=1, C=64, M=80, E=73)
    m = map_layer(spec)
    assert m.pack_factor == 16
    assert m.eff_channels == 4
    assert m.macs_per_line == 16


# ---------------------------------------------------------------------------
# layer-level compute anchor (§VI-A)
# ---------------------------------------------------------------------------
def test_conv2d_2b_cycles(result):
    l2b = next(l for l in result.layers if l.spec.name == "Conv2d_2b_3x3")
    assert l2b.compute_cycles_per_pass == PAPER["conv2d_2b_cycles_per_conv"]  # 2784
    assert l2b.mapped.serial_passes == PAPER["conv2d_2b_serial"]  # 43
    compute_ms = (l2b.mapped.serial_passes * l2b.compute_cycles_per_pass
                  / XEON_E5_35MB.compute_freq_hz * 1e3)
    assert compute_ms == pytest.approx(0.0479, rel=0.01)


# ---------------------------------------------------------------------------
# end-to-end latency + breakdown (Figures 14, 15)
# ---------------------------------------------------------------------------
def test_total_latency(result):
    assert result.latency_s * 1e3 == pytest.approx(PAPER["nc_latency_ms"], rel=0.03)


def test_speedups(result):
    ms = result.latency_s * 1e3
    assert PAPER["cpu_latency_ms"] / ms == pytest.approx(PAPER["latency_speedup_cpu"], rel=0.05)
    assert PAPER["gpu_latency_ms"] / ms == pytest.approx(PAPER["latency_speedup_gpu"], rel=0.05)


def test_breakdown(result):
    bd = result.breakdown()
    for key, want in PAPER["breakdown"].items():
        assert bd[key] == pytest.approx(want, abs=0.015), (key, bd[key], want)


# ---------------------------------------------------------------------------
# throughput vs batch (Figure 16)
# ---------------------------------------------------------------------------
def test_throughput_batching(result):
    tp1 = throughput(result, 1)
    tp64 = throughput(result, 64)
    tp256 = throughput(result, 256)
    assert tp64 == pytest.approx(PAPER["nc_throughput"], rel=0.05)
    assert tp256 - tp64 < 0.02 * tp64  # plateau
    assert tp1 > PAPER["gpu_throughput"]  # beats GPU even unbatched
    assert tp64 / PAPER["cpu_throughput"] == pytest.approx(12.4, rel=0.07)
    assert tp64 / PAPER["gpu_throughput"] == pytest.approx(2.2, rel=0.07)


def test_batching_monotone(result):
    tps = [throughput(result, b) for b in (1, 2, 4, 8, 16, 32, 64)]
    assert all(b >= a for a, b in zip(tps, tps[1:]))


# ---------------------------------------------------------------------------
# energy / power (Table III)
# ---------------------------------------------------------------------------
def test_energy_power(result):
    assert result.energy_j == pytest.approx(PAPER["nc_energy_j"], rel=0.10)
    assert result.power_w == pytest.approx(PAPER["nc_power_w"], rel=0.10)
    assert PAPER["cpu_energy_j"] / result.energy_j > 30  # ~37x efficiency
    assert PAPER["gpu_energy_j"] / result.energy_j > 14  # ~16.6x


# ---------------------------------------------------------------------------
# cache-capacity scaling (Table IV) — emerges mechanistically (serial-pass
# counts + slice-parallel bandwidth), nothing fitted to these points.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("geom,mb", [(XEON_E5_35MB, 35), (XEON_45MB, 45), (XEON_60MB, 60)])
def test_capacity_scaling(geom, mb):
    r = simulate_network(inception_v3_specs(), geom)
    assert r.latency_s * 1e3 == pytest.approx(PAPER["capacity_ms"][mb], rel=0.03)


def test_capacity_filter_time_constant():
    """§VI-D: filter loading does not speed up with more slices."""
    r35 = simulate_network(inception_v3_specs(), XEON_E5_35MB)
    r60 = simulate_network(inception_v3_specs(), XEON_60MB)
    assert r35.filter_s == pytest.approx(r60.filter_s, rel=1e-9)
    assert r60.input_s < r35.input_s
    assert r60.compute_s < r35.compute_s
