"""§IV-E double-buffered slice pipeline, locked down three ways.

* **Pricing property sweep** — for any geometry/batch, the overlapped
  schedule's ``batch_time_s`` equals the serial schedule's minus EXACTLY
  the hidden-load credit (``NetworkResult.hidden_s``), per-layer credits
  are bounded by both the hideable load and one image's MAC+reduce, and
  ``total_cycles`` never moves (overlap re-times copies, not compute).
* **Engine differential** — overlap-granted plans through ``nc_conv2d``
  and ``nc_forward`` (including ``stream_chunk`` cross-layer streaming
  and the sparse x overlap composition) return BYTE-IDENTICAL outputs to
  the serial plans on the same weights.
* **Legality + API guards** — single-pass layers, pools, and fully
  pruned layers are denied overlap; ``overlap=`` alongside an explicit
  plan/schedule raises (overlap is a plan decision, like sparsity).

The measured wall-time side (serial vs overlapped batch-4 pair) lives in
``benchmarks/kernel_bench.py`` + ``benchmarks/sched_breakdown.py``,
gated by ``benchmarks.common.overlap_wall_slack``.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nc_layers as nc
from repro.core import quantize as q
from repro.core import schedule as sched
from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.mapper import LayerSpec, pass_filter_bytes
from repro.core.simulator import (batch_time_s, modeled_layer_cycles,
                                  simulate_network)
from repro.models import inception

GEOM = XEON_E5_35MB
GEOM_1SLICE = XEON_E5_35MB.scaled(1)
GEOM_4SLICE = XEON_E5_35MB.scaled(4)


@pytest.fixture(scope="module")
def reduced_specs():
    return inception.inception_v3_specs(inception.reduced_config())


# ---------------------------------------------------------------------------
# Pricing: credit exactness, bounds, cycle invariance
# ---------------------------------------------------------------------------
def test_overlap_credit_exact_for_any_batch(reduced_specs):
    """Acceptance: serial minus overlapped == hidden credit, to float
    precision, for every batch size — the identity that lets the serving
    LatencyModel calibrate against overlapped plans with no changes."""
    for geom in (GEOM_1SLICE, GEOM_4SLICE):
        serial = sched.plan_network(reduced_specs, geom, batch=4)
        over = sched.plan_network(reduced_specs, geom, batch=4, overlap=True)
        assert over.overlapped_layers > 0
        rs, ro = simulate_network(serial), simulate_network(over)
        assert rs.hidden_s == 0.0
        assert ro.hidden_s > 0.0
        assert math.isclose(ro.hidden_s, sum(l.hidden_s for l in ro.layers),
                            rel_tol=1e-12)
        for n in (1, 2, 4, 16, 64):
            assert math.isclose(batch_time_s(rs, n) - batch_time_s(ro, n),
                                ro.hidden_s, rel_tol=1e-9)
        assert math.isclose(ro.overlapped_latency_s,
                            ro.latency_s - ro.hidden_s, rel_tol=1e-12)


def test_overlap_layer_credit_bounds(reduced_specs):
    """Per layer: the credit is exactly min(hideable load, one image's
    MAC+reduce), never negative, never more than the filter load — and
    the serial-priced totals (seconds AND cycles) don't move at all."""
    serial = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4)
    over = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                              overlap=True)
    rs, ro = simulate_network(serial), simulate_network(over)
    for ls, lo, ps, po in zip(rs.layers, ro.layers, serial.layers,
                              over.layers):
        assert lo.total_s == ls.total_s  # serial pricing untouched
        assert modeled_layer_cycles(po, GEOM_1SLICE)["total_cycles"] == \
            modeled_layer_cycles(ps, GEOM_1SLICE)["total_cycles"]
        if not lo.overlap:
            assert lo.hidden_s == 0.0 and lo.prologue_s == 0.0
            continue
        assert 0.0 < lo.prologue_s <= lo.filter_s
        assert math.isclose(
            lo.hidden_s,
            min(max(lo.filter_s - lo.prologue_s, 0.0),
                lo.mac_s + lo.reduce_s), rel_tol=1e-12)
        assert lo.hidden_s <= lo.filter_s


def test_overlap_off_bit_identical_to_serial(reduced_specs):
    """overlap=False is the PR 3/4 schedule, field for field, and the
    simulator's numbers don't move a bit."""
    for batch in (1, 4):
        dense = sched.plan_network(reduced_specs, GEOM, batch=batch)
        off = sched.plan_network(reduced_specs, GEOM, batch=batch,
                                 overlap=False)
        assert off == dense
    r = simulate_network(sched.plan_network(reduced_specs, GEOM))
    assert r.hidden_s == 0.0
    assert r.overlapped_latency_s == r.latency_s


def test_pruning_overlap_composition(reduced_specs):
    """Sparsity first, overlap second: the sparse+overlapped schedule
    keeps the sparse plan's skip credit bit-for-bit and its own hidden
    credit stays exact against the sparse-serial schedule — composition
    never over-credits."""
    occ = sched.prune_occupancy(reduced_specs, 0.5)
    ss = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                            occupancy=occ)
    so = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                            occupancy=occ, overlap=True)
    assert so.skipped_passes == ss.skipped_passes
    assert 0 < so.overlapped_layers
    rs, ro = simulate_network(ss), simulate_network(so)
    assert ro.hidden_s > 0.0
    for n in (1, 4, 64):
        assert math.isclose(batch_time_s(rs, n) - batch_time_s(ro, n),
                            ro.hidden_s, rel_tol=1e-9)
    # a layer pruned down to <=1 executed pass has nothing to buffer
    for p in so.layers:
        if p.is_compute and p.executed_passes <= 1:
            assert not p.overlap


# ---------------------------------------------------------------------------
# pass_stages: the explicit (load, compute) split
# ---------------------------------------------------------------------------
def test_pass_stages_invariants(reduced_specs):
    for overlap in (False, True):
        net = sched.plan_network(reduced_specs, GEOM_1SLICE, batch=4,
                                 overlap=overlap)
        for p in net.layers:
            stages = p.pass_stages()
            if not p.is_compute:
                assert stages == ()
                continue
            assert len(stages) == p.executed_passes
            assert sum(s.load_bytes for s in stages) == p.filter_bytes
            for k, s in enumerate(stages):
                assert s.index == k
                assert s.load_bytes <= p.filter_bytes_per_pass
                # stage 0 is the prologue: never overlapped
                assert s.overlapped == (p.overlap and k > 0)
            if p.overlap:
                assert p.filter_bytes_per_pass == pass_filter_bytes(
                    p.filter_bytes, p.executed_passes)


def test_overlap_legality_denials(reduced_specs):
    spec = LayerSpec(name="t", kind="conv", H=18, R=3, S=3, C=8, M=64, E=16)
    # multi-pass at 1 slice: granted
    assert sched.plan_layer(spec, GEOM_1SLICE, overlap=True).overlap
    # same layer single-pass at the paper geometry: denied (nothing to
    # prefetch under — every filter column is already streaming for pass 0)
    full = sched.plan_layer(spec, GEOM, overlap=True)
    assert full.executed_passes == 1 and not full.overlap
    # pools carry no filters: denied, no stages
    pool = next(s for s in reduced_specs if s.kind not in ("conv", "fc"))
    pp = sched.plan_layer(pool, GEOM_1SLICE, overlap=True)
    assert not pp.overlap and pp.pass_stages() == ()
    # fully pruned: zero executed passes, nothing to double-buffer
    dead = sched.plan_layer(spec, GEOM_1SLICE, overlap=True,
                            occupancy=sched.LayerOccupancy(
                                spec.M, tuple(range(spec.M))))
    assert dead.executed_passes == 0 and not dead.overlap


# ---------------------------------------------------------------------------
# Engine differential: byte identity + API guards
# ---------------------------------------------------------------------------
def test_nc_conv2d_overlap_byte_identical():
    """The prefetch + deferred-store execution path returns the same
    bytes as the serial path on a genuinely multi-pass plan."""
    rng = np.random.default_rng(11)
    spec = LayerSpec(name="t", kind="conv", H=18, R=3, S=3, C=8, M=64, E=16)
    wq = rng.integers(0, 256, size=(3, 3, 8, 64)).astype(np.uint8)
    w_qp = q.QuantParams(scale=np.float32(0.05), zero_point=7)
    x = rng.normal(size=(18, 18, 8)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    serial = sched.plan_layer(spec, GEOM_1SLICE)
    over = sched.plan_layer(spec, GEOM_1SLICE, overlap=True)
    assert over.overlap and over.executed_passes > 1
    ref, cyc_s = nc.nc_conv2d(x, wq, x_qp, w_qp, plan=serial)
    out, cyc_o, stats = nc.nc_conv2d(x, wq, x_qp, w_qp, plan=over,
                                     return_stats=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert cyc_o == cyc_s  # modeled cycles are overlap-invariant
    assert stats.overlap
    assert stats.filter_loads == 1


def test_overlap_with_explicit_plan_raises():
    rng = np.random.default_rng(3)
    wq = rng.integers(0, 256, size=(3, 3, 3, 8)).astype(np.uint8)
    w_qp = q.QuantParams(scale=np.float32(0.1), zero_point=0)
    x = rng.normal(size=(8, 8, 3)).astype(np.float32)
    x_qp = q.choose_qparams(jnp.float32(x.min()), jnp.float32(x.max()))
    spec = LayerSpec(name="t", kind="conv", H=8, R=3, S=3, C=3, M=8, E=6)
    plan = sched.plan_layer(spec, GEOM)
    with pytest.raises(ValueError, match="plan_layer"):
        nc.nc_conv2d(x, wq, x_qp, w_qp, plan=plan, overlap=True)


def test_nc_forward_overlap_schedule_guards():
    cfg = inception.reduced_config(img=39, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    x = np.zeros((39, 39, 3), np.float32)
    schedule = sched.plan_network(inception.inception_v3_specs(cfg), GEOM)
    with pytest.raises(ValueError, match="plan_network"):
        inception.nc_forward(params, x, config=cfg, schedule=schedule,
                             overlap=True)
    with pytest.raises(ValueError, match="stream_chunk"):
        inception.nc_forward(params, x, config=cfg, schedule=schedule,
                             stream_chunk=1)


def test_nc_forward_overlap_and_stream_chunk_byte_identical():
    """End to end on the miniature network at 1 slice (3 layers genuinely
    double-buffered): overlap and cross-layer streaming both return the
    serial logits byte for byte, and cycles don't move."""
    cfg = inception.reduced_config(img=39, width_div=8, classes=8,
                                   stages=("a",))
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    xb = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(1), (2, 39, 39, 3), jnp.float32))
    ref, rd = inception.nc_forward(params, xb, config=cfg, geom=GEOM_1SLICE)
    out, ro = inception.nc_forward(params, xb, config=cfg, geom=GEOM_1SLICE,
                                   overlap=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert sum(1 for l in ro.layers if l.overlap) > 0
    assert ro.total_emulated_cycles == rd.total_emulated_cycles
    chunked, _ = inception.nc_forward(params, xb, config=cfg,
                                      geom=GEOM_1SLICE, overlap=True,
                                      stream_chunk=1)
    np.testing.assert_array_equal(np.asarray(chunked), np.asarray(ref))
    # sparse x overlap composition: still byte-identical to sparse-serial
    wpack = inception.prune_wpack(
        inception.prepare_conv_weights(params, cfg), 0.5)
    sref, _ = inception.nc_forward(params, xb, config=cfg, geom=GEOM_1SLICE,
                                   wpack=wpack, sparse=True)
    sout, rso = inception.nc_forward(params, xb, config=cfg,
                                     geom=GEOM_1SLICE, wpack=wpack,
                                     sparse=True, overlap=True)
    np.testing.assert_array_equal(np.asarray(sout), np.asarray(sref))
    assert sum(l.zero_filters for l in rso.layers) > 0
