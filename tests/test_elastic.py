"""Elastic restart: a checkpoint saved on one device count restores onto a
different mesh (subprocess: the parent pytest locked jax to 1 device)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SAVE = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.checkpoint import save_checkpoint
    params = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
    save_checkpoint("%s", 7, {"params": params}, extras={"data": {"next_index": 3}})
    print("SAVED")
""")

_RESTORE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_checkpoint
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    like = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    sh = {"params": {"w": NamedSharding(mesh, P("data", "model")),
                     "b": NamedSharding(mesh, P("model"))}}
    step, out, extras = restore_checkpoint("%s", {"params": like},
                                           shardings=sh)
    assert step == 7 and extras["data"]["next_index"] == 3
    w = out["params"]["w"]
    assert len(w.sharding.device_set) == 4, w.sharding
    np.testing.assert_array_equal(np.asarray(w),
                                  np.arange(64.0).reshape(8, 8))
    print("RESTORED_ELASTIC")
""")


def test_checkpoint_restores_onto_larger_mesh(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(__file__))
    ck = str(tmp_path / "ck")
    r1 = subprocess.run([sys.executable, "-c", _SAVE % ck], env=env,
                        capture_output=True, text=True, timeout=300, cwd=cwd)
    assert "SAVED" in r1.stdout, r1.stdout + r1.stderr
    r2 = subprocess.run([sys.executable, "-c", _RESTORE % ck], env=env,
                        capture_output=True, text=True, timeout=300, cwd=cwd)
    assert "RESTORED_ELASTIC" in r2.stdout, r2.stdout + r2.stderr
