"""Inception v3: Table-I fidelity + runnable forward (float & quantized)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapper import map_network
from repro.models import inception


TABLE_I = {  # block -> (conv count, filter MiB)
    "Conv2d_1a_3x3": (710432, 0.001), "Conv2d_2a_3x3": (691488, 0.009),
    "Conv2d_2b_3x3": (1382976, 0.018), "MaxPool_3a_3x3": (0, 0.0),
    "Conv2d_3b_1x1": (426320, 0.005), "Conv2d_4a_3x3": (967872, 0.132),
    "MaxPool_5a_3x3": (0, 0.0), "Mixed_5b": (568400, 0.243),
    "Mixed_5c": (607600, 0.264), "Mixed_5d": (607600, 0.271),
    "Mixed_6a": (334720, 0.255), "Mixed_6b": (443904, 1.234),
    "Mixed_6c": (499392, 1.609), "Mixed_6d": (499392, 1.609),
    "Mixed_6e": (499392, 1.898), "Mixed_7a": (254720, 1.617),
    "Mixed_7b": (208896, 4.805), "Mixed_7c": (208896, 5.789),
    "AvgPool": (0, 0.0), "FullyConnected": (1001, 1.955),
}

# Table-I cells that are internally inconsistent in the paper itself
# (documented in EXPERIMENTS.md §Paper-repro):
#   * Mixed_6e conv count omits the pool-projection conv
#     (499392 = 554880 - 192*17^2) and its filter bytes,
#   * Mixed_6a filter size was computed with C=32 on the 3x3x384 branch.
PAPER_TABLE_QUIRKS = {"Mixed_6a", "Mixed_6e"}


@pytest.fixture(scope="module")
def specs():
    return inception.inception_v3_specs()


def _by_block(specs):
    blocks = {}
    for s in specs:
        c, f = blocks.get(s.block, (0, 0.0))
        blocks[s.block] = (c + s.conv_count, f + s.filter_bytes / (1 << 20))
    return blocks


def test_conv_counts_match_table_i(specs):
    blocks = _by_block(specs)
    for name, (convs, _) in TABLE_I.items():
        got = blocks[name][0]
        if name == "Mixed_6e":
            assert got == convs + 192 * 17 * 17  # paper omitted pool-proj
        else:
            assert got == convs, name


def test_filter_sizes_match_table_i(specs):
    blocks = _by_block(specs)
    for name, (_, mib) in TABLE_I.items():
        if name in PAPER_TABLE_QUIRKS or mib == 0:
            continue
        assert blocks[name][1] == pytest.approx(mib, abs=0.006), name


def test_total_convs_about_half_million_per_layer(specs):
    """§IV: 'Inception v3 has ~0.5 million convolutions in each layer on avg'."""
    blocks = _by_block(specs)
    convs = [c for c, _ in blocks.values() if c > 0]
    assert 0.3e6 < np.mean(convs) < 0.7e6


def test_output_shapes_chain(specs):
    """Every layer's input grid must match the previous output grid."""
    for s in specs:
        if s.kind in ("conv", "fc"):
            assert s.E >= 1 and s.C >= 1 and s.M >= 1


def test_network_maps_without_budget_violation(specs):
    mapped = map_network(specs)
    assert len(mapped) == len(specs)
    for m in mapped:
        assert m.channels_rounded <= 512


def test_forward_small():
    """Forward pass on a reduced image: shapes + finite outputs."""
    key = jax.random.PRNGKey(0)
    params = inception.init_params(key)
    x = jax.random.uniform(key, (1, 299, 299, 3), jnp.float32)
    logits = inception.apply(params, x)
    assert logits.shape == (1, 1001)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_quantized_close_to_float():
    """§IV-D: 8-bit quantized inference tracks the float model."""
    key = jax.random.PRNGKey(1)
    params = inception.init_params(key)
    x = jax.random.uniform(key, (1, 299, 299, 3), jnp.float32)
    f = inception.apply(params, x, quant=False)
    g = inception.apply(params, x, quant=True)
    f, g = np.asarray(f)[0], np.asarray(g)[0]
    # logits correlation is the quantization-quality metric
    corr = np.corrcoef(f, g)[0, 1]
    assert corr > 0.98, corr
    assert np.argmax(f) == np.argmax(g) or corr > 0.995
