"""SLO-aware serving admission (core/slo.py + launch/serve.py).

Covers the latency model (monotone predictions, wall-time calibration),
the admission policy (SLO-bounded batch pick, ragged-tail early admission,
the stream-batch-limit cap), and the engine integration: SLO hit/miss
accounting on an injectable clock, plan-cache reuse across admitted batch
sizes, and bit-identity of policy-batched results vs standalone
``nc_forward`` runs."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.cache_geometry import XEON_E5_35MB
from repro.core.schedule import plan_network
from repro.core.simulator import batch_time_s, simulate_network, throughput
from repro.core.slo import AdmissionDecision, AdmissionPolicy, LatencyModel
from repro.models import inception

GEOM = XEON_E5_35MB


@pytest.fixture(scope="module")
def paper_model():
    specs = inception.inception_v3_specs()
    return LatencyModel(lambda b: plan_network(specs, GEOM, batch=b))


# ---------------------------------------------------------------------------
# LatencyModel
# ---------------------------------------------------------------------------
def test_batch_time_matches_throughput(paper_model):
    """batch_time_s is the exact reciprocal view of throughput()."""
    res = paper_model.result_for(8)
    for b in (1, 2, 8, 64):
        assert throughput(res, b, sockets=2) == pytest.approx(
            2 * b / batch_time_s(res, b), rel=1e-12)
    # filter load amortizes, marginal + spill accrue per image
    assert batch_time_s(res, 1) == pytest.approx(
        res.filter_s + res.marginal_s, rel=1e-12)
    assert batch_time_s(res, 4) == pytest.approx(
        res.filter_s + 4 * (res.marginal_s + res.spill_s_per_image()),
        rel=1e-12)


def test_latency_model_strictly_monotone(paper_model):
    batches = (1, 2, 3, 4, 8, 16, 64, 256)
    pred = [paper_model.predict_s(b) for b in batches]
    p99 = [paper_model.predict_p99_s(b) for b in batches]
    assert all(b > a for a, b in zip(pred, pred[1:]))
    assert all(b > a for a, b in zip(p99, p99[1:]))
    # the tail prediction is never thinner than the mean prediction
    assert all(t >= m for m, t in zip(pred, p99))


def test_latency_model_calibration():
    specs = inception.inception_v3_specs(inception.reduced_config())
    m = LatencyModel(lambda b: plan_network(specs, GEOM, batch=b))
    assert not m.calibrated
    assert m.scale == 1.0
    base = m.modeled_batch_s(4)
    # uncalibrated: predictions are modeled time (x tail safety for p99)
    assert m.predict_s(4) == pytest.approx(base)
    assert m.predict_p99_s(4) == pytest.approx(base * m.tail_safety)
    # one observation pins the scale to the observed ratio
    r = m.observe(4, 10.0 * base)
    assert r == pytest.approx(10.0)
    assert m.calibrated and m.scale == pytest.approx(10.0)
    assert m.predict_s(2) == pytest.approx(10.0 * m.modeled_batch_s(2))
    # EWMA folds later evidence; the worst ratio drives the tail
    m.observe(2, 30.0 * m.modeled_batch_s(2))
    assert m.scale == pytest.approx(20.0)  # 0.5 * 30 + 0.5 * 10
    assert m.worst == pytest.approx(30.0)
    assert m.predict_p99_s(1) == pytest.approx(30.0 * m.modeled_batch_s(1))
    # predictions stay monotone through calibration
    vals = [m.predict_p99_s(b) for b in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(vals, vals[1:]))


def test_latency_model_tail_outlier_ages_out():
    """The p99 multiplier is a WINDOWED max: one cold-compile/CPU-steal
    spike must not cap admitted batch sizes for the engine's lifetime."""
    specs = inception.inception_v3_specs(inception.reduced_config())
    m = LatencyModel(lambda b: plan_network(specs, GEOM, batch=b), window=4)
    base = m.modeled_batch_s(1)
    m.observe(1, 100.0 * base)  # outlier (e.g. first-batch compile)
    assert m.worst == pytest.approx(100.0)
    for _ in range(4):  # steady state fills the window
        m.observe(1, 10.0 * base)
    assert m.worst == pytest.approx(10.0)  # the spike aged out
    assert m.predict_p99_s(1) < 100.0 * base


def test_latency_model_shares_plan_cache():
    """The model prices the very schedule objects its planner returns."""
    specs = inception.inception_v3_specs(inception.reduced_config())
    cache = {}

    def schedule_for(b):
        if b not in cache:
            cache[b] = plan_network(specs, GEOM, batch=b)
        return cache[b]

    m = LatencyModel(schedule_for)
    assert m.result_for(3).schedule is cache[3]
    assert m.result_for(3) is m.result_for(3)  # memoized, priced once
    assert m.stream_batch_limit == cache[1].stream_batch_limit


# ---------------------------------------------------------------------------
# AdmissionPolicy (over a deterministic fake model)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FakeLatency:
    """predict_p99_s(n) = per_batch_s * n; enough surface for the policy."""

    per_batch_s: float = 1.0
    stream_batch_limit: int = 100

    def predict_p99_s(self, n):
        return self.per_batch_s * n


def test_target_batch_is_largest_under_budget():
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=64)
    assert pol.target_batch(7.5) == 7
    assert pol.target_batch(7.0) == 7  # boundary: p99(7) == budget
    assert pol.target_batch(100.0) == 64  # max_batch caps
    assert pol.target_batch(0.5) == 1  # below even batch 1: floor, not 0
    assert pol.target_batch(-1.0) == 1
    # monotone in budget
    targets = [pol.target_batch(b) for b in (0.5, 2.0, 5.0, 9.0, 50.0)]
    assert targets == sorted(targets)


def test_target_batch_capped_by_stream_limit():
    pol = AdmissionPolicy(FakeLatency(0.001, stream_batch_limit=5),
                          slo_s=10.0, max_batch=64)
    assert pol.batch_cap == 5
    assert pol.target_batch(10.0) == 5  # budget fits 10000, limit wins


def test_admission_full_queue_admits_target():
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=8)
    d = pol.admit(queued=20, oldest_wait_s=0.0)
    assert d == AdmissionDecision(8, 8, 10.0, "full")
    # queue wait shrinks the budget, and with it the admitted batch
    d = pol.admit(queued=20, oldest_wait_s=7.0)
    assert d.admit == d.target == 3 and d.reason == "full"


def test_admission_ragged_tail_held_then_flushed_early():
    # hold_slack_s=2: hold while the shallow batch retains >2s slack
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=8,
                          hold_slack_s=2.0)
    # fresh shallow queue: budget 10, p99(2)=2, slack 8 > 2 -> hold
    d = pol.admit(queued=2, oldest_wait_s=0.0)
    assert d.admit == 0 and d.reason == "hold" and d.target == 8
    # waited 6s: budget 4, slack 4 - 2 = 2 <= 2 -> admit the ragged tail
    d = pol.admit(queued=2, oldest_wait_s=6.0)
    assert d.admit == 2 and d.reason == "ragged-early"
    # flush overrides the hold but keeps the SLO sizing
    d = pol.admit(queued=2, oldest_wait_s=0.0, flush=True)
    assert d.admit == 2 and d.reason == "flush"
    # deadline already blown: the floor batch drains the queue anyway
    d = pol.admit(queued=2, oldest_wait_s=11.0)
    assert d.admit == 1 and d.reason == "full" and d.budget_s < 0


def test_default_hold_slack_is_quarter_slo():
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=8.0, max_batch=4)
    assert pol.hold_slack == pytest.approx(2.0)
    pol2 = AdmissionPolicy(FakeLatency(1.0), slo_s=8.0, max_batch=4,
                           hold_slack_s=0.5)
    assert pol2.hold_slack == 0.5


# ---------------------------------------------------------------------------
# NCServingEngine integration (tiny stem-only config, injectable clock)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = inception.reduced_config(img=47, width_div=8, classes=8, stages=())
    params = inception.init_params(jax.random.PRNGKey(0), config=cfg)
    return cfg, params


def _engine(cfg, params, clock, **kw):
    from repro.launch.serve import NCServingEngine
    return NCServingEngine(params, cfg, now_fn=lambda: clock["t"], **kw)


def test_engine_slo_hit_and_miss_accounting(tiny):
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    # generous SLO (1e4 s): emulation wall time can never miss it
    eng = _engine(cfg, params, clock, max_batch=2, slo_ms=1e7)
    rng = np.random.default_rng(0)
    imgs = rng.random((3, cfg.img, cfg.img, 3)).astype(np.float32)
    eng.submit(NCRequest(rid=0, image=imgs[0]))
    eng.submit(NCRequest(rid=1, image=imgs[1]))
    assert eng.step(flush=True)
    assert eng.slo_hits == 2 and eng.slo_misses == 0
    assert all(r.slo_ok and r.latency_s is not None for r in eng.completed)
    # a request whose queue wait alone blows the deadline is a miss
    eng.submit(NCRequest(rid=2, image=imgs[2]))
    clock["t"] += 2e4  # 2e4 s >> 1e4 s SLO
    assert eng.step(flush=True)
    late = next(r for r in eng.completed if r.rid == 2)
    assert late.slo_ok is False and late.latency_s >= 2e4
    assert eng.slo_misses == 1
    assert eng.slo_hit_rate == pytest.approx(2 / 3)
    s = eng.stats()
    assert s["slo_hits"] == 2 and s["slo_misses"] == 1
    assert s["batch_histogram"] == {1: 1, 2: 1}


def test_engine_holds_shallow_queue_then_admits_on_deadline(tiny):
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    eng = _engine(cfg, params, clock, max_batch=4, slo_ms=60_000.0)
    rng = np.random.default_rng(1)
    eng.submit(NCRequest(rid=0, image=rng.random(
        (cfg.img, cfg.img, 3)).astype(np.float32)))
    # uncalibrated model: target is the full batch of 4, queue holds 1 with
    # ~60s of slack -> the policy holds for more arrivals
    assert eng.step() is False
    assert eng.decisions[-1].reason == "hold" and eng.steps == 0
    # the deadline approaches: slack below hold_slack flushes the tail
    clock["t"] = 50.0
    assert eng.step() is True
    assert eng.decisions[-1].reason == "ragged-early"
    assert eng.decisions[-1].admit == 1 and eng.steps == 1
    assert eng.completed[0].slo_ok  # wait 50s + wall < 60s SLO


def test_engine_slo_batches_bit_identical_and_plan_cache_reuse(tiny):
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    eng = _engine(cfg, params, clock, max_batch=4, slo_ms=1e7)
    rng = np.random.default_rng(2)
    imgs = rng.random((5, cfg.img, cfg.img, 3)).astype(np.float32)
    for r in range(5):
        eng.submit(NCRequest(rid=r, image=imgs[r]))
    done = eng.run()
    assert len(done) == 5 and all(r.done for r in done)
    cap = min(eng.max_batch, eng.schedule.stream_batch_limit)
    admitted = sorted(eng.batch_histogram)
    assert all(1 <= n <= cap for n in admitted)
    # plan-cache reuse: one schedule per admitted batch size, and the
    # latency model priced those SAME objects (shared _schedule_for cache)
    for n in admitted:
        assert n in eng._schedules
        assert eng.latency_model.result_for(n).schedule is eng._schedules[n]
    # calibration saw every admitted batch
    assert eng.latency_model.samples == eng.steps
    # results are bit-identical to standalone single-image runs whatever
    # batch sizes the policy picked
    for r in done:
        ref, _ = inception.nc_forward(params, imgs[r.rid], config=cfg)
        np.testing.assert_array_equal(r.logits, np.asarray(ref))


def test_engine_without_slo_unchanged(tiny):
    """No slo_ms: greedy FIFO admission, no hit/miss accounting, stats
    still report the batch histogram."""
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    eng = _engine(cfg, params, clock, max_batch=2)
    assert eng.policy is None and eng.slo_s is None
    rng = np.random.default_rng(3)
    for r in range(3):
        eng.submit(NCRequest(rid=r, image=rng.random(
            (cfg.img, cfg.img, 3)).astype(np.float32)))
    done = eng.run()
    assert len(done) == 3 and eng.steps == 2  # 2 + ragged 1
    assert all(r.slo_ok is None for r in done)
    assert eng.slo_hit_rate is None
    assert eng.stats()["batch_histogram"] == {1: 1, 2: 1}
    # per-request latency is tracked even without an SLO
    assert all(r.latency_s is not None for r in done)


def test_simulate_network_const_keyword():
    """simulate_network(schedule, const=...) — the LatencyModel call
    pattern — prices with the supplied constants."""
    from repro.core.simulator import SimConstants
    specs = inception.inception_v3_specs(inception.reduced_config())
    sched = plan_network(specs, GEOM, batch=2)
    a = simulate_network(sched)
    b = simulate_network(sched, const=SimConstants(mac8_cycles=300))
    assert b.latency_s > a.latency_s


# ---------------------------------------------------------------------------
# ArrivalRateEstimator + the fill-time-bounded hold (PR 9)
# ---------------------------------------------------------------------------
def test_arrival_estimator_unknown_until_two_arrivals():
    import math
    from repro.core.slo import ArrivalRateEstimator
    est = ArrivalRateEstimator()
    assert est.rate_hz is None and est.expected_fill_time_s(3) is None
    est.observe(10.0)
    # one arrival: still no interval, callers fall back to slack-only hold
    assert est.rate_hz is None and est.expected_fill_time_s(3) is None
    est.observe(12.0)
    assert est.mean_interval_s == pytest.approx(2.0)
    assert est.rate_hz == pytest.approx(0.5)
    assert est.expected_fill_time_s(3) == pytest.approx(6.0)
    assert est.expected_fill_time_s(0) == 0.0
    assert not math.isnan(est.expected_fill_time_s(1))


def test_arrival_estimator_ewma_tracks_rate_changes():
    from repro.core.slo import ArrivalRateEstimator
    est = ArrivalRateEstimator(ewma=0.5)
    est.observe(0.0)
    est.observe(4.0)   # interval 4
    est.observe(6.0)   # interval 2: 0.5*2 + 0.5*4 = 3
    assert est.mean_interval_s == pytest.approx(3.0)
    # simultaneous arrivals drive the estimate toward zero, never negative
    est.observe(6.0)
    assert est.mean_interval_s == pytest.approx(1.5)
    assert est.mean_interval_s >= 0.0


def test_admission_empty_queue_budget_is_nan():
    """An empty queue has no oldest request and therefore NO deadline
    budget: admit() reports NaN, not a number pretending to be one."""
    import math
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=8)
    d = pol.admit(queued=0, oldest_wait_s=0.0)
    assert d.admit == 0 and d.target == 0 and d.reason == "hold"
    assert math.isnan(d.budget_s)


def test_admission_hold_bounded_by_expected_fill_time():
    """With an arrivals estimator, a shallow queue is held ONLY while the
    target batch is expected to fill inside the remaining slack — sparse
    traffic flushes ragged batches immediately (PR 5's open thread)."""
    from repro.core.slo import ArrivalRateEstimator
    est = ArrivalRateEstimator()
    pol = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=8,
                          hold_slack_s=2.0, arrivals=est)
    # unknown rate (one arrival): the slack-only rule holds as before
    est.observe(0.0)
    d = pol.admit(queued=2, oldest_wait_s=0.0)
    assert d.reason == "hold"
    # dense traffic (interval 0.1 s): filling 6 more takes ~0.6 s, well
    # inside the 6 s slack -> keep holding
    est.observe(0.1)
    d = pol.admit(queued=2, oldest_wait_s=0.0)
    assert d.reason == "hold"
    # sparse traffic (interval ~100 s): the batch will never fill in
    # time -> admit the ragged tail NOW while the deadline survives
    sparse = ArrivalRateEstimator()
    sparse.observe(0.0)
    sparse.observe(100.0)
    pol_sparse = AdmissionPolicy(FakeLatency(1.0), slo_s=10.0, max_batch=8,
                                 hold_slack_s=2.0, arrivals=sparse)
    d = pol_sparse.admit(queued=2, oldest_wait_s=0.0)
    assert d.admit == 2 and d.reason == "ragged-early"


def test_engine_submit_feeds_arrival_estimator(tiny):
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    eng = _engine(cfg, params, clock, max_batch=4, slo_ms=1e7)
    rng = np.random.default_rng(4)
    img = rng.random((cfg.img, cfg.img, 3)).astype(np.float32)
    eng.submit(NCRequest(rid=0, image=img))
    clock["t"] = 2.0
    eng.submit(NCRequest(rid=1, image=img))
    assert eng.arrivals.samples == 2
    assert eng.arrivals.mean_interval_s == pytest.approx(2.0)
    assert eng.policy.arrivals is eng.arrivals


def test_fail_requests_message_precedence():
    """An exception with an empty str() falls back to the TYPE name —
    (str(err) or type name), not str(err or type name)."""
    from repro.launch.serve import BatchQueueEngine, NCRequest
    eng = BatchQueueEngine()
    reqs = [NCRequest(rid=0, image=np.zeros((1, 1, 3), np.float32))]
    eng._fail_requests(reqs, ValueError())
    assert eng.errors[-1] == "ValueError"
    eng._fail_requests(reqs, ValueError("boom"))
    assert eng.errors[-1] == "boom"
    eng._fail_requests(reqs, "plain string")
    assert eng.errors[-1] == "plain string"


# ---------------------------------------------------------------------------
# Rung-4 SLO accounting (PR 9 bugfix): a failed batch HAPPENED
# ---------------------------------------------------------------------------
def test_rung4_failed_batch_slo_accounting(tiny):
    """A batch that exhausts the whole recovery ladder still waited and
    still burned wall time: its requests are stamped SLO misses with a
    latency, the batch lands in the histogram, and the wall is routed
    through LatencyModel.exclude.  Identities:
    slo_hits + slo_misses == completed + failed, and the histogram
    admit-sum covers every finished request."""
    import types
    from repro.launch.serve import NCRequest
    cfg, params = tiny
    clock = {"t": 0.0}
    eng = _engine(cfg, params, clock, max_batch=2, slo_ms=50.0)
    rng = np.random.default_rng(5)
    imgs = rng.random((3, cfg.img, cfg.img, 3)).astype(np.float32)
    for r in range(3):
        eng.submit(NCRequest(rid=r, image=imgs[r]))

    def broken(x, schedule):
        raise RuntimeError("emulation down")

    eng._forward = broken
    eng._inception = types.SimpleNamespace(
        apply=lambda *a, **kw: (_ for _ in ()).throw(
            RuntimeError("float down")))
    done = eng.run()
    assert done == [] and len(eng.failed) == 3 and not eng.queue
    s = eng.stats()
    # the fixed identity: every finished request is accounted exactly once
    assert s["slo_hits"] + s["slo_misses"] == s["completed"] + s["failed"] == 3
    assert s["slo_hits"] == 0 and s["slo_misses"] == 3
    # failed batches happened: histogram covers them, totals match steps
    assert sum(s["batch_histogram"].values()) == s["steps"] == eng.steps
    assert sum(n * c for n, c in s["batch_histogram"].items()) == 3
    # their walls never calibrate the model -- excluded, not observed
    assert eng.latency_model.samples == 0
    assert s["calibration_excluded"] == len(s["batch_histogram"]) == 2
    for r in eng.failed:
        assert r.slo_ok is False and r.latency_s is not None
