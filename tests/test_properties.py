"""Hypothesis property tests on system invariants (quantization, data,
loop-aware analysis, cycle formulas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quantize import (choose_qparams, dequantize, quantize,
                                 quantize_per_channel)
from repro.data import SyntheticLMDataset

jax.config.update("jax_enable_x64", True)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=2,
                max_size=64),
       st.booleans())
def test_quantize_roundtrip_error_bounded(vals, signed):
    """|dequant(quant(x)) - x| <= scale elementwise (affine, 8-bit).

    The bound is `scale`, not `scale/2`: zero-point rounding can shift the
    whole grid by up to half a step on top of value rounding.
    """
    x = jnp.asarray(vals, jnp.float32)
    qp = choose_qparams(jnp.min(x), jnp.max(x), bits=8, signed=signed)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    assert float(jnp.max(err)) <= float(qp.scale) * (1 + 1e-5) + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(2, 8))
def test_per_channel_quant_scales_per_column(bits, k, n):
    w = jnp.asarray(np.random.default_rng(0).normal(size=(k, n)), jnp.float32)
    q, scale = quantize_per_channel(w, bits=bits)
    qmax = (1 << (bits - 1)) - 1
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= qmax + 1
    recon = q.astype(jnp.float32) * scale
    assert float(jnp.max(jnp.abs(recon - w))) <= float(jnp.max(scale)) * 0.51


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]), st.integers(0, 3))
def test_data_same_index_same_batch_any_host_split(idx, num_hosts, host_sel):
    """Global batch content is independent of the host partitioning."""
    ds = SyntheticLMDataset(vocab_size=64, seq_len=16, global_batch=4,
                            seed=9)
    host = min(host_sel, num_hosts - 1)
    b = ds.host_batch(idx, host, num_hosts)
    assert b["tokens"].shape == (4 // num_hosts, 16)
    # deterministic per (index, host)
    b2 = ds.host_batch(idx, host, num_hosts)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    # tokens stay inside the vocab
    assert b["tokens"].min() >= 1 and b["tokens"].max() < 64


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 24))
def test_loop_analyzer_linear_in_trip_count(L):
    from repro.distributed.hlo_loop_analysis import analyze_hlo
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        y, _ = jax.lax.scan(body, a, None, length=L)
        return y

    c = jax.jit(f).lower(x, x).compile()
    got = analyze_hlo(c.as_text()).flops
    want = L * (2 * 64 ** 3 + 64 * 64)  # dot + tanh per step
    # the loop-counter increment adds O(1) flops per iteration
    assert abs(got - want) <= 4 * L, (got, want)
