"""Sharding rules, roofline math, checkpointing, data pipeline."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.configs.base import SHAPES, ShapeSpec
from repro.distributed.hlo_analysis import collective_bytes
from repro.distributed.roofline import TPU_V5E, model_flops, roofline
from repro.distributed.sharding import (
    ShardingReport, _batch_spec, plan_parallelism, spec_for_param)


class FakeMesh:
    """Mesh stand-in: axis names + shape only (rules never touch devices)."""

    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def test_attention_weights_tp_sharded():
    cfg = get_config("qwen1.5-110b")
    spec = spec_for_param("stages/0/attn/wq", (80, 8192, 8192), cfg, MESH)
    assert tuple(spec) == (None, "data", "model")
    spec = spec_for_param("stages/0/attn/wo", (80, 8192, 8192), cfg, MESH)
    assert tuple(spec) == (None, "model", "data")


def test_indivisible_head_dim_falls_back():
    cfg = get_config("qwen2-7b")  # 28 heads * 128 = 3584 % 16 = 0 -> ok
    r = ShardingReport()
    spec = spec_for_param("stages/0/attn/wq", (28, 3584, 3585), cfg, MESH, r)
    assert tuple(spec)[-1] is None  # 3585 % 16 != 0 -> replicated + logged
    assert r.fallbacks


def test_moe_expert_sharding():
    cfg = get_config("arctic-480b")
    spec = spec_for_param("stages/0/moe/wi", (35, 128, 7168, 4864), cfg, MESH)
    assert tuple(spec) == (None, "model", "data", None)


def test_embed_head_vocab_sharding():
    cfg = get_config("olmo-1b")
    assert tuple(spec_for_param("embed", (50304, 2048), cfg, MESH)) == \
        ("model", "data")
    assert tuple(spec_for_param("head", (2048, 50304), cfg, MESH)) == \
        ("data", "model")


def test_norms_replicated():
    cfg = get_config("olmo-1b")
    assert tuple(spec_for_param("stages/0/norm1/w", (16, 2048), cfg, MESH)) \
        == (None, None)


# ---------------------------------------------------------------------------
# parallelism plan / batch specs
# ---------------------------------------------------------------------------
def test_plan_modes():
    assert plan_parallelism(get_config("olmo-1b")) == "fsdp"
    assert plan_parallelism(get_config("qwen1.5-110b")) == "tp"
    assert plan_parallelism(get_config("arctic-480b")) == "ep"
    assert plan_parallelism(get_config("mamba2-2.7b")) == "fsdp"


def test_batch_spec_preference_order():
    # fsdp: batch 256 on 16x16 -> both axes
    assert _batch_spec(256, MESH, None, "t", "fsdp") == ("data", "model")
    # tp: never puts batch on model (single axis returned bare)
    assert _batch_spec(256, MESH, None, "t", "tp") == "data"
    # indivisible by full product -> next candidate
    assert _batch_spec(128, MESH, None, "t", "fsdp") == "data"
    # multi-pod fsdp
    assert _batch_spec(512, MESH3, None, "t", "fsdp") == \
        ("pod", "data", "model")
    assert _batch_spec(256, MESH3, None, "t", "fsdp") == ("pod", "data")


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------
def test_model_flops_rules():
    cfg = get_config("olmo-1b")
    tr = SHAPES["train_4k"]
    assert model_flops(cfg, tr) == pytest.approx(
        6 * cfg.param_count() * tr.global_batch * tr.seq_len)
    de = SHAPES["decode_32k"]
    assert model_flops(cfg, de) == pytest.approx(
        2 * cfg.param_count() * de.global_batch)
    moe = get_config("arctic-480b")
    assert moe.active_param_count() < 0.2 * moe.param_count()


def test_roofline_terms_and_dominance():
    cfg = get_config("olmo-1b")
    spec = SHAPES["train_4k"]
    from repro.distributed.hlo_analysis import CollectiveStats
    coll = CollectiveStats(wire_bytes={"all-reduce": 819e9})  # 1s of HBM bw
    rep = roofline("olmo-1b", "train_4k", "m", 256,
                   {"flops": 197e12, "bytes accessed": 819e9 / 2},
                   coll, cfg, spec)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(0.5)
    assert rep.t_collective == pytest.approx(819e9 / 50e9)
    assert rep.dominant == "collective"
    assert 0 < rep.roofline_fraction < 1


# ---------------------------------------------------------------------------
# collective text parser
# ---------------------------------------------------------------------------
def test_collective_parser_counts_and_wire_factors():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
  %ar.start = f32[4,4]{1,0} all-reduce-start(%y), replica_groups={{0,1}}
  %ar.done = f32[4,4]{1,0} all-reduce-done(%ar.start)
  %cp = u8[100]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    st = collective_bytes(hlo)
    assert st.ops == {"all-gather": 1, "all-reduce": 1,
                      "collective-permute": 1}
    assert st.operand_bytes["all-gather"] == 16 * 1024 * 2
    # ring wire: AG moves (n-1)/n of output, AR 2(n-1)/n, permute 1x
    assert st.wire_bytes["all-gather"] == pytest.approx(
        16 * 1024 * 2 * 15 / 16)
    assert st.wire_bytes["all-reduce"] == pytest.approx(64 * 2 * 1 / 2)
    assert st.wire_bytes["collective-permute"] == 100


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_checkpointable():
    from repro.data import DataIterator, SyntheticLMDataset
    ds = SyntheticLMDataset(vocab_size=256, seq_len=64, global_batch=8,
                            seed=3)
    a = ds.host_batch(5)
    b = ds.host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])

    it = DataIterator(ds)
    first = next(it)
    state = it.state_dict()
    second = next(it)
    it2 = DataIterator(ds)
    it2.load_state_dict(state)
    resumed = next(it2)
    np.testing.assert_array_equal(np.asarray(second["tokens"]),
                                  np.asarray(resumed["tokens"]))


def test_data_host_sharding_partitions_batch():
    from repro.data import SyntheticLMDataset
    ds = SyntheticLMDataset(vocab_size=128, seq_len=32, global_batch=8)
    full_rows = [ds.host_batch(0, h, 4)["tokens"] for h in range(4)]
    assert all(r.shape == (2, 32) for r in full_rows)
    # different hosts draw different rows
    assert not np.array_equal(full_rows[0], full_rows[1])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_latest(tmp_path):
    from repro.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(tmp_path, 10, {"params": tree}, extras={"k": 1})
    save_checkpoint(tmp_path, 20, {"params": tree})
    assert latest_step(tmp_path) == 20
    step, out, extras = restore_checkpoint(tmp_path, {"params": tree},
                                           step=10)
    assert step == 10 and extras == {"k": 1}
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_async_and_gc(tmp_path):
    from repro.checkpoint import AsyncCheckpointer, latest_step
    ck = AsyncCheckpointer(tmp_path, keep=2)
    tree = {"w": jnp.zeros((8,))}
    for s in (1, 2, 3, 4):
        ck.save(s, {"params": tree})
    ck.wait()
    assert latest_step(tmp_path) == 4
    import pathlib
    steps = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert "step_1" not in steps and "step_2" not in steps


def test_checkpoint_structure_mismatch_raises(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    save_checkpoint(tmp_path, 1, {"params": {"w": jnp.zeros((2,))}})
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(
            tmp_path, {"params": {"w": jnp.zeros((2,)),
                                  "extra": jnp.zeros((1,))}})
