"""Flash attention (pure-JAX tiled) vs naive oracle: causal/window/GQA sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models.layers import decode_attention, flash_attention


def _qkv(rng, B, H, Hkv, T, D):
    q = jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, T, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, T, D)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize(
    "B,H,Hkv,T,D,qc,kc",
    [
        (1, 4, 4, 64, 16, 16, 16),
        (2, 8, 2, 128, 32, 32, 64),
        (1, 4, 1, 96, 16, 32, 32),  # GQA 4:1
        (2, 4, 4, 100, 16, 32, 16),  # T not divisible by chunks
        (1, 2, 2, 16, 8, 64, 64),  # chunk > T
    ],
)
def test_causal_matches_oracle(B, H, Hkv, T, D, qc, kc):
    rng = np.random.default_rng(B * H + T)
    q, k, v = _qkv(rng, B, H, Hkv, T, D)
    got = flash_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,qc,kc", [(16, 16, 16), (24, 32, 16), (8, 16, 32)])
def test_banded_window_matches_oracle(window, qc, kc):
    rng = np.random.default_rng(window)
    q, k, v = _qkv(rng, 2, 4, 2, 128, 16)
    got = flash_attention(q, k, v, causal=True, window=window, q_chunk=qc, kv_chunk=kc)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_oracle():
    rng = np.random.default_rng(9)
    B, H, Hkv, S, D = 2, 8, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, H, 1, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    pos = 40
    got = decode_attention(q, k, v, pos)
    want = flash_attention_ref(q, k[:, :, : pos + 1], v[:, :, : pos + 1],
                               causal=True, q_offset=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_banded_flops_scale_with_window_not_context():
    """The banded path's HLO FLOPs must not grow quadratically with context."""

    def flops(T):
        rng = np.random.default_rng(0)
        q, k, v = _qkv(rng, 1, 2, 2, T, 16)
        f = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=True, window=64, q_chunk=64, kv_chunk=64))
        from repro.distributed.hlo_analysis import xla_cost_analysis
        c = xla_cost_analysis(f.lower(q, k, v).compile())
        return c["flops"]

    f1, f2 = flops(512), flops(1024)
    # 2x tokens -> ~2x flops (linear), NOT 4x (quadratic)
    assert f2 / f1 < 2.6, (f1, f2)


def test_kv_valid_masks_padding():
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 1, 2, 2, 64, 16)
    got = flash_attention(q[:, :, :32], k, v, causal=False, kv_valid=32,
                          q_chunk=32, kv_chunk=32)
    want = flash_attention_ref(q[:, :, :32], k[:, :, :32], v[:, :, :32], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
