"""End-to-end train loop (checkpoint/resume/preemption) and serving engine."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeSpec

SHAPE = ShapeSpec("tiny", seq_len=32, global_batch=4, kind="train")


def _train(steps, ckpt_dir=None, **kw):
    from repro.launch.train import train
    cfg = reduced_config(get_config("olmo-1b"), n_layers=2, d_model=32,
                         d_ff=64, vocab_size=64, head_dim=8)
    return train(cfg, SHAPE, steps=steps, ckpt_dir=ckpt_dir,
                 ckpt_every=2, log_every=100, **kw)


def test_train_loss_decreases():
    # Long enough that the warmup-ramped LR accumulates real progress;
    # batch-mean losses are compared (single-batch loss noise is ~+-0.02,
    # the same order as a dozen steps' worth of learning).
    _, _, hist = _train(120)
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.01


def test_train_checkpoint_resume_is_deterministic(tmp_path):
    # run 8 steps straight through
    _, _, full = _train(8, ckpt_dir=str(tmp_path / "a"))
    # run 4, then resume to 8
    _, _, first = _train(4, ckpt_dir=str(tmp_path / "b"))
    _, _, second = _train(8, ckpt_dir=str(tmp_path / "b"))
    assert [h["step"] for h in second] == [4, 5, 6, 7]
    np.testing.assert_allclose(full[-1]["loss"], second[-1]["loss"],
                               rtol=1e-4)


def test_watchdog_flags_stragglers():
    from repro.launch.train import Watchdog
    wd = Watchdog(factor=3.0)
    assert not wd.observe(0, 1.0)
    assert not wd.observe(1, 1.1)
    assert wd.observe(2, 10.0)
    assert wd.flagged == [2]


@pytest.mark.slow
def test_serving_engine_completes_requests():
    from repro.launch.serve import Request, ServingEngine
    from repro.models import transformer as T
    import jax
    cfg = reduced_config(get_config("olmo-1b"), n_layers=2, d_model=32,
                         d_ff=64, vocab_size=64, head_dim=8)
    params = T.init_lm(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for r in range(5):
        eng.submit(Request(rid=r,
                           prompt=rng.integers(2, 64, 8).astype(np.int32),
                           max_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) >= 4 for r in done)
    # continuous batching actually batched: fewer engine steps than serial
    assert eng.steps < 5 * 4


@pytest.mark.slow
def test_serving_matches_unbatched_decode():
    """Engine output for one request == plain prefill+decode loop."""
    from repro.launch.serve import Request, ServingEngine
    from repro.models import transformer as T
    import jax
    import jax.numpy as jnp
    cfg = reduced_config(get_config("olmo-1b"), n_layers=2, d_model=32,
                         d_ff=64, vocab_size=64, head_dim=8)
    params = T.init_lm(cfg, jax.random.key(0))
    prompt = np.arange(2, 10).astype(np.int32)

    eng = ServingEngine(cfg, params, max_batch=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_tokens=5))
    out = eng.run()[0].out

    logits, caches = T.prefill(cfg, params, jnp.asarray(prompt)[None],
                               max_len=32)
    ref = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(4):
        logits, caches = T.decode_step(
            cfg, params, jnp.asarray([[ref[-1]]], jnp.int32), caches, pos)
        ref.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert out == ref


def test_serving_mixed_prompt_lengths_match_sequential():
    """PR 9 bugfix: slots admitted with DIFFERENT prompt lengths decode —
    and write KV — each at its own position.  The old engine decoded
    every slot at max(pos), so a short prompt batched next to a longer
    one produced different (corrupted) tokens than it did served alone.
    This test runs the same two prompts batched and sequentially and
    demands identical outputs; it fails on the max(pos) code."""
    from repro.launch.serve import Request, ServingEngine
    from repro.models import transformer as T
    import jax
    cfg = reduced_config(get_config("olmo-1b"), n_layers=2, d_model=32,
                         d_ff=64, vocab_size=64, head_dim=8)
    params = T.init_lm(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 11)]  # mixed lengths share one decode batch

    eng = ServingEngine(cfg, params, max_batch=2, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_tokens=5))
    done = eng.run()
    batched = {r.rid: r.out for r in done}
    assert len(batched) == 2

    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, max_batch=1, max_len=32)
        solo.submit(Request(rid=0, prompt=p, max_tokens=5))
        ref = solo.run()[0].out
        assert batched[i] == ref, (
            f"prompt {i} (len {len(p)}) decoded differently batched vs "
            f"alone: {batched[i]} vs {ref}")
