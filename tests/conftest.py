"""Shared fixtures: isolate the packed engine's module-level state.

core/bitserial.py keeps three pieces of process-wide state:

* ``SKIP_STATS`` — cumulative zero-operand/dead-plane elision counters,
* ``ZERO_SKIP`` — the host engine's elision switch (tests toggle it for
  differential sweeps),
* the bucketed-jit ``_ENGINE_CACHE`` — a pure compilation cache keyed by
  (planes, acc, K); shared across tests DELIBERATELY (clearing it per test
  would recompile every jit-engine tile), and the tests that assert on
  ``engine_cache_info`` call ``engine_cache_clear()`` themselves.

The autouse fixture resets the first two around every test so sparsity
sweeps (tests/test_sparsity.py) and skip-accounting asserts can never
order-depend on whatever ran before them.  It also clears
``core/faults._ACTIVE`` — ``faults.inject`` restores it on exit, but a
test that fails INSIDE the scope must not leak an active fault
environment into whatever runs next.
"""
import pytest

from repro.core import bitserial as bs
from repro.core import faults


@pytest.fixture(autouse=True)
def _isolate_engine_state():
    bs.SKIP_STATS.reset()
    faults._ACTIVE = None
    zero_skip = bs.ZERO_SKIP
    yield
    bs.ZERO_SKIP = zero_skip
    bs.SKIP_STATS.reset()
    faults._ACTIVE = None
