"""int8 KV cache: quantized decode matches the bf16 cache path."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import transformer as T


def _cfgs(arch="qwen2-7b", **kw):
    base = reduced_config(get_config(arch), n_layers=2, d_model=64,
                          d_ff=128, vocab_size=128, head_dim=16,
                          dtype="float32", **kw)
    return base, dataclasses.replace(base, kv_dtype="int8")


def _greedy(cfg, params, prompt, n=8):
    logits, caches = T.prefill(cfg, params, prompt, max_len=64)
    toks = [int(jnp.argmax(logits[0]))]
    pos = prompt.shape[1]
    all_logits = [logits]
    for _ in range(n - 1):
        logits, caches = T.decode_step(
            cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), caches, pos)
        all_logits.append(logits)
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks, jnp.concatenate(all_logits, 0)


def test_kv8_decode_close_to_fp():
    cfg, cfg8 = _cfgs()
    params = T.init_lm(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (1, 12), 2, 128)
    toks_fp, logits_fp = _greedy(cfg, params, prompt)
    toks_q8, logits_q8 = _greedy(cfg8, params, prompt)
    # int8 cache quantization error is small; logits stay close and greedy
    # decode should agree on (almost) every step for a random tiny model
    err = jnp.abs(logits_fp - logits_q8).max() / jnp.abs(logits_fp).max()
    assert float(err) < 0.08, float(err)
    agree = np.mean([a == b for a, b in zip(toks_fp, toks_q8)])
    assert agree >= 0.75, (toks_fp, toks_q8)


def test_kv8_swa_ring_buffer():
    """Hymba-style sliding-window layers use the ring-buffer slot math."""
    cfg, cfg8 = _cfgs(arch="hymba-1.5b")
    params = T.init_lm(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(2), (1, 10), 2, 128)
    toks_fp, logits_fp = _greedy(cfg, params, prompt, n=6)
    toks_q8, logits_q8 = _greedy(cfg8, params, prompt, n=6)
    err = jnp.abs(logits_fp - logits_q8).max() / jnp.abs(logits_fp).max()
    assert float(err) < 0.1, float(err)


def test_kv8_cache_is_int8():
    _, cfg8 = _cfgs()
    caches = T.init_caches(cfg8, batch=2, seq_len=16)
    c = caches[0]["attn"]
    assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
    assert c["ks"].dtype == jnp.float32
    # payload+scales cost ~ (1 + 4/hd) bytes/elem vs 2 for bf16
    bytes8 = c["k"].nbytes + c["ks"].nbytes
    assert bytes8 < 0.7 * (c["k"].size * 2)


@pytest.mark.parametrize("arch", ["qwen2-7b", "hymba-1.5b"])
@pytest.mark.parametrize("quant", [False, True])
def test_decode_vector_positions_match_scalar(arch, quant):
    """PR 9: decode_step accepts a per-row int32 [B] position vector (the
    serving engine's mixed-prompt batches).  A uniform vector must be
    BYTE-identical to the scalar path — logits and every cache leaf —
    for fp and int8 caches, full attention and SWA rings."""
    cfg, cfg8 = _cfgs(arch)
    cfg = cfg8 if quant else cfg
    params = T.init_lm(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 6), 2, 128)
    _, caches = T.prefill(cfg, params, prompt, max_len=32)
    toks = jnp.asarray([[3], [4]], jnp.int32)
    pos = prompt.shape[1]
    logits_s, caches_s = T.decode_step(cfg, params, toks, caches, pos)
    logits_v, caches_v = T.decode_step(cfg, params, toks, caches,
                                       jnp.full((2,), pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(logits_s),
                                  np.asarray(logits_v))
    for ls, lv in zip(jax.tree.leaves(caches_s), jax.tree.leaves(caches_v)):
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lv))
