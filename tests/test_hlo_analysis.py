"""Loop-aware HLO analyzer validated against XLA's own cost analysis."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_analysis import xla_cost_analysis
from repro.distributed.hlo_loop_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_loop_free_dot_flops_match_xla():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a, b):
        return jnp.tanh(a @ b) @ b

    c = _compile(f, x, x)
    mine = analyze_hlo(c.as_text())
    xla = xla_cost_analysis(c)["flops"]
    # dots dominate; elementwise accounting differs by <2%
    assert mine.flops == pytest.approx(xla, rel=0.02)


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    L = 12

    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        y, _ = jax.lax.scan(body, a, None, length=L)
        return y

    c = _compile(f, x, x)
    mine = analyze_hlo(c.as_text())
    assert any(l["trips"] == L for l in mine.loops)
    expected = L * 2 * 128 ** 3
    assert mine.flops == pytest.approx(expected, rel=0.05)
    # XLA's own analysis misses the loop factor — that's the bug we fix
    assert xla_cost_analysis(c)["flops"] < expected / 2


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return jnp.tanh(d @ b), None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y

    c = _compile(f, x, x)
    mine = analyze_hlo(c.as_text())
    assert mine.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.05)


def test_bytes_scale_with_loop():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def mk(L):
        def f(a, b):
            def body(c, _):
                return jnp.tanh(c @ b), None
            y, _ = jax.lax.scan(body, a, None, length=L)
            return y
        return f

    b4 = analyze_hlo(_compile(mk(4), x, x).as_text()).bytes_accessed
    b8 = analyze_hlo(_compile(mk(8), x, x).as_text()).bytes_accessed
    assert b8 == pytest.approx(2 * b4, rel=0.15)
