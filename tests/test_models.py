"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: one forward/train step on CPU asserting output shapes +
no NaNs, and the strongest end-to-end check we have — prefill + decode_step
must reproduce the full-forward logits at the next position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config, shapes_for
from repro.models import frontends as F
from repro.models import transformer as T


@pytest.fixture(scope="module")
def rigs():
    out = {}
    for name in ARCH_IDS:
        cfg = reduced_config(get_config(name))
        params = T.init_lm(cfg, jax.random.PRNGKey(0))
        out[name] = (cfg, params)
    return out


def _inputs(cfg, key, B, S):
    if cfg.family == "vlm":
        return None, F.stub_embeddings(cfg, key, B, S)
    return F.stub_tokens(cfg, key, B, S), None


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_smoke(rigs, name):
    cfg, params = rigs[name]
    key = jax.random.PRNGKey(1)
    tokens, embeds = _inputs(cfg, key, 2, 64)
    hidden, _ = T.lm_apply(cfg, params, tokens, embeds=embeds)
    assert hidden.shape == (2, 64, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    logits = T.lm_logits(cfg, params, hidden[:, -1])
    assert logits.shape == (2, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_smoke(rigs, name):
    """One loss+grad step: finite loss, grads exist for every param."""
    cfg, params = rigs[name]
    key = jax.random.PRNGKey(2)
    B, S = 2, 64
    tokens, embeds = _inputs(cfg, key, B, S)
    if tokens is None:
        tokens = F.stub_tokens(cfg, key, B, S)
        embeds = None  # vlm trains over text tokens in the smoke test
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        return T.lm_loss(cfg, p, tokens, labels, loss_chunk=32)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    # random-init loss should be ~ log(vocab)
    assert 0.2 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_matches_full_forward(rigs, name):
    cfg, params = rigs[name]
    key = jax.random.PRNGKey(3)
    B, S = 2, 48
    tokens = F.stub_tokens(cfg, key, B, S + 1)
    if cfg.family == "vlm":
        emb = F.stub_embeddings(cfg, key, B, S)
        tok_emb = params["embed"][tokens[:, S : S + 1]]
        full_emb = jnp.concatenate([emb, tok_emb], axis=1)
        hidden, _ = T.lm_apply(cfg, params, embeds=full_emb)
        _, caches = T.prefill(cfg, params, embeds=emb, max_len=S + 1)
    else:
        hidden, _ = T.lm_apply(cfg, params, tokens)
        _, caches = T.prefill(cfg, params, tokens[:, :S], max_len=S + 1)
    want = T.lm_logits(cfg, params, hidden[:, S])
    got, _ = T.decode_step(cfg, params, tokens[:, S : S + 1], caches, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_multi_step_decode(rigs, name):
    """Four consecutive decode steps track the full forward."""
    cfg, params = rigs[name]
    if cfg.family == "vlm":
        pytest.skip("vlm covered by single-step test")
    key = jax.random.PRNGKey(4)
    B, S, n = 2, 40, 4
    tokens = F.stub_tokens(cfg, key, B, S + n)
    hidden, _ = T.lm_apply(cfg, params, tokens)
    _, caches = T.prefill(cfg, params, tokens[:, :S], max_len=S + n)
    for i in range(n):
        got, caches = T.decode_step(cfg, params, tokens[:, S + i : S + i + 1], caches, S + i)
        want = T.lm_logits(cfg, params, hidden[:, S + i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-4, rtol=3e-4)


def test_stage_plan_hymba():
    cfg = get_config("hymba-1.5b")
    stages = T.plan_stages(cfg)
    spans = [(s.start, s.length, s.window) for s in stages]
    assert spans == [(0, 1, 0), (1, 14, 1024), (15, 1, 0), (16, 15, 1024), (31, 1, 0)]
    assert sum(s.length for s in stages) == cfg.n_layers


def test_stage_plan_dense():
    cfg = get_config("qwen2-7b")
    stages = T.plan_stages(cfg)
    assert len(stages) == 1 and stages[0].length == cfg.n_layers


@pytest.mark.parametrize("name", ARCH_IDS)
def test_param_count_analytic_matches_actual(rigs, name):
    cfg, params = rigs[name]
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert actual == pytest.approx(analytic, rel=0.02), (actual, analytic)


def test_full_config_param_counts():
    """Sanity: full configs land near their advertised sizes."""
    expect = {
        "qwen1.5-110b": 111e9, "qwen2-7b": 7.6e9, "qwen1.5-32b": 35e9,
        "olmo-1b": 1.2e9, "mamba2-2.7b": 2.8e9, "hymba-1.5b": 1.6e9,
        # assignment config says 48L x 64e (the HF Moonlight-16B has 27L);
        # we implement the assignment's numbers -> 28B total / 4.5B active
        "moonshot-v1-16b-a3b": 28e9,
        "arctic-480b": 482e9,
        "musicgen-large": 2.4e9,  # decoder backbone (EnCodec/text stubs excluded)
        "internvl2-26b": 20e9,  # LM backbone of the 26B VLM
    }
    for name, want in expect.items():
        got = get_config(name).param_count()
        assert 0.85 * want < got < 1.15 * want, (name, got, want)


def test_shapes_for_skips_long_context_for_full_attention():
    assert len(shapes_for(get_config("qwen2-7b"))) == 3
    assert len(shapes_for(get_config("mamba2-2.7b"))) == 4
    assert len(shapes_for(get_config("hymba-1.5b"))) == 4


def test_moe_active_params():
    cfg = get_config("moonshot-v1-16b-a3b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()  # ~3B of 16B
