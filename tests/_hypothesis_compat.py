"""Deterministic stand-in for `hypothesis` when it isn't installed.

The real library is preferred whenever importable; offline containers fall
back to a fixed example sweep: each strategy first yields its boundary
values, then values drawn from a per-test seeded ``numpy`` generator, so
runs are reproducible and collection never fails on a missing dependency.

Usage in test modules (drop-in for the common subset)::

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _Strategy:
        """A value source: boundary examples first, then seeded draws."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self._edges = tuple(edges)

        def example(self, rng, i):
            if i < len(self._edges):
                return self._edges[i]
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                edges=(min_value, max_value),
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                             edges=(False, True))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False):
            del allow_nan  # the fallback never generates NaN
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                edges=(float(min_value), float(max_value)),
            )

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))],
                edges=tuple(options),
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng, len(elements._edges) + j)
                        for j in range(size)]

            edge = [elements.example(np.random.default_rng(0), j)
                    for j in range(max(min_size, 1))]
            return _Strategy(draw, edges=(edge,))

    def settings(max_examples: int = 10, deadline=None, **_kw):
        """Record the requested example count on the decorated callable."""

        def deco(fn):
            fn._hyp_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Run the test over a deterministic sweep of examples."""

        def deco(fn):
            def wrapper():
                conf = (getattr(wrapper, "_hyp_settings", None)
                        or getattr(fn, "_hyp_settings", {}))
                n = conf.get("max_examples", 10)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    args = [s.example(rng, i) for s in arg_strategies]
                    kwargs = {k: s.example(rng, i)
                              for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception as e:  # identify the failing example
                        raise AssertionError(
                            f"falsifying example #{i}: args={args!r} "
                            f"kwargs={kwargs!r}") from e

            # NOTE: deliberately no functools.wraps / __wrapped__ — pytest
            # must see a zero-argument signature, not the strategy params.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco


__all__ = ["given", "settings", "strategies", "HAVE_HYPOTHESIS"]
